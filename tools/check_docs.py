#!/usr/bin/env python
"""Link-check the repo docs: README/DESIGN/EXPERIMENTS cross-references.

Three classes of reference are verified (exit code 1 on any failure):

  1. Markdown links ``[text](target)`` in the doc files — relative targets
     must exist (external http(s)/mailto links are skipped: CI has no
     network guarantee).
  2. Backticked repo paths in the doc files — tokens that look like file
     paths (``src/...``, ``benchmarks/foo.py``, ``BENCH_*.json``) and dotted
     module paths (``repro.core.topology``) must resolve.
  3. Section anchors — every ``DESIGN.md §X`` / ``EXPERIMENTS.md §X``
     reference found in docs, source and tests must match a ``## §X``
     heading in the referenced file.
  4. Anchor coverage (the reverse direction) — every ``## §X`` heading
     defined in DESIGN.md / EXPERIMENTS.md must be cited at least once
     (full ``<file>.md §X`` form) from the docs, source or tests, so new
     sections cannot silently become dead weight.

Run from anywhere:  python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
DOC_FILES = ["README.md", "DESIGN.md", "EXPERIMENTS.md"]
CODE_GLOBS = ["src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py",
              "examples/**/*.py"]

MD_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
BACKTICK = re.compile(r"`([^`\n]+)`")
SECTION_REF = re.compile(r"(DESIGN|EXPERIMENTS)\.md\s+§([\w-]+)")
PATHLIKE = re.compile(r"^[\w./-]+\.(py|md|json|yml|yaml|txt)$")
MODULE = re.compile(r"^repro(\.\w+)+$")


def fail(errors: list[str], msg: str) -> None:
    errors.append(msg)


def module_exists(dotted: str) -> bool:
    """True if some prefix of ``a.b.c.Symbol`` resolves to a module/package
    (references may carry trailing class/function names)."""
    parts = dotted.split(".")
    for depth in range(len(parts), 1, -1):
        rel = Path("src", *parts[:depth])
        if (REPO / rel).with_suffix(".py").exists() or (REPO / rel).is_dir():
            return True
    return False


def section_anchors(md: str) -> set[str]:
    text = (REPO / md).read_text()
    return set(re.findall(r"^##\s+§([\w-]+)", text, flags=re.M))


def main() -> int:
    errors: list[str] = []
    anchors = {f: section_anchors(f) for f in ("DESIGN.md", "EXPERIMENTS.md")}

    for doc in DOC_FILES:
        path = REPO / doc
        if not path.exists():
            fail(errors, f"{doc}: file missing")
            continue
        text = path.read_text()
        for target in MD_LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            if not (REPO / target.split("#")[0]).exists():
                fail(errors, f"{doc}: broken link -> {target}")
        for tok in BACKTICK.findall(text):
            tok = tok.split("::")[0].strip()
            if PATHLIKE.match(tok) and "/" in tok:
                if not (REPO / tok).exists():
                    fail(errors, f"{doc}: backticked path missing -> {tok}")
            elif MODULE.match(tok) and not module_exists(tok):
                fail(errors, f"{doc}: backticked module missing -> {tok}")

    # section references from docs AND code/docstrings
    sources = [REPO / d for d in DOC_FILES]
    for glob in CODE_GLOBS:
        sources.extend(REPO.glob(glob))
    referenced: set[tuple[str, str]] = set()
    for src in sources:
        rel = src.relative_to(REPO)
        for fname, sec in SECTION_REF.findall(src.read_text()):
            known = anchors[f"{fname}.md"]
            # EXPERIMENTS uses word anchors (§Repro); DESIGN numeric (§6);
            # list items inside a section are cited as §Methodology-5
            if sec not in known and sec.split("-")[0] not in known:
                fail(errors, f"{rel}: dangling reference {fname}.md §{sec}")
            referenced.add((f"{fname}.md", sec.split("-")[0]))

    # reverse direction: every defined anchor must be cited somewhere
    for fname, known in anchors.items():
        for sec in sorted(known):
            if (fname, sec) not in referenced:
                fail(errors, f"{fname}: anchor §{sec} is never referenced")

    if errors:
        print(f"check_docs: {len(errors)} problem(s)")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"check_docs: OK ({len(DOC_FILES)} docs, "
          f"{len(sources)} files scanned for section refs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
