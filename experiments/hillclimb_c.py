import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.configs import registry
from repro.launch.dryrun import run_cell, OUT_DIR

def save(r, tag):
    p = OUT_DIR / f"{r['arch']}__{r['shape']}__{r['mesh']}__{tag}.json"
    r["tag"] = tag
    with open(p, "w") as f: json.dump(r, f, indent=2)
    rr = r["roofline"]
    cb = r["raw_cost_analysis"]["collective_by_kind"]
    print(f"[HC:{tag}] coll={rr['collective_s']*1e3:.1f}ms mem={rr['memory_s']*1e3:.1f}ms "
          f"hbm={r['memory']['per_device_hbm_bytes']/2**30:.2f} frac={rr['roofline_fraction']:.3f} "
          f"counts={r['raw_cost_analysis']['collective_counts']} "
          f"bytesMB={ {k: round(v/1e6,1) for k,v in cb.items()} }", flush=True)

for alg in ("auto", "psum", "hier_faithful", "hier_scatter", "wrht", "planned"):
    over = {"sync_algorithm": alg, "fsdp": False, "microbatches": 8, "sync_m": 5}
    try: save(run_cell("qwen2-1.5b", "train_4k", False, over, verbose=False), f"C_{alg}")
    except Exception as e: print(f"[HC:C {alg}] FAIL {type(e).__name__} {str(e)[:150]}", flush=True)
