import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, time
from pathlib import Path
from repro.configs import registry
from repro.launch.dryrun import run_cell, OUT_DIR

def save(r, tag):
    p = OUT_DIR / f"{r['arch']}__{r['shape']}__{r['mesh']}__{tag}.json"
    r["tag"] = tag
    with open(p, "w") as f: json.dump(r, f, indent=2)
    rr = r["roofline"]
    print(f"[HC:{tag}] {r['arch']} {r['shape']}: hbm={r['memory']['per_device_hbm_bytes']/2**30:.2f}GiB "
          f"args={r['memory']['argument_size_in_bytes']/2**30:.2f} "
          f"c/m/coll={rr['compute_s']*1e3:.1f}/{rr['memory_s']*1e3:.1f}/{rr['collective_s']*1e3:.1f}ms "
          f"frac={rr['roofline_fraction']:.3f} coll_counts={r['raw_cost_analysis']['collective_counts']}", flush=True)

# --- A: deepseek-67b train_4k memory ladder -------------------------------
for tag, over in [("A_mb16", {"microbatches": 16}),
                  ("A_mb16_bf16acc", {"microbatches": 16, "grad_accum_dtype": "bfloat16"})]:
    try: save(run_cell("deepseek-67b", "train_4k", False, over, verbose=False), tag)
    except Exception as e: print(f"[HC:{tag}] FAIL {e}", flush=True)

# --- B: 67b serve with TP-only weights (threshold change already applied) --
for shape in ("prefill_32k", "decode_32k"):
    try: save(run_cell("deepseek-67b", shape, False, None, verbose=False), "B_tponly")
    except Exception as e: print(f"[HC:B {shape}] FAIL {e}", flush=True)

# --- C: paper technique on qwen2 train — sync algorithm comparison ---------
for alg in ("auto", "psum", "hier_faithful", "hier_scatter", "wrht", "planned"):
    over = {"sync_algorithm": alg, "fsdp": False, "microbatches": 8, "sync_m": 5}
    try: save(run_cell("qwen2-1.5b", "train_4k", False, over, verbose=False), f"C_{alg}")
    except Exception as e: print(f"[HC:C {alg}] FAIL {type(e).__name__} {str(e)[:150]}", flush=True)
