"""Pallas TPU int8 symmetric quantize / dequant-accumulate kernels.

The compute hot-spot of the compressed cross-pod all-reduce
(core.compression): quantize before the wire, fused dequant+add after.
Per-block scales ([block] f32 alongside the int8 payload) keep the VPU busy
and the error bounded; block size 1024 aligns with the lane width.

``ef_quantize_bucketize`` is the planned-compressed hot path (DESIGN.md §15):
one pass per block fuses the error-feedback add (grad + residual), the
absmax scan, the scale, round/clip into the bucket's int8 wire buffer, the
dequantized value the collective reduces, and the new EF residual — five
reads/writes that the unfused jnp path spreads over as many kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)                  # [blk]
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.full_like(s_ref, scale)


def _dequant_add_kernel(q_ref, s_ref, acc_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (acc_ref[...].astype(jnp.float32)
                  + q * s_ref[0]).astype(o_ref.dtype)


def quantize_blocks(x: jax.Array, *, block: int = 1024, bits: int = 8,
                    interpret: bool = False):
    """x [n] -> (q int8 [n_pad], scales f32 [nblocks], n)."""
    qmax = float(2 ** (bits - 1) - 1)
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    nb = x.shape[0] // block
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * block,), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s, n


def _ef_quant_kernel(g_ref, e_ref, q_ref, s_ref, d_ref, r_ref, *, qmax: float):
    t = g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    # explicit reciprocal multiply, NOT `/ qmax`: XLA rewrites division by a
    # compile-time constant to a reciprocal multiply in some fusion contexts
    # but not others, which would break bit-equality with the reference
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-30) * (1.0 / qmax)
    q = jnp.clip(jnp.round(t / scale), -qmax, qmax)
    deq = q * scale
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.full_like(s_ref, scale)
    d_ref[...] = deq
    r_ref[...] = t - deq


def ef_quantize_bucketize(grad: jax.Array, residual: jax.Array, *,
                          block: int = 1024, bits: int = 8,
                          interpret: bool = False):
    """Fused EF quantize+bucketize: grad/residual [n] ->
    (q int8 [n_pad], scales f32 [nblocks], deq f32 [n_pad],
    new_residual f32 [n_pad], n).

    q/scales/deq (the wire contract) are bit-equal to
    ``ref.ef_quantize_bucketize_ref``; the residual matches to 1 ulp because
    the fused ``t - q*scale`` contracts into an FMA here while the reference
    rounds the dequantized product first.
    """
    qmax = float(2 ** (bits - 1) - 1)
    n = grad.shape[0]
    pad = (-n) % block
    if pad:
        grad = jnp.pad(grad, (0, pad))
        residual = jnp.pad(residual, (0, pad))
    nb = grad.shape[0] // block
    q, s, deq, new_r = pl.pallas_call(
        functools.partial(_ef_quant_kernel, qmax=qmax),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * block,), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb * block,), jnp.float32),
            jax.ShapeDtypeStruct((nb * block,), jnp.float32),
        ],
        interpret=interpret,
    )(grad, residual)
    return q, s, deq, new_r, n


def dequant_add(q: jax.Array, scales: jax.Array, acc: jax.Array, *,
                block: int = 1024, interpret: bool = False) -> jax.Array:
    """acc [n_pad] += dequant(q) (fused); returns same length as acc."""
    nb = scales.shape[0]
    return pl.pallas_call(
        _dequant_add_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        interpret=interpret,
    )(q, scales, acc)
