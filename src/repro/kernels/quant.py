"""Pallas TPU int8 symmetric quantize / dequant-accumulate kernels.

The compute hot-spot of the compressed cross-pod all-reduce
(core.compression): quantize before the wire, fused dequant+add after.
Per-block scales ([block] f32 alongside the int8 payload) keep the VPU busy
and the error bounded; block size 1024 aligns with the lane width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)                  # [blk]
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.full_like(s_ref, scale)


def _dequant_add_kernel(q_ref, s_ref, acc_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (acc_ref[...].astype(jnp.float32)
                  + q * s_ref[0]).astype(o_ref.dtype)


def quantize_blocks(x: jax.Array, *, block: int = 1024, bits: int = 8,
                    interpret: bool = False):
    """x [n] -> (q int8 [n_pad], scales f32 [nblocks], n)."""
    qmax = float(2 ** (bits - 1) - 1)
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    nb = x.shape[0] // block
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * block,), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s, n


def dequant_add(q: jax.Array, scales: jax.Array, acc: jax.Array, *,
                block: int = 1024, interpret: bool = False) -> jax.Array:
    """acc [n_pad] += dequant(q) (fused); returns same length as acc."""
    nb = scales.shape[0]
    return pl.pallas_call(
        _dequant_add_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        interpret=interpret,
    )(q, scales, acc)
