"""Pallas TPU flash attention (forward).

Grid = (batch*heads, q_blocks, kv_blocks); the kv axis is the innermost
(sequential on TPU), so the online-softmax running state (m, l, acc) lives in
VMEM scratch and persists across kv steps.  Block shapes are MXU-aligned
(q_block × head_dim and kv_block × head_dim tiles, multiples of 128 on the
matmul dims).  The output tile is written once, on the last kv step.

HBM -> VMEM traffic per q block: Q·D + S·D·2 (streamed kv) — the flash
pattern; nothing S×S ever exists.  The pure-jnp oracle is
``kernels/ref.py::flash_attention_ref`` (also the model-layer implementation
``models.layers.blocked_attention`` modulo layout).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, sm_scale: float, q_block: int, kv_block: int,
                  kv_seq: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # [qb, d]
    k = k_ref[0].astype(jnp.float32)          # [kvb, d]
    v = v_ref[0].astype(jnp.float32)          # [kvb, dv]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # [qb, kvb]
    kv_ids = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kv_ids < kv_seq
    if causal:
        q_ids = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        mask = jnp.logical_and(mask, q_ids >= kv_ids)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    q_block: int = 256, kv_block: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q [BH, Sq, D]; k/v [BH, Skv, D(v)] (kv already expanded across GQA
    groups by ops.py).  Returns [BH, Sq, Dv]."""
    bh, sq, d = q.shape
    skv, dv = k.shape[1], v.shape[2]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)

    qb = min(q_block, sq)
    kvb = min(kv_block, skv)
    pad_q = (-sq) % qb
    pad_kv = (-skv) % kvb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0)))
    nq, nk = q.shape[1] // qb, k.shape[1] // kvb

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=sm_scale,
        q_block=qb, kv_block=kvb, kv_seq=skv)

    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kvb, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kvb, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * qb, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :sq]
    return out
