"""Pallas TPU fused RMSNorm (+ scale) kernel.

Grid over row blocks; each step loads a [rows_block, d] tile into VMEM,
reduces mean-square in f32, rescales, multiplies by the weight vector —
one HBM read + one write per element (vs. 3+ for the unfused chain).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # [rb, d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            rows_block: int = 256, interpret: bool = False) -> jax.Array:
    """x [..., d]; w [d].  Row-blocked fused RMSNorm."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    rb = min(rows_block, n)
    pad = (-n) % rb
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xf.shape[0] // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w)
    if pad:
        out = out[:n]
    return out.reshape(shape)
