"""Pallas TPU kernel for the Mamba2 SSD intra-chunk compute.

Grid = (batch*heads, n_chunks); the chunk axis is sequential, so the carried
SSM state h [N, P] lives in VMEM scratch and flows across chunks — the
inter-chunk recurrence costs nothing extra.  Per chunk the kernel does the
three MXU matmuls of the SSD dual form:

    G   = (C · Bᵀ) ∘ L          [Q, Q]   decay-masked attention-like weights
    Y   = G · X̄  +  (exp(cum)·C) · h     intra + carried contribution
    h'  = exp(seg) · h + Bᵀ · (X̄ ∘ exp(seg - cum))

Block shapes: Q×N and Q×P tiles, Q=chunk (128), N=state (64..128), P=head_dim
— all MXU-friendly.  Oracle: ``kernels/ref.py::ssd_ref`` (=
models.ssm.ssd_chunked modulo layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)      # [Q, 1]
    a = a_ref[0].astype(jnp.float32)        # [1, 1] (negative decay rate)
    bm = b_ref[0].astype(jnp.float32)       # [Q, N]
    cm = c_ref[0].astype(jnp.float32)       # [Q, N]

    adt = dt * a                            # [Q, 1]
    cum = jnp.cumsum(adt, axis=0)           # [Q, 1]
    seg = cum[q - 1]                        # [1]

    # decay-masked intra weights
    li = cum - cum.T                        # [Q, Q]  cum_i - cum_j
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(row >= col, jnp.exp(li), 0.0)
    g = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ()))) * lmat  # [Q, Q]
    xbar = x * dt                           # [Q, P]
    y = jax.lax.dot(g, xbar)                # [Q, P]

    # carried-state contribution
    y = y + jax.lax.dot(cm * jnp.exp(cum), h_scr[...])

    # state update (xbar already carries dt_j)
    w = jnp.exp(seg - cum)                  # [Q, 1]
    h_scr[...] = jnp.exp(seg) * h_scr[...] + jax.lax.dot_general(
        bm, xbar * w, (((0,), (0,)), ((), ())))   # [N, P]

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
             cm: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> jax.Array:
    """x [BH, S, P]; dt [BH, S]; a [BH]; bm/cm [BH, S, N] -> y [BH, S, P].

    (batch and heads pre-folded by ops.py; B/C shared across heads are
    broadcast there.)
    """
    bh, s, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q
    dt2 = dt[..., None]
    a2 = a[:, None, None]

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, q, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nc * q, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt2, a2, bm, cm)
    if pad:
        out = out[:, :s]
    return out
