"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the kernels are TPU-target artifacts validated here in interpret mode
against ``ref.py`` (tests sweep shapes and dtypes).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import mamba_scan as _ms
from . import quant as _q
from . import rmsnorm as _rn


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal=True, q_block=256, kv_block=256,
                    interpret=None):
    """q [B,Sq,H,D]; k/v [B,Skv,K,D] (GQA: K | H).  Returns [B,Sq,H,D]."""
    interpret = _default_interpret() if interpret is None else interpret
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    # fold batch+kv-head, broadcast kv across the group dim
    qf = q.reshape(b, sq, kh, g, d).transpose(0, 2, 3, 1, 4).reshape(b * kh * g, sq, d)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kh, g, k.shape[1], d)).reshape(b * kh * g, -1, d)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kh, g, v.shape[1], v.shape[-1])).reshape(
                              b * kh * g, -1, v.shape[-1])
    out = _fa.flash_attention(qf, kf, vf, causal=causal, q_block=q_block,
                              kv_block=kv_block, interpret=interpret)
    return out.reshape(b, kh, g, sq, -1).transpose(0, 3, 1, 2, 4).reshape(b, sq, h, -1)


@partial(jax.jit, static_argnames=("eps", "rows_block", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, rows_block=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rn.rmsnorm(x, w, eps=eps, rows_block=rows_block, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, bm, cm, *, chunk=128, interpret=None):
    """x [B,S,H,P]; dt [B,S,H]; a [H]; bm/cm [B,S,N] (shared across heads)."""
    interpret = _default_interpret() if interpret is None else interpret
    b, s, h, p = x.shape
    n = bm.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    af = jnp.broadcast_to(a[None], (b, h)).reshape(b * h)
    bf = jnp.broadcast_to(bm[:, None], (b, h, s, n)).reshape(b * h, s, n)
    cf = jnp.broadcast_to(cm[:, None], (b, h, s, n)).reshape(b * h, s, n)
    y = _ms.ssd_scan(xf, dtf, af, bf, cf, chunk=chunk, interpret=interpret)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block", "bits", "interpret"))
def quantize_blocks(x, *, block=1024, bits=8, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _q.quantize_blocks(x, block=block, bits=bits, interpret=interpret)


@partial(jax.jit, static_argnames=("block", "bits", "interpret"))
def ef_quantize_bucketize(grad, residual, *, block=1024, bits=8,
                          interpret=None):
    """Fused EF quantize+bucketize (one pass: t = grad + residual, per-block
    absmax scale, round/clip into the int8 wire buffer, dequantized value,
    new residual)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _q.ef_quantize_bucketize(grad, residual, block=block, bits=bits,
                                    interpret=interpret)


@partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_add(q, scales, acc, *, block=1024, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _q.dequant_add(q, scales, acc, block=block, interpret=interpret)
