# Pallas TPU kernels for the framework's compute hot spots, each with a
# jit'd wrapper (ops.py) and a pure-jnp oracle (ref.py):
#   flash_attention   online-softmax attention (q/kv block grid, VMEM scratch)
#   rmsnorm           fused row-blocked RMSNorm
#   mamba_scan        Mamba2 SSD intra-chunk compute + carried state
#   quant             int8 block quantize / fused dequant-add (compressed sync)
# Kernels are TPU targets; on CPU (this container) ops.py runs interpret=True
# and tests/test_kernels.py sweeps shapes/dtypes against the oracles.
