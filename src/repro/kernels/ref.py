"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, sm_scale=None):
    """q [BH, Sq, D]; k/v [BH, Skv, D(v)]."""
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        sq, sk = s.shape[1], s.shape[2]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, w, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


def ssd_ref(x, dt, a, bm, cm):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    x [BH,S,P]; dt [BH,S]; a [BH]; bm/cm [BH,S,N] -> y [BH,S,P].
    """
    bh, s, p = x.shape
    n = bm.shape[-1]

    def per_batch(xb, dtb, ab, bb, cb):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            dec = jnp.exp(dtt * ab)
            h = dec * h + jnp.outer(bt, xt * dtt)
            return h, ct @ h

        h0 = jnp.zeros((n, p), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb.astype(jnp.float32),
                                        dtb.astype(jnp.float32),
                                        bb.astype(jnp.float32),
                                        cb.astype(jnp.float32)))
        return ys

    return jax.vmap(per_batch)(x, dt, a, bm, cm).astype(x.dtype)


def quantize_blocks_ref(x, *, block=1024, bits=8):
    qmax = float(2 ** (bits - 1) - 1)
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)) if pad else x
    xb = xp.reshape(-1, block).astype(jnp.float32)
    scales = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-30) / qmax
    q = jnp.clip(jnp.round(xb / scales[:, None]), -qmax, qmax).astype(jnp.int8)
    return q.reshape(-1), scales, n


def ef_quantize_bucketize_ref(grad, residual, *, block=1024, bits=8):
    """Oracle for the fused EF quantize+bucketize kernel: returns
    (q [n_pad] int8, scales [nblocks] f32, deq [n_pad] f32,
    new_residual [n_pad] f32, n)."""
    qmax = float(2 ** (bits - 1) - 1)
    n = grad.shape[0]
    pad = (-n) % block
    t = grad.astype(jnp.float32) + residual.astype(jnp.float32)
    tp = jnp.pad(t, (0, pad)) if pad else t
    tb = tp.reshape(-1, block)
    # reciprocal multiply to match the kernel bit-for-bit (see quant.py)
    scales = jnp.maximum(jnp.max(jnp.abs(tb), axis=1), 1e-30) * (1.0 / qmax)
    qb = jnp.clip(jnp.round(tb / scales[:, None]), -qmax, qmax)
    deq = qb * scales[:, None]
    resid = tb - deq
    return (qb.astype(jnp.int8).reshape(-1), scales, deq.reshape(-1),
            resid.reshape(-1), n)


def dequant_add_ref(q, scales, acc, *, block=1024):
    qb = q.reshape(-1, block).astype(jnp.float32)
    deq = (qb * scales[:, None]).reshape(-1)
    return (acc.astype(jnp.float32) + deq).astype(acc.dtype)
