"""Fault-tolerance runtime: closed-loop fault management, straggler watchdog,
failure injection (DESIGN.md §12/§14).

At 1000+ nodes the per-step failure probability is O(hours⁻¹); the trainer
treats every step as restartable AND the optical fabric as mutable:

  * ``HealthMonitor`` consumes per-resource telemetry
    (:class:`~repro.core.topology.ResourceObservation` — per-λ/per-span
    error or timeout events from the simulator probe
    ``repro.core.simulator.observe_faults``) plus ``StragglerEvent``s from
    the watchdog, and runs one hysteresis state machine per resource:
    *confirm-before-demote* (``ReplanPolicy.confirm_k`` consecutive errors
    before a resource enters the mask) and *cooldown-before-readmit*
    (``recover_k`` consecutive oks AND ``cooldown_steps`` since demotion
    before it leaves).  A flapping λ faster than the confirm window never
    thrashes the planner.
  * ``FaultManager`` closes the loop: probe → monitor → mask proposal →
    ``Trainer.replan`` (rate-limited by ``min_replan_interval``), replacing
    caller-injected ``degrade_at`` masks as the primary path.  Recovery
    replans shrink the mask back toward the healthy plan — a plan-cache /
    controller-memo hit, zero retraces (DESIGN.md §12).
  * ``StepWatchdog`` tracks a running median of step wall-times and flags
    steps slower than ``threshold ×`` median (straggler / pre-failure
    symptom).  Policy hooks: "log" (default), "checkpoint" (force an early
    checkpoint so the inevitable restart loses less), or a user callback.
  * ``FailureInjector`` deterministically raises at configured steps —
    the integration tests use it to prove checkpoint/restart reproduces the
    uninterrupted run bit-for-bit (same data source, same RNG).
"""

from __future__ import annotations

import logging
import statistics
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable

from repro.core.topology import FailureMask, ResourceObservation

log = logging.getLogger("repro.fault")


class InjectedFailure(RuntimeError):
    """Simulated node failure."""


@dataclass
class FailureInjector:
    """Deterministic failure injection for restart/re-plan tests.

    ``fail_at_steps`` raise :class:`InjectedFailure` once each (hard crash →
    trainer restart).  ``degrade_at`` maps a step to the
    :class:`~repro.core.topology.FailureMask` that becomes active there
    (soft optical failure → trainer re-plan, DESIGN.md §12); each mask is
    reported exactly once via :meth:`degradation`.  Masks are validated at
    construction — a wrong value type fails HERE with a clear error, not
    steps later deep inside ``Trainer.replan``.  ``reset()`` re-arms
    everything so a restarted trainer can reuse one injector without
    double-firing inside a single run loop.
    """

    fail_at_steps: tuple[int, ...] = ()
    fired: set[int] = field(default_factory=set)
    degrade_at: dict[int, FailureMask] = field(default_factory=dict)
    degraded_fired: set[int] = field(default_factory=set)

    def __post_init__(self) -> None:
        for step, mask in self.degrade_at.items():
            if not isinstance(mask, FailureMask):
                raise TypeError(
                    f"degrade_at[{step}] must be a FailureMask, got "
                    f"{type(mask).__name__} — build one with "
                    "topology.FailureMask(dead_segments=..., ...)")

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")

    def degradation(self, step: int) -> FailureMask | None:
        """The failure mask newly active at ``step`` (one-shot), else None."""
        if step in self.degrade_at and step not in self.degraded_fired:
            self.degraded_fired.add(step)
            return self.degrade_at[step]
        return None

    def reset(self) -> None:
        """Re-arm every configured failure and degradation."""
        self.fired.clear()
        self.degraded_fired.clear()


@dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float


class StepWatchdog:
    """Flags steps slower than ``threshold ×`` the running median.

    ``window`` bounds the median history (an O(1) ``deque(maxlen=...)``);
    ``warmup`` is the number of recorded steps before flagging starts, so
    the first compile-heavy steps never count as stragglers.
    """

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 on_straggler: Callable[[StragglerEvent], None] | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 warmup: int = 4):
        if warmup < 1:
            raise ValueError("warmup must be >= 1 recorded step")
        self.threshold = threshold
        self.window = window
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.clock = clock
        self._times: deque[float] = deque(maxlen=window)
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = self.clock()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "stop() without start()"
        dt = self.clock() - self._t0
        self._t0 = None
        if len(self._times) >= self.warmup:
            med = statistics.median(self._times)
            if dt > self.threshold * med:
                ev = StragglerEvent(step, dt, med)
                self.events.append(ev)
                if self.on_straggler is not None:
                    self.on_straggler(ev)
        self._times.append(dt)
        return dt


# ---------------------------------------------------------------------------
# Closed-loop fault management (DESIGN.md §14): observations -> hysteresis
# state machines -> FailureMask proposals -> Trainer.replan -> recovery.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReplanPolicy:
    """Hysteresis and rate limits of the fault-management loop.

    ``confirm_k``           consecutive error observations before a resource
                            is demoted into the mask (confirm-before-demote:
                            a λ flapping faster than this never replans).
    ``recover_k``           consecutive ok observations before a demoted
                            resource becomes readmission-eligible.
    ``cooldown_steps``      minimum steps a resource stays masked after its
                            demotion (cooldown-before-readmit: a slow
                            flapper is held out instead of oscillating).
    ``min_replan_interval`` minimum steps between two replans — the global
                            rate limit bounding planner thrash even when
                            many resources churn independently.
    ``straggler_probe``     consecutive stragglers before the manager runs
                            an out-of-band probe of its observation source
                            (timeouts are a pre-failure symptom; 0 disables).
    ``on_infeasible``       ``"keep"`` (default): a mask proposal the
                            planner rejects with ``DegradedInfeasibleError``
                            keeps the previous plan installed and the loop
                            running (failure-storm survival); ``"raise"``
                            propagates.
    """

    confirm_k: int = 3
    recover_k: int = 3
    cooldown_steps: int = 8
    min_replan_interval: int = 1
    straggler_probe: int = 2
    on_infeasible: str = "keep"

    def __post_init__(self) -> None:
        if min(self.confirm_k, self.recover_k) < 1:
            raise ValueError("confirm_k and recover_k must be >= 1")
        if self.cooldown_steps < 0 or self.min_replan_interval < 0:
            raise ValueError("cooldown_steps/min_replan_interval must be "
                             ">= 0")
        if self.on_infeasible not in ("keep", "raise"):
            raise ValueError(f"on_infeasible must be 'keep' or 'raise', "
                             f"got {self.on_infeasible!r}")


# per-resource hysteresis states
UP, SUSPECT, DOWN, RECOVERING = "up", "suspect", "down", "recovering"


@dataclass
class _ResourceRecord:
    state: str = UP
    errors: int = 0          # consecutive errors while UP/SUSPECT
    oks: int = 0             # consecutive oks while DOWN/RECOVERING
    demoted_at: int | None = None


class HealthMonitor:
    """Per-resource hysteresis state machines over raw telemetry.

    Feed :class:`~repro.core.topology.ResourceObservation`s via
    :meth:`observe`; read the confirmed-down set as :attr:`mask`.  The
    state machine per resource (DESIGN.md §14):

    ``up --error--> suspect --confirm_k'th error--> down``
    ``suspect --ok--> up`` (transient glitch absorbed, nothing replans)
    ``down --ok--> recovering --recover_k'th ok AND cooldown elapsed--> up``
    ``recovering --error--> down`` (flap caught, cooldown restarts)

    Demotions and readmissions mutate :attr:`mask`; :meth:`advance` reports
    the new mask once per change (the :class:`FaultManager` turns that into
    a rate-limited replan).
    """

    def __init__(self, policy: ReplanPolicy | None = None) -> None:
        self.policy = policy or ReplanPolicy()
        self._records: dict[tuple[str, tuple[int, int]], _ResourceRecord] = {}
        self._mask = FailureMask()
        self._dirty = False
        self.demotions = 0
        self.readmissions = 0
        self.straggler_streak = 0

    # ------------------------------------------------------------- state
    @property
    def mask(self) -> FailureMask:
        """The currently confirmed-down resources as a
        :class:`~repro.core.topology.FailureMask`."""
        return self._mask

    def state(self, kind: str, ident) -> str:
        rec = self._records.get((kind, (int(ident[0]), int(ident[1]))))
        return UP if rec is None else rec.state

    def _rebuild_mask(self) -> None:
        segs, lams, txs = [], [], []
        for (kind, ident), rec in self._records.items():
            if rec.state in (DOWN, RECOVERING):
                {"segment": segs, "wavelength": lams,
                 "transceiver": txs}[kind].append(ident)
        self._mask = FailureMask(dead_segments=tuple(segs),
                                 dead_wavelengths=tuple(lams),
                                 dead_transceivers=tuple(txs))

    # ------------------------------------------------------------ inputs
    def observe(self, obs: ResourceObservation) -> None:
        """Advance one resource's state machine by one telemetry sample."""
        key = (obs.kind, obs.ident)
        rec = self._records.get(key)
        if rec is None:
            if obs.ok:
                return  # healthy resource we were not tracking: stay lazy
            rec = self._records[key] = _ResourceRecord()
        p = self.policy
        if rec.state in (UP, SUSPECT):
            if obs.ok:
                rec.state, rec.errors = UP, 0
            else:
                rec.state = SUSPECT
                rec.errors += 1
                if rec.errors >= p.confirm_k:
                    rec.state, rec.oks = DOWN, 0
                    rec.demoted_at = obs.step
                    self.demotions += 1
                    self._dirty = True
        else:  # DOWN / RECOVERING
            if not obs.ok:
                rec.state, rec.oks = DOWN, 0
            else:
                rec.state = RECOVERING
                rec.oks += 1
                if (rec.oks >= p.recover_k
                        and obs.step - rec.demoted_at >= p.cooldown_steps):
                    rec.state, rec.errors = UP, 0
                    rec.demoted_at = None
                    self.readmissions += 1
                    self._dirty = True
        if self._dirty:
            self._rebuild_mask()
            self._dirty = False
            self._changed = True

    _changed = False

    def observe_straggler(self, event: StragglerEvent) -> None:
        """Stragglers are a pre-failure symptom without resource
        attribution: they raise :attr:`straggler_streak`, which the
        :class:`FaultManager` uses to trigger an out-of-band probe of its
        observation source (``ReplanPolicy.straggler_probe``)."""
        self.straggler_streak += 1

    def note_healthy_step(self) -> None:
        """A step finished without straggling — the streak resets."""
        self.straggler_streak = 0

    # ----------------------------------------------------------- output
    def advance(self, step: int) -> FailureMask | None:
        """The new mask if the confirmed-down set changed since the last
        call, else ``None``."""
        if self._changed:
            self._changed = False
            return self._mask
        return None


class FaultManager:
    """The closed loop: probe → :class:`HealthMonitor` → rate-limited
    ``replan`` (DESIGN.md §14).

    ``probe(step)`` returns the step's telemetry (an iterable of
    :class:`~repro.core.topology.ResourceObservation`) — in the simulated
    system that is ``simulator.observe_faults(timeline, step)``; a real
    deployment would adapt its transport telemetry.  ``attach(replan_fn)``
    connects the trainer (done automatically by ``Trainer.__post_init__``);
    the loop then runs from :meth:`on_step` once per training step.

    A mask proposal the planner rejects as infeasible keeps the previous
    plan installed when ``policy.on_infeasible == "keep"`` — the storm-
    survival mode: the loop logs, counts, and keeps training on the last
    feasible plan instead of crashing mid-storm.
    """

    def __init__(self,
                 probe: Callable[[int], Iterable[ResourceObservation]],
                 policy: ReplanPolicy | None = None,
                 monitor: HealthMonitor | None = None) -> None:
        self.policy = policy or ReplanPolicy()
        self.monitor = monitor or HealthMonitor(self.policy)
        self.probe = probe
        self._replan: Callable[[FailureMask | None], object] | None = None
        self.current_mask: FailureMask | None = None
        self.replan_count = 0
        self.infeasible_count = 0
        self.last_replan_step: int | None = None
        self.deferred: FailureMask | None = None
        self.history: list[dict] = []

    def attach(self, replan_fn: Callable[[FailureMask | None], object]) -> None:
        """Connect the replan sink (``Trainer.replan`` or a test stub)."""
        self._replan = replan_fn

    # ------------------------------------------------------------- loop
    def observe_straggler(self, event: StragglerEvent) -> None:
        self.monitor.observe_straggler(event)

    def on_step(self, step: int) -> FailureMask | None:
        """Run one loop iteration: feed the step's telemetry through the
        monitor and apply any mask change as a (rate-limited) replan.
        Returns the mask applied this step, or ``None``."""
        for obs in self.probe(step):
            self.monitor.observe(obs)
        proposal = self.monitor.advance(step)
        if proposal is None and self.deferred is not None:
            proposal = self.deferred  # rate-limited earlier; retry now
        if proposal is None and self.policy.straggler_probe and (
                self.monitor.straggler_streak >= self.policy.straggler_probe):
            # persistent timeouts with no confirmed fault: the next loop
            # iterations keep probing; nothing to apply yet
            self.monitor.straggler_streak = 0
        if proposal is None:
            return None
        if (self.last_replan_step is not None
                and step - self.last_replan_step
                < self.policy.min_replan_interval):
            self.deferred = proposal  # hold until the rate limit clears
            return None
        self.deferred = None
        return self._apply(step, proposal)

    def _apply(self, step: int, mask: FailureMask) -> FailureMask | None:
        from repro.core.wrht import DegradedInfeasibleError

        if self._replan is None:
            raise RuntimeError("FaultManager.on_step before attach() — the "
                               "trainer attaches its replan in __post_init__")
        normalized = None if mask.empty else mask
        if normalized == self.current_mask:
            return None
        try:
            self._replan(mask)
        except DegradedInfeasibleError as e:
            self.infeasible_count += 1
            self.history.append({"step": step, "mask": mask.fingerprint(),
                                 "applied": False, "reason": str(e)})
            if self.policy.on_infeasible == "raise":
                raise
            log.warning("step %d: proposed mask %s infeasible — keeping the "
                        "previous plan (%s)", step, mask.fingerprint(), e)
            return None
        self.current_mask = normalized
        self.replan_count += 1
        self.last_replan_step = step
        self.history.append({"step": step, "mask": mask.fingerprint(),
                             "applied": True})
        return mask
