"""Fault-tolerance runtime: straggler watchdog + failure injection.

At 1000+ nodes the per-step failure probability is O(hours⁻¹); the trainer
treats every step as restartable:

  * ``StepWatchdog`` tracks a running median of step wall-times and flags
    steps slower than ``threshold ×`` median (straggler / pre-failure
    symptom).  Policy hooks: "log" (default), "checkpoint" (force an early
    checkpoint so the inevitable restart loses less), or a user callback
    (e.g. re-shard away from the slow host — the elastic path).
  * ``FailureInjector`` deterministically raises at configured steps —
    the integration tests use it to prove checkpoint/restart reproduces the
    uninterrupted run bit-for-bit (same data source, same RNG).
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable


class InjectedFailure(RuntimeError):
    """Simulated node failure."""


@dataclass
class FailureInjector:
    """Deterministic failure injection for restart/re-plan tests.

    ``fail_at_steps`` raise :class:`InjectedFailure` once each (hard crash →
    trainer restart).  ``degrade_at`` maps a step to the
    :class:`~repro.core.topology.FailureMask` that becomes active there
    (soft optical failure → trainer re-plan, DESIGN.md §12); each mask is
    reported exactly once via :meth:`degradation`.  ``reset()`` re-arms
    everything so a restarted trainer can reuse one injector without
    double-firing inside a single run loop.
    """

    fail_at_steps: tuple[int, ...] = ()
    fired: set[int] = field(default_factory=set)
    degrade_at: dict[int, object] = field(default_factory=dict)
    degraded_fired: set[int] = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")

    def degradation(self, step: int):
        """The failure mask newly active at ``step`` (one-shot), else None."""
        if step in self.degrade_at and step not in self.degraded_fired:
            self.degraded_fired.add(step)
            return self.degrade_at[step]
        return None

    def reset(self) -> None:
        """Re-arm every configured failure and degradation."""
        self.fired.clear()
        self.degraded_fired.clear()


@dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float


class StepWatchdog:
    def __init__(self, threshold: float = 3.0, window: int = 32,
                 on_straggler: Callable[[StragglerEvent], None] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.clock = clock
        self._times: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = self.clock()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "stop() without start()"
        dt = self.clock() - self._t0
        self._t0 = None
        if len(self._times) >= 4:
            med = statistics.median(self._times)
            if dt > self.threshold * med:
                ev = StragglerEvent(step, dt, med)
                self.events.append(ev)
                if self.on_straggler is not None:
                    self.on_straggler(ev)
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        return dt
