from .fault_tolerance import (  # noqa: F401
    FailureInjector,
    InjectedFailure,
    StepWatchdog,
)
