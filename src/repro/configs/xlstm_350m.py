"""xLSTM-350M — sLSTM + mLSTM recurrent blocks [arXiv:2405.04517].

24 blocks, d_model 1024, 4 heads, vocab 50304.  d_ff=0 per assignment: the
blocks carry their own up/down projections (proj_factor 2.0) instead of a
separate MLP.  Fully recurrent -> subquadratic -> long_500k runs.
"""

from .base import ModelConfig, XLSTMConfig, smoke_variant

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pos_embed="none",
    xlstm=XLSTMConfig(slstm_every=2, n_heads=4, proj_factor=2.0),
    subquadratic=True,
)

SMOKE = smoke_variant(CONFIG)
