"""Qwen1.5-4B — dense decoder, MHA + QKV bias [hf:Qwen/Qwen1.5-4B; hf].

40L, d_model 2560, 20 heads (kv=20, i.e. full MHA), d_ff 6912, vocab 151936.
"""

from .base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="decoder",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = smoke_variant(CONFIG, n_kv_heads=4)
