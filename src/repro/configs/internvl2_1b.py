"""InternVL2-1B — InternViT frontend + Qwen2-0.5B LM [arXiv:2404.16821; hf].

LM backbone: 24L, d_model 896, 14 heads (GQA kv=2), d_ff 4864, vocab 151655.
The InternViT-300M vision tower is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings [batch, 256, d_model]
prepended to the token stream.  Full attention -> long_500k skipped.
"""

from .base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    frontend="patch_embed",
    frontend_seq=256,
)

SMOKE = smoke_variant(CONFIG, n_kv_heads=2)
