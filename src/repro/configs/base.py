"""Config system: model/mesh/shape/train configs and the arch registry.

Every assigned architecture lives in ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (exact published numbers) and ``SMOKE`` (reduced same-family
variant for CPU tests).  ``registry.get(name)`` resolves either.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Literal


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                   # per-expert FFN hidden size
    n_shared: int = 0               # always-on shared experts (deepseek-v2)
    capacity_factor: float = 1.25   # dispatch capacity multiplier
    router_aux_weight: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style selective state space block."""

    state_dim: int = 64
    head_dim: int = 64              # per-SSM-head channel width
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128                # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: alternating sLSTM / mLSTM blocks."""

    slstm_every: int = 2            # every k-th block is sLSTM, rest mLSTM
    n_heads: int = 4
    proj_factor: float = 2.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["decoder", "encdec", "xlstm", "hybrid", "moe", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None      # default d_model // n_heads (gemma: 256)
    act: str = "silu"                # "silu"(swiglu) | "geglu" | "gelu"(plain)
    qkv_bias: bool = False           # qwen-style attention bias
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    pos_embed: str = "rope"          # "rope" | "learned" | "none"
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    dense_d_ff: int = 0              # FFN width of the first_k_dense layers
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    # hybrid (zamba2): attn block shared across periodic insertions
    attn_every: int = 0              # 0 = no interleaved shared attention
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper 30s @ 50Hz after conv stub
    # modality frontend stubs
    frontend: Literal[None, "patch_embed", "audio_frames"] = None
    frontend_seq: int = 0            # patches/frames prepended to the LM
    # learned-position table size (whisper-style models)
    learned_pos_max: int = 32768
    # long-context capability (sub-quadratic families only)
    subquadratic: bool = False
    sliding_window: int | None = None  # used by hybrid attn at long context
    first_k_dense: int = 0           # deepseek-v2: first k layers dense FFN

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows, rounded to 256 so the vocab dim shards over
        the 16-way model axis (standard production padding; the pad logits
        are masked to -1e9 in unembed_apply)."""
        return -(-self.vocab_size // 256) * 256


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The four assigned LM shapes (applied to every architecture)
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    microbatches: int = 1            # gradient accumulation splits
    param_dtype: str = "float32"     # master copy
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    grad_accum_dtype: str = "float32"  # microbatch accumulator (bf16 at 100B+)
    remat: str = "full"              # "none" | "full" | "dots"
    fsdp: bool = False               # shard params/opt over the data axis
    # --- the paper's technique, first-class ---
    sync_algorithm: str = "auto"     # auto|psum|ring|rd|bt|wrht|hier_faithful|
                                     # hier_scatter|planned|planned_sharded|
                                     # planned_pipelined|planned_compressed|
                                     # planned_sharded_compressed
    # planned_pipelined only: buckets in flight between their RS and AG
    # phases — bucket k+1's reduce-scatter is issued before bucket k's
    # all-gather so the two ride one composed ring schedule (DESIGN.md §13)
    pipeline_depth: int = 2
    # wire dtype for explicit gradient sync: f32 default (the XLA *CPU*
    # backend aborts on some bf16 collectives — see EXPERIMENTS §Perf-10);
    # set "bfloat16" on TPU for 2x fewer wire bytes
    sync_dtype: str = "float32"
    sync_m: int = 17                 # WRHT branching (2w+1 analogue)
    bucket_bytes: int = 32 * 2**20
    compress_pod_axis: bool = False  # int8+EF on the pod axis
    # planned_compressed / planned_sharded_compressed only: the per-bucket
    # wire-width sweep the planner runs at setup (DESIGN.md §15).  Each
    # bucket independently picks the cheapest width — small latency-bound
    # buckets decline compression (stay 32) because the quantize/dequant
    # overhead exceeds the β saving; the chosen widths are then frozen for
    # the run so an online re-plan never retraces.
    compress_bits: tuple[int, ...] = (32, 8, 4)
    compress_block: int = 1024       # per-block scale granularity (EF quant)
    compress_fused_kernel: bool = False  # fused pallas quantize+bucketize


def smoke_variant(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family config: tiny dims, same structural features."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.attn_every == 0 else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(4, cfg.n_kv_heads * 4 // max(cfg.n_heads, 1)) or 1),
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=64 if cfg.head_dim else None,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=32 if cfg.encoder_layers else cfg.encoder_seq,
        frontend_seq=8 if cfg.frontend else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        first_k_dense=min(cfg.first_k_dense, 1),
    )
    if cfg.moe:
        kw["moe"] = replace(cfg.moe, n_experts=4, top_k=2, d_expert=64,
                            n_shared=min(cfg.moe.n_shared, 1))
    if cfg.mla:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
    if cfg.ssm:
        kw["ssm"] = replace(cfg.ssm, state_dim=16, head_dim=32, chunk=16)
    if cfg.xlstm:
        kw["xlstm"] = replace(cfg.xlstm, n_heads=2)
    kw.update(over)
    return replace(cfg, name=cfg.name + "-smoke", **kw)
