"""Gemma-7B — dense decoder, GeGLU, head_dim 256 [arXiv:2403.08295; hf].

28L, d_model 3072, 16 heads (kv=16), d_ff 24576, vocab 256000.  Embeddings
tied and scaled by sqrt(d_model) (gemma convention).
"""

from .base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="gemma-7b",
    family="decoder",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    act="geglu",
    tie_embeddings=True,
)

SMOKE = smoke_variant(CONFIG, n_kv_heads=4)
