"""DeepSeek-V2-236B — MoE with Multi-head Latent Attention [arXiv:2405.04434; hf].

60L, d_model 5120, 128 heads, vocab 102400.  MLA: kv_lora_rank 512,
q_lora_rank 1536, qk_nope 128 + qk_rope 64, v_head 128.  MoE: 2 shared +
160 routed experts, top-6, expert width 1536; the first layer uses a dense
FFN (width 12288).  Full attention (MLA is exact attention) -> long_500k
skipped.
"""

from .base import MLAConfig, ModelConfig, MoEConfig, smoke_variant

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,      # MLA: kv heads = q heads after decompression
    d_ff=1536,
    dense_d_ff=12288,
    first_k_dense=1,
    vocab_size=102400,
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
)

SMOKE = smoke_variant(CONFIG, n_heads=4, n_kv_heads=4)
