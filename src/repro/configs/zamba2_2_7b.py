"""Zamba2-2.7B — Mamba2 backbone + shared attention block [arXiv:2411.15242; hf].

54 Mamba2 layers, d_model 2560, ssm_state 64; a SHARED full transformer block
(32 heads, d_ff 10240) is interleaved every 6 Mamba2 layers (same weights at
every insertion — Zamba's parameter-sharing trick).  vocab 32000.

Sub-quadratic: the Mamba2 state is O(1) in sequence length; at long_500k the
shared attention block runs with a sliding window (4096) so the whole model
stays sub-quadratic (noted in DESIGN.md §5).
"""

from .base import ModelConfig, SSMConfig, smoke_variant

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    attn_every=6,
    subquadratic=True,
    sliding_window=4096,
)

SMOKE = smoke_variant(CONFIG)
