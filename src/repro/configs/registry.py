"""Architecture registry: ``--arch <id>`` resolution for every entry point."""

from __future__ import annotations

from . import (
    deepseek_67b,
    deepseek_v2_236b,
    gemma_7b,
    granite_moe_1b,
    internvl2_1b,
    qwen1_5_4b,
    qwen2_1_5b,
    whisper_medium,
    xlstm_350m,
    zamba2_2_7b,
)
from .base import SHAPES, MeshConfig, ModelConfig, ShapeConfig

_MODULES = {
    "deepseek-67b": deepseek_67b,
    "qwen2-1.5b": qwen2_1_5b,
    "qwen1.5-4b": qwen1_5_4b,
    "gemma-7b": gemma_7b,
    "whisper-medium": whisper_medium,
    "xlstm-350m": xlstm_350m,
    "internvl2-1b": internvl2_1b,
    "zamba2-2.7b": zamba2_2_7b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "deepseek-v2-236b": deepseek_v2_236b,
}

ARCH_IDS = tuple(_MODULES)


def get(name: str, smoke: bool = False) -> ModelConfig:
    key = name.removesuffix("-smoke")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {', '.join(ARCH_IDS)}")
    mod = _MODULES[key]
    return mod.SMOKE if (smoke or name.endswith("-smoke")) else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {', '.join(SHAPES)}")
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) cells; long_500k only for sub-quadratic
    archs unless include_skipped (skips recorded in DESIGN.md §5)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get(arch)
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and not cfg.subquadratic
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name, skipped))
    return out
