"""Whisper-medium — encoder-decoder audio transformer [arXiv:2212.04356].

24+24L, d_model 1024, 16 heads, d_ff 4096, vocab 51865.  LayerNorm, learned
positions, plain GELU MLP.  The conv audio frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
[batch, 1500, d_model] for the encoder.  Full attention -> long_500k skipped.
"""

from .base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,                  # decoder layers
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    pos_embed="learned",
    frontend="audio_frames",
    norm_eps=1e-5,
    tie_embeddings=True,
)

SMOKE = smoke_variant(CONFIG, n_kv_heads=4)
