"""Granite-3.0-1B-A400M — MoE decoder [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d_model 1024, 16 heads (GQA kv=8), vocab 49155; MoE with 32 experts,
top-8 routing, expert FFN width 512.  Full attention -> long_500k skipped.
"""

from .base import ModelConfig, MoEConfig, smoke_variant

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
)

SMOKE = smoke_variant(CONFIG)
