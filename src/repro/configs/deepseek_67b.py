"""DeepSeek-67B — dense llama-arch decoder [arXiv:2401.02954; hf].

95L, d_model 8192, 64 heads (GQA kv=8), d_ff 22016, vocab 102400.
Pure full attention -> long_500k is skipped (see DESIGN.md §5).
"""

from .base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="decoder",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=1e4,
)

SMOKE = smoke_variant(CONFIG)
