"""xLSTM: alternating sLSTM (scalar memory) and mLSTM (matrix memory) blocks.

mLSTM trains with the *chunkwise-parallel* formulation (quadratic within a
chunk, recurrent across chunks — same shape as Mamba2's SSD), with
log-domain exponential gating and the max-stabilizer carried across chunks.
A naive per-token scan would store the [dh, dh] matrix memory per step for
backprop (hundreds of GB at 4k); the chunkwise form stores it per *chunk*.

sLSTM is inherently sequential (recurrent gate connections through h_{t-1});
it runs as a lax.scan over time with tiny per-step state — the paper's
trade-off, kept faithfully.

Layer pattern: blocks alternate [sLSTM, mLSTM] (cfg.xlstm.slstm_every == 2),
scanned in pairs so the stacked-params trick still applies.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.parallel import context as pctx
from . import layers as L

CHUNK = 64


def _dims(cfg: ModelConfig):
    x: XLSTMConfig = cfg.xlstm
    d_i = int(x.proj_factor * cfg.d_model)
    h = x.n_heads
    return x, d_i, h, d_i // h


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    _, d_i, h, dh = _dims(cfg)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    return {
        "ln": L.init_norm(cfg, dtype),
        "up": L._dense_init(ks[0], (d, 2 * d_i), dtype),
        "wq": L._dense_init(ks[1], (d_i, d_i), dtype),
        "wk": L._dense_init(ks[2], (d_i, d_i), dtype),
        "wv": L._dense_init(ks[3], (d_i, d_i), dtype),
        "wi": L._dense_init(ks[4], (d_i, h), dtype),
        "bi": jnp.zeros((h,), dtype),
        "wf": L._dense_init(ks[5], (d_i, h), dtype),
        "bf": jnp.full((h,), 3.0, dtype),            # forget-gate bias init
        "gn": jnp.ones((d_i,), dtype),
        "down": L._dense_init(ks[6], (d_i, d), dtype),
    }


def mlstm_chunked(q, k, v, ilog, flog, state):
    """Chunkwise mLSTM.  q/k/v [B,S,H,dh]; ilog/flog [B,S,H] (log gates);
    state: (C [B,H,dh,dh], n [B,H,dh], m [B,H]).  Returns (h [B,S,H,dh],
    new_state).  All math in f32/log-domain."""
    b, s, h, dh = q.shape
    qn = min(CHUNK, s)
    pad = (-s) % qn
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v, ilog, flog = map(zp, (q, k, v, ilog, flog))
        # padded steps: i = -inf (no input), f = 0 (identity decay)
        padmask = jnp.arange(q.shape[1]) >= s
        ilog = jnp.where(padmask[None, :, None], -1e30, ilog)
        flog = jnp.where(padmask[None, :, None], 0.0, flog)
    nc = q.shape[1] // qn

    def r(a):  # [B, S, ...] -> [nc, B, Q, ...]
        return a.reshape(b, nc, qn, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    qs, ks_, vs, is_, fs = map(r, (q, k, v, ilog, flog))
    scale = 1.0 / math.sqrt(dh)
    causal = jnp.tril(jnp.ones((qn, qn), bool))

    def chunk_step(carry, inp):
        C, n, m = carry                      # [B,H,dh,dh], [B,H,dh], [B,H]
        qk, kk, vk, ik, fk = inp             # [B,Q,H,*]
        bcum = jnp.cumsum(fk, axis=1)        # [B,Q,H] cumulative log-decay
        # D[i,j] = bcum_i - bcum_j + ilog_j  (j <= i)
        dmat = bcum[:, :, None, :] - bcum[:, None, :, :] + ik[:, None, :, :]
        dmat = jnp.where(causal[None, :, :, None], dmat, -1e30)
        inter_log = bcum + m[:, None, :]     # [B,Q,H] log-weight of carry
        m_row = jnp.maximum(dmat.max(axis=2), inter_log)   # [B,Q,H]
        sm = jnp.exp(dmat - m_row[:, :, None, :])          # [B,Q,Q,H]
        qk_dot = jnp.einsum("bihd,bjhd->bijh", qk, kk) * scale
        w = qk_dot * sm
        inter_w = jnp.exp(inter_log - m_row)               # [B,Q,H]
        numer = jnp.einsum("bijh,bjhd->bihd", w, vk) + \
            inter_w[..., None] * jnp.einsum("bihd,bhde->bihe", qk, C) * scale
        denom = jnp.einsum("bijh->bih", w) + \
            inter_w * jnp.einsum("bihd,bhd->bih", qk, n) * scale
        hout = numer / jnp.maximum(jnp.abs(denom), jnp.exp(-m_row))[..., None]
        # chunk-end state
        bq = bcum[:, -1, :]                                # [B,H]
        m_state = jnp.maximum(bq + m, (bq[:, None, :] - bcum + ik).max(axis=1))
        wstate = jnp.exp(bq[:, None, :] - bcum + ik - m_state[:, None, :])
        C_new = jnp.exp(bq + m - m_state)[..., None, None] * C + \
            jnp.einsum("bjh,bjhd,bjhe->bhde", wstate, kk, vk)
        n_new = jnp.exp(bq + m - m_state)[..., None] * n + \
            jnp.einsum("bjh,bjhd->bhd", wstate, kk)
        return (C_new, n_new, m_state), hout

    (C, n, m), hs = lax.scan(chunk_step, state, (qs, ks_, vs, is_, fs))
    hout = hs.transpose(1, 0, 2, 3, 4).reshape(b, nc * qn, h, dh)
    if pad:
        hout = hout[:, :s]
    return hout, (C, n, m)


def mlstm_apply(p, x, cfg: ModelConfig, *, state=None):
    _, d_i, h, dh = _dims(cfg)
    b, s, _ = x.shape
    res = x
    xn = L.norm_apply(p["ln"], x, cfg)
    up = xn @ p["up"].astype(x.dtype)
    xm, z = up[..., :d_i], up[..., d_i:]
    f32 = jnp.float32
    q = (xm @ p["wq"].astype(x.dtype)).reshape(b, s, h, dh).astype(f32)
    k = (xm @ p["wk"].astype(x.dtype)).reshape(b, s, h, dh).astype(f32)
    v = (xm @ p["wv"].astype(x.dtype)).reshape(b, s, h, dh).astype(f32)
    ilog = (xm @ p["wi"].astype(x.dtype)).astype(f32) + p["bi"].astype(f32)
    flog = jax.nn.log_sigmoid(
        (xm @ p["wf"].astype(x.dtype)).astype(f32) + p["bf"].astype(f32))
    st = state if state is not None else (
        jnp.zeros((b, h, dh, dh), f32), jnp.zeros((b, h, dh), f32),
        jnp.full((b, h), -1e30, f32),
    )
    hout, new_state = mlstm_chunked(q, k, v, ilog, flog, st)
    hout = hout.reshape(b, s, d_i).astype(x.dtype)
    hout = L._rms(hout, p["gn"], cfg.norm_eps) * jax.nn.silu(z)
    out = res + hout @ p["down"].astype(x.dtype)
    return pctx.constrain(out, pctx.BATCH, None, None), \
        (new_state if state is not None else None)


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    x, _, h, _ = _dims(cfg)
    d = cfg.d_model
    dh = d // h
    ks = jax.random.split(key, 10)
    blk = lambda kk: L._dense_init(kk, (h, dh, dh), dtype)
    return {
        "ln": L.init_norm(cfg, dtype),
        "wz": L._dense_init(ks[0], (d, d), dtype), "rz": blk(ks[1]),
        "wi": L._dense_init(ks[2], (d, h), dtype), "ri": L._dense_init(ks[3], (h, dh), dtype),
        "wf": L._dense_init(ks[4], (d, h), dtype), "rf": L._dense_init(ks[5], (h, dh), dtype),
        "wo": L._dense_init(ks[6], (d, d), dtype), "ro": blk(ks[7]),
        "bi": jnp.zeros((h,), dtype), "bf": jnp.full((h,), 3.0, dtype),
        "gn": jnp.ones((d,), dtype),
        "ff_up": L._dense_init(ks[8], (d, 2 * d), dtype),
        "ff_down": L._dense_init(ks[9], (d, d), dtype),
    }


def _slstm_step(p, carry, xt, cfg, h_heads, dh):
    """One sLSTM time step.  carry: (c [B,H,dh], n [B,H,dh], m [B,H],
    hprev [B,d]).  xt [B,d]."""
    f32 = jnp.float32
    c, n, m, hprev = carry
    hp = hprev.reshape(-1, h_heads, dh)
    z = jnp.tanh((xt @ p["wz"].astype(xt.dtype)).astype(f32).reshape(-1, h_heads, dh)
                 + jnp.einsum("bhd,hde->bhe", hp.astype(f32), p["rz"].astype(f32)))
    ilog = (xt @ p["wi"].astype(xt.dtype)).astype(f32) + p["bi"].astype(f32) \
        + jnp.einsum("bhd,hd->bh", hp.astype(f32), p["ri"].astype(f32))
    flog = (xt @ p["wf"].astype(xt.dtype)).astype(f32) + p["bf"].astype(f32) \
        + jnp.einsum("bhd,hd->bh", hp.astype(f32), p["rf"].astype(f32))
    flog = jax.nn.log_sigmoid(flog)
    o = jax.nn.sigmoid((xt @ p["wo"].astype(xt.dtype)).astype(f32).reshape(-1, h_heads, dh)
                       + jnp.einsum("bhd,hde->bhe", hp.astype(f32), p["ro"].astype(f32)))
    m_new = jnp.maximum(flog + m, ilog)
    i = jnp.exp(ilog - m_new)[..., None]
    f = jnp.exp(flog + m - m_new)[..., None]
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    h_flat = h_new.reshape(h_new.shape[0], -1)
    return (c_new, n_new, m_new, h_flat.astype(xt.dtype)), h_flat


def slstm_apply(p, x, cfg: ModelConfig, *, state=None):
    xcfg, _, _, _ = _dims(cfg)
    h_heads = xcfg.n_heads
    d = cfg.d_model
    dh = d // h_heads
    b, s, _ = x.shape
    res = x
    xn = L.norm_apply(p["ln"], x, cfg)
    f32 = jnp.float32
    st = state if state is not None else (
        jnp.zeros((b, h_heads, dh), f32), jnp.zeros((b, h_heads, dh), f32),
        jnp.full((b, h_heads), -1e30, f32), jnp.zeros((b, d), x.dtype),
    )

    def step(carry, xt):
        return _slstm_step(p, carry, xt, cfg, h_heads, dh)

    new_state, hs = lax.scan(step, st, xn.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)           # [B,S,d]
    hs = L._rms(hs, p["gn"], cfg.norm_eps)
    x = res + hs
    # gated FF
    up = x @ p["ff_up"].astype(x.dtype)
    a, g = up[..., :d], up[..., d:]
    x = x + (a * jax.nn.silu(g)) @ p["ff_down"].astype(x.dtype)
    return pctx.constrain_acts(x), \
        (new_state if state is not None else None)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    assert cfg.n_layers % 2 == 0
    pairs = cfg.n_layers // 2
    ke, ks_, km = jax.random.split(key, 3)
    skeys = jax.random.split(ks_, pairs)
    mkeys = jax.random.split(km, pairs)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "slstm": jax.vmap(lambda k: init_slstm(k, cfg, dtype))(skeys),
        "mlstm": jax.vmap(lambda k: init_mlstm(k, cfg, dtype))(mkeys),
        "final_norm": L.init_norm(cfg, dtype),
    }


def forward(params, tokens, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            cache=None, cache_index=None, remat="full"):
    b, s = tokens.shape
    x = L.embed_apply(params["embed"], tokens, cfg, compute_dtype)
    x = pctx.constrain_acts(x)

    def pair_body(xc, inp):
        sp, mp, scache, mcache = inp
        xc, new_s = slstm_apply(sp, xc, cfg, state=scache)
        xc, new_m = mlstm_apply(mp, xc, cfg, state=mcache)
        return xc, (new_s, new_m)

    if remat == "full":
        pair_body = jax.checkpoint(pair_body)
    scache = None if cache is None else cache["slstm"]
    mcache = None if cache is None else cache["mlstm"]
    x, (new_s, new_m) = lax.scan(
        pair_body, x, (params["slstm"], params["mlstm"], scache, mcache))
    new_cache = None if cache is None else {"slstm": new_s, "mlstm": new_m}
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, new_cache, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    del max_seq  # recurrent state is O(1) in sequence length
    xcfg, d_i, h, dh = _dims(cfg)
    pairs = cfg.n_layers // 2
    d = cfg.d_model
    dhs = d // xcfg.n_heads
    f32 = jnp.float32
    return {
        "slstm": (
            jnp.zeros((pairs, batch, xcfg.n_heads, dhs), f32),
            jnp.zeros((pairs, batch, xcfg.n_heads, dhs), f32),
            jnp.full((pairs, batch, xcfg.n_heads), -1e30, f32),
            jnp.zeros((pairs, batch, d), dtype),
        ),
        "mlstm": (
            jnp.zeros((pairs, batch, h, dh, dh), f32),
            jnp.zeros((pairs, batch, h, dh), f32),
            jnp.full((pairs, batch, h), -1e30, f32),
        ),
    }


def loss_fn(params, batch, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            remat="full"):
    hidden, _, _ = forward(params, batch["tokens"], cfg,
                           compute_dtype=compute_dtype, remat=remat)
    logits = L.unembed_apply(params["embed"], hidden, cfg)
    loss = L.masked_xent(logits, batch["labels"])
    return loss, {"nll": loss}


def prefill(params, tokens, cfg: ModelConfig, cache, *, compute_dtype=jnp.bfloat16):
    hidden, new_cache, _ = forward(params, tokens, cfg, compute_dtype=compute_dtype,
                                   cache=cache, cache_index=0, remat="none")
    logits = L.unembed_apply(params["embed"], hidden[:, -1:], cfg)
    return logits[:, 0], new_cache


def decode_step(params, token, pos, cfg: ModelConfig, cache, *,
                compute_dtype=jnp.bfloat16):
    hidden, new_cache, _ = forward(params, token[:, None], cfg,
                                   compute_dtype=compute_dtype,
                                   cache=cache, cache_index=pos, remat="none")
    logits = L.unembed_apply(params["embed"], hidden, cfg)
    return logits[:, 0], new_cache
