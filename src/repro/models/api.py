"""Unified model API: family dispatch + input specs for every (arch, shape).

``get_api(cfg)`` returns a ``ModelAPI`` whose five functions share signatures
across families, so the trainer / server / dry-run never branch on family.

``input_specs(cfg, shape, ...)`` builds jax.ShapeDtypeStruct stand-ins for
every input of the lowered step — tokens, labels, frontend-stub embeddings,
decode caches — without allocating anything (the dry-run contract).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec, ssm, transformer, xlstm


@dataclass(frozen=True)
class ModelAPI:
    init: Callable[..., Any]            # (key, dtype) -> params
    loss: Callable[..., Any]            # (params, batch) -> (loss, metrics)
    prefill: Callable[..., Any]         # (params, batch, cache) -> (logits, cache)
    decode: Callable[..., Any]          # (params, token, pos, cache) -> (logits, cache)
    init_cache: Callable[..., Any]      # (batch, max_seq, dtype) -> cache


def get_api(cfg: ModelConfig, compute_dtype=jnp.bfloat16, remat: str = "full") -> ModelAPI:
    if cfg.family in ("decoder", "moe", "vlm"):
        mod = transformer
        window = cfg.sliding_window

        def loss(params, batch):
            return mod.loss_fn(params, batch, cfg, compute_dtype=compute_dtype,
                               remat=remat)

        def prefill(params, batch, cache):
            return mod.prefill(params, batch["tokens"], cfg, cache,
                               compute_dtype=compute_dtype,
                               patch_embeds=batch.get("patch_embeds"),
                               window=window)

        def decode(params, token, pos, cache):
            return mod.decode_step(params, token, pos, cfg, cache,
                                   compute_dtype=compute_dtype, window=window)

        return ModelAPI(
            init=lambda key, dtype=jnp.float32: mod.init_params(key, cfg, dtype),
            loss=loss,
            prefill=prefill,
            decode=decode,
            init_cache=lambda b, s, dtype=jnp.bfloat16: mod.init_cache(cfg, b, s, dtype),
        )
    if cfg.family == "hybrid":
        mod = ssm
    elif cfg.family == "xlstm":
        mod = xlstm
    elif cfg.family == "encdec":
        mod = encdec

        def loss_ed(params, batch):
            return mod.loss_fn(params, batch, cfg, compute_dtype=compute_dtype,
                               remat=remat)

        def prefill_ed(params, batch, cache):
            return mod.prefill(params, batch["tokens"], cfg, cache,
                               frames=batch["frames"], compute_dtype=compute_dtype)

        def decode_ed(params, token, pos, cache):
            return mod.decode_step(params, token, pos, cfg, cache,
                                   compute_dtype=compute_dtype)

        return ModelAPI(
            init=lambda key, dtype=jnp.float32: mod.init_params(key, cfg, dtype),
            loss=loss_ed,
            prefill=prefill_ed,
            decode=decode_ed,
            init_cache=lambda b, s, dtype=jnp.bfloat16: mod.init_cache(cfg, b, s, dtype),
        )
    else:
        raise ValueError(f"unknown family {cfg.family}")

    # hybrid / xlstm share the plain-LM signature
    def loss_lm(params, batch):
        return mod.loss_fn(params, batch, cfg, compute_dtype=compute_dtype,
                           remat=remat)

    def prefill_lm(params, batch, cache):
        return mod.prefill(params, batch["tokens"], cfg, cache,
                           compute_dtype=compute_dtype)

    def decode_lm(params, token, pos, cache):
        return mod.decode_step(params, token, pos, cfg, cache,
                               compute_dtype=compute_dtype)

    return ModelAPI(
        init=lambda key, dtype=jnp.float32: mod.init_params(key, cfg, dtype),
        loss=loss_lm,
        prefill=prefill_lm,
        decode=decode_lm,
        init_cache=lambda b, s, dtype=jnp.bfloat16: mod.init_cache(cfg, b, s, dtype),
    )


# ---------------------------------------------------------------------------
# input specs (dry-run contract: ShapeDtypeStruct only, no allocation)
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "patch_embed":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
    return specs


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """Abstract cache pytree via eval_shape (no allocation)."""
    api = get_api(cfg)
    extra = cfg.frontend_seq if cfg.frontend == "patch_embed" else 0
    return jax.eval_shape(partial(api.init_cache, shape.global_batch,
                                  shape.seq_len + extra, dtype))


def param_specs(cfg: ModelConfig, dtype=jnp.float32):
    """Abstract params pytree via eval_shape (no allocation)."""
    api = get_api(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: api.init(k, dtype), key)


def param_count(cfg: ModelConfig) -> int:
    import math

    specs = param_specs(cfg)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(specs))
