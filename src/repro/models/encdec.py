"""Encoder-decoder transformer (Whisper-style).

Encoder: bidirectional attention over stubbed audio-frame embeddings
([B, 1500, d] — the conv frontend is a stub per the assignment).
Decoder: causal self-attention (KV-cached) + cross-attention whose K/V are
computed once from the encoder output at prefill and reused every decode
step.  LayerNorm + learned positions + plain-GELU MLPs per Whisper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel import context as pctx
from . import layers as L


def init_enc_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(k2, cfg, dtype),
    }


def init_dec_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "self_attn": L.init_attention(k1, cfg, dtype),
        "ln_x": L.init_norm(cfg, dtype),
        "cross_attn": L.init_attention(k2, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(k3, cfg, dtype),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ke, kenc, kdec, kp = jax.random.split(key, 4)
    ekeys = jax.random.split(kenc, cfg.encoder_layers)
    dkeys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "enc_pos": L._dense_init(kp, (cfg.encoder_seq, cfg.d_model), dtype),
        "encoder": jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(ekeys),
        "enc_norm": L.init_norm(cfg, dtype),
        "decoder": jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(dkeys),
        "final_norm": L.init_norm(cfg, dtype),
    }


def encode(params, frames, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
           remat="full"):
    """frames: [B, enc_seq, d] stub embeddings -> encoder hidden states."""
    b, s, _ = frames.shape
    x = frames.astype(compute_dtype) + params["enc_pos"][None, :s].astype(compute_dtype)
    x = pctx.constrain_acts(x)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)

    def body(xc, lp):
        h = L.norm_apply(lp["ln1"], xc, cfg)
        a, _ = L.attention_apply(lp["attn"], h, cfg, positions, causal=False)
        xc = xc + a
        h = L.norm_apply(lp["ln2"], xc, cfg)
        xc = xc + L.mlp_apply(lp["mlp"], h, cfg)
        return pctx.constrain_acts(xc), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["encoder"])
    return L.norm_apply(params["enc_norm"], x, cfg)


def cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute per-decoder-layer cross-attention K/V from encoder output:
    leaves [L, B, enc_seq, K, hd]."""
    hd = cfg.resolved_head_dim

    def one(lp):
        k = L._proj(enc_out, lp["cross_attn"]["wk"], lp["cross_attn"].get("bk"))
        v = L._proj(enc_out, lp["cross_attn"]["wv"], lp["cross_attn"].get("bv"))
        b, s, _ = enc_out.shape
        return (k.reshape(b, s, cfg.n_kv_heads, hd),
                v.reshape(b, s, cfg.n_kv_heads, hd))

    return jax.vmap(one)(params["decoder"])


def decode_forward(params, tokens, cfg: ModelConfig, xkv, *,
                   compute_dtype=jnp.bfloat16, cache=None, cache_index=None,
                   remat="full"):
    """Decoder stack.  xkv: stacked cross K/V.  cache: self-attn KV stack."""
    b, s = tokens.shape
    base_pos = 0 if cache_index is None else cache_index
    positions = base_pos + jnp.arange(s)[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (b, s))
    x = L.embed_apply(params["embed"], tokens, cfg, compute_dtype,
                      positions=jnp.minimum(positions, cfg.learned_pos_max - 1))
    x = pctx.constrain_acts(x)

    def body(xc, inp):
        lp, (xk, xv), lcache = inp
        h = L.norm_apply(lp["ln1"], xc, cfg)
        a, ncache = L.attention_apply(lp["self_attn"], h, cfg, positions,
                                      causal=True, cache=lcache,
                                      cache_index=cache_index)
        xc = xc + a
        h = L.norm_apply(lp["ln_x"], xc, cfg)
        a, _ = L.attention_apply(lp["cross_attn"], h, cfg, positions,
                                 causal=False,
                                 kv_override=(xk.astype(compute_dtype),
                                              xv.astype(compute_dtype)))
        xc = xc + a
        h = L.norm_apply(lp["ln2"], xc, cfg)
        xc = xc + L.mlp_apply(lp["mlp"], h, cfg)
        return pctx.constrain_acts(xc), ncache

    if remat == "full":
        body = jax.checkpoint(body)
    x, new_cache = lax.scan(body, x, (params["decoder"], xkv, cache))
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, (new_cache if cache is not None else None)


def loss_fn(params, batch, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            remat="full"):
    """batch: frames [B,enc_seq,d], tokens [B,S], labels [B,S]."""
    enc = encode(params, batch["frames"], cfg, compute_dtype=compute_dtype,
                 remat=remat)
    xkv = cross_kv(params, enc, cfg)
    hidden, _ = decode_forward(params, batch["tokens"], cfg, xkv,
                               compute_dtype=compute_dtype, remat=remat)
    logits = L.unembed_apply(params["embed"], hidden, cfg)
    loss = L.masked_xent(logits, batch["labels"])
    return loss, {"nll": loss}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    return {
        "self": {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        },
        "cross": (
            jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
            jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
        ),
    }


def prefill(params, tokens, cfg: ModelConfig, cache, *, frames=None,
            compute_dtype=jnp.bfloat16):
    enc = encode(params, frames, cfg, compute_dtype=compute_dtype, remat="none")
    xkv = jax.tree.map(lambda a, proto: a.astype(proto.dtype),
                       cross_kv(params, enc, cfg), cache["cross"])
    hidden, new_self = decode_forward(params, tokens, cfg, xkv,
                                      compute_dtype=compute_dtype,
                                      cache=cache["self"], cache_index=0,
                                      remat="none")
    logits = L.unembed_apply(params["embed"], hidden[:, -1:], cfg)
    return logits[:, 0], {"self": new_self, "cross": xkv}


def decode_step(params, token, pos, cfg: ModelConfig, cache, *,
                compute_dtype=jnp.bfloat16):
    hidden, new_self = decode_forward(params, token[:, None], cfg, cache["cross"],
                                      compute_dtype=compute_dtype,
                                      cache=cache["self"], cache_index=pos,
                                      remat="none")
    logits = L.unembed_apply(params["embed"], hidden, cfg)
    return logits[:, 0], {"self": new_self, "cross": cache["cross"]}
