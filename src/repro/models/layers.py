"""Shared neural layers (pure functional JAX — params are nested dicts).

Conventions:
  * ``init_*`` returns a params pytree; ``*_apply`` consumes it.
  * activations flow in ``cdt`` (compute dtype, usually bf16); params are
    stored in the config's param dtype and cast at use.
  * attention tensors use [batch, seq, heads, head_dim] at rest and
    [batch, heads, seq, head_dim] inside kernels.
  * every sequence-quadratic op goes through :func:`blocked_attention`
    (online-softmax flash pattern) so the 32k prefill shapes never
    materialize an S×S score matrix.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

Init = jax.nn.initializers.normal


def _dense_init(key, shape, dtype, scale=0.02):
    return Init(scale)(key, shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def norm_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blocked (flash-pattern) attention — pure jnp oracle of kernels/flash_attention
# ---------------------------------------------------------------------------

def blocked_attention(
    q: jax.Array,              # [B, Sq, H, D]
    k: jax.Array,              # [B, Skv, K, D]
    v: jax.Array,              # [B, Skv, K, Dv]
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: int | None = None,
    scale: float | None = None,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax attention; never materializes [Sq, Skv].

    GQA: H = K * G handled by folding the group into the batch of the
    einsum.  Peak live intermediate: [B, H, q_block, kv_block].
    """
    b, sq, h, d = q.shape
    skv, kh, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qb = min(q_block, sq)
    kvb = min(kv_block, skv)
    pad_q = (-sq) % qb
    pad_kv = (-skv) % kvb
    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kf = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else k
    vf = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else v
    nq, nkv = qf.shape[1] // qb, kf.shape[1] // kvb

    # [nq, B, K, G, qb, D] / [nkv, B, K, kvb, D]
    qs = qf.reshape(b, nq, qb, kh, g, d).transpose(1, 0, 3, 4, 2, 5)
    ks = kf.reshape(b, nkv, kvb, kh, d).transpose(1, 0, 3, 2, 4)
    vs = vf.reshape(b, nkv, kvb, kh, dv).transpose(1, 0, 3, 2, 4)

    kv_pos = jnp.arange(nkv * kvb).reshape(nkv, kvb)

    def q_block_fn(args):
        qi, qblk = args                      # qblk [B, K, G, qb, D]
        q_pos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp           # [B,K,kvb,D], [B,K,kvb,Dv], [kvb]
            s = jnp.einsum(
                "bkgqd,bksd->bkgqs", qblk.astype(jnp.float32),
                kblk.astype(jnp.float32),
            ) * scale
            mask = jnp.ones((qb, kvb), bool)
            if causal:
                mask &= q_pos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows (padded tail): keep m finite
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qb), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qb, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (ks, vs, kv_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                            # [B, K, G, qb, Dv]

    outs = lax.map(q_block_fn, (jnp.arange(nq), qs))   # [nq, B, K, G, qb, Dv]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qb, h, dv)
    if pad_q:
        out = out[:, :sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,              # [B, 1, H, D]
    k_cache: jax.Array,        # [B, S, K, D]
    v_cache: jax.Array,        # [B, S, K, Dv]
    length: jax.Array | int,   # valid prefix length (scalar or [B])
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-token attention against a KV cache: [B,H,S] scores, no S×S."""
    b, _, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qh = q.reshape(b, kh, g, d)
    # preferred_element_type keeps the accumulation in f32 WITHOUT
    # materializing an f32 copy of the whole cache (measured 2×6.4 GiB/device
    # on the 67B decode cell — see §Perf hypothesis log)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qh, k_cache.astype(qh.dtype),
        preferred_element_type=jnp.float32,
    ) * scale
    pos = jnp.arange(s)
    larr = jnp.asarray(length)
    if larr.ndim == 0:
        valid = (pos < larr)[None, None, None, :]
        if window is not None:
            valid = jnp.logical_and(valid, (pos >= larr - window)[None, None, None, :])
    else:
        valid = (pos[None, :] < larr[:, None])[:, None, None, :]
        if window is not None:
            valid = jnp.logical_and(
                valid, (pos[None, :] >= larr[:, None] - window)[:, None, None, :])
    scores = jnp.where(valid, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# standard GQA attention layer (with optional cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), dtype),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), dtype),
        "wo": _dense_init(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _proj(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def attention_apply(
    p: dict,
    x: jax.Array,                # [B, S, d]
    cfg: ModelConfig,
    positions: jax.Array,        # [B, S]
    *,
    causal: bool = True,
    window: int | None = None,
    kv_override: tuple | None = None,   # cross-attention: (k, v) precomputed
    cache: dict | None = None,          # {"k","v"} [B, S_max, K, hd]
    cache_index: jax.Array | int | None = None,
) -> tuple[jax.Array, dict | None]:
    from repro.parallel import context as pctx

    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(b, s, cfg.n_heads, hd)
    # settle attention layouts ONCE per layer: q sharded over heads ('model'),
    # kv replicated over 'model' when kv-heads don't divide it — otherwise
    # GSPMD re-shards per kv block inside the scan (measured 6.4 GB/layer of
    # all-reduce on the 67B prefill cell; §Perf iteration 11)
    q = pctx.constrain(q, pctx.BATCH, None, pctx.MODEL, None)
    if kv_override is None:
        k = _proj(x, p["wk"], p.get("bk")).reshape(b, s, cfg.n_kv_heads, hd)
        v = _proj(x, p["wv"], p.get("bv")).reshape(b, s, cfg.n_kv_heads, hd)
        if cfg.pos_embed == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        kv_spec = pctx.MODEL if cfg.n_kv_heads % pctx.model_axis_size() == 0 else None
        k = pctx.constrain(k, pctx.BATCH, None, kv_spec, None)
        v = pctx.constrain(v, pctx.BATCH, None, kv_spec, None)
    else:
        k, v = kv_override

    new_cache = None
    if cache is not None and kv_override is None:
        kc = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                             cache_index, axis=1)
        vc = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                             cache_index, axis=1)
        new_cache = {"k": kc, "v": vc}
        if s == 1:
            out = decode_attention(q, kc, vc, cache_index + 1, window=window)
        else:
            out = blocked_attention(q, kc[:, : cache_index + s], vc[:, : cache_index + s],
                                    causal=causal, q_offset=cache_index, window=window)
    else:
        out = blocked_attention(q, k, v, causal=causal, window=window)
    y = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    m: MLAConfig = cfg.mla
    h = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": _dense_init(ks[0], (cfg.d_model, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": _dense_init(ks[1], (m.q_lora_rank, h * qk), dtype),
        "wkv_a": _dense_init(ks[2], (cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": _dense_init(ks[3], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)), dtype),
        "wo": _dense_init(ks[4], (h * m.v_head_dim, cfg.d_model), dtype),
    }


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def mla_compress(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """Produce the compressed KV the cache stores: c_kv [B,S,r], k_rope [B,S,1,dr]."""
    m: MLAConfig = cfg.mla
    kv_a = _proj(x, p["wkv_a"])
    c_kv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    c_kv = _rms(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_expand_kv(p: dict, c_kv: jax.Array, cfg: ModelConfig):
    """Decompress cached latents into per-head K_nope and V."""
    m: MLAConfig = cfg.mla
    h = cfg.n_heads
    kv = _proj(c_kv, p["wkv_b"]).reshape(*c_kv.shape[:-1], h, m.qk_nope_head_dim + m.v_head_dim)
    return kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]


def mla_queries(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    m: MLAConfig = cfg.mla
    h = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = _proj(_rms(_proj(x, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(*x.shape[:-1], h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache: dict | None = None,         # {"c_kv": [B,Smax,r], "k_rope": [B,Smax,1,dr]}
    cache_index: jax.Array | int | None = None,
) -> tuple[jax.Array, dict | None]:
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = mla_queries(p, x, cfg, positions)
    c_kv, k_rope = mla_compress(p, x, cfg, positions)

    new_cache = None
    if cache is not None:
        ckv_c = lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache_index, axis=1)
        krope_c = lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), cache_index, axis=1)
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c}
        if s == 1:
            # decode: traced position -> keep the full cache, mask by length
            c_kv_all, k_rope_all = ckv_c, krope_c
        else:
            upto = cache_index + s  # prefill: static start (0)
            c_kv_all, k_rope_all = ckv_c[:, :upto], krope_c[:, :upto]
    else:
        c_kv_all, k_rope_all = c_kv, k_rope

    if s == 1 and cache is not None:
        # ---- absorbed decode (MLA's raison d'etre): score & combine in the
        # r-dim latent space; per-head K/V are never materialized over the
        # cache.  w_kv_b is folded into the query / output projections.
        h = cfg.n_heads
        w_b = p["wkv_b"].astype(x.dtype).reshape(
            m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
        w_k, w_v = w_b[..., : m.qk_nope_head_dim], w_b[..., m.qk_nope_head_dim:]
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_k)      # [B,H,r]
        s_lat = jnp.einsum("bhr,bsr->bhs", q_lat.astype(c_kv_all.dtype),
                           c_kv_all, preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bhd,bsd->bhs",
                            q_rope[:, 0].astype(k_rope_all.dtype),
                            k_rope_all[:, :, 0],
                            preferred_element_type=jnp.float32)
        scores = (s_lat + s_rope) * scale                          # [B,H,Smax]
        length = cache_index + 1
        valid = jnp.arange(scores.shape[-1])[None, None, :] < length
        scores = jnp.where(valid, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1).astype(c_kv_all.dtype)
        lat = jnp.einsum("bhs,bsr->bhr", probs, c_kv_all,
                         preferred_element_type=jnp.float32).astype(x.dtype)
        out = jnp.einsum("bhr,rhd->bhd", lat, w_v)[:, None]        # [B,1,H,dv]
    else:
        k_nope, v = mla_expand_kv(p, c_kv_all, cfg)     # [B,Skv,H,dn], [B,Skv,H,dv]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_all, (*k_nope.shape[:-1], m.qk_rope_head_dim))],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = blocked_attention(q_full, k_full, v, causal=True, scale=scale,
                                q_offset=0 if cache_index is None else cache_index)
    y = out.reshape(b, s, cfg.n_heads * m.v_head_dim) @ p["wo"].astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    ff = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":  # plain 2-matrix MLP (whisper)
        return {
            "w_up": _dense_init(ks[0], (cfg.d_model, ff), dtype),
            "b_up": jnp.zeros((ff,), dtype),
            "w_down": _dense_init(ks[1], (ff, cfg.d_model), dtype),
            "b_down": jnp.zeros((cfg.d_model,), dtype),
        }
    return {  # gated (swiglu / geglu)
        "w_gate": _dense_init(ks[0], (cfg.d_model, ff), dtype),
        "w_up": _dense_init(ks[1], (cfg.d_model, ff), dtype),
        "w_down": _dense_init(ks[2], (ff, cfg.d_model), dtype),
    }


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.act == "gelu":
        h = jax.nn.gelu(_proj(x, p["w_up"], p["b_up"]))
        return _proj(h, p["w_down"], p["b_down"])
    gate = _proj(x, p["w_gate"])
    gate = jax.nn.gelu(gate) if cfg.act == "geglu" else jax.nn.silu(gate)
    return _proj(gate * _proj(x, p["w_up"]), p["w_down"])


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dropless-with-capacity dispatch; EP-shardable)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    mo: MoEConfig = cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (cfg.d_model, mo.n_experts), dtype),
        # stacked expert weights: [E, d, ff] / [E, ff, d] — EP shards dim 0
        "w_gate": _dense_init(ks[1], (mo.n_experts, cfg.d_model, mo.d_expert), dtype),
        "w_up": _dense_init(ks[2], (mo.n_experts, cfg.d_model, mo.d_expert), dtype),
        "w_down": _dense_init(ks[3], (mo.n_experts, mo.d_expert, cfg.d_model), dtype),
    }
    if mo.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, dtype, d_ff=mo.d_expert * mo.n_shared)
    return p


def _moe_groups(t: int) -> int:
    """Dispatch-group count: one group per DP shard (GShard-style), so every
    sort/gather/scatter keeps a leading sharded batch dim and stays local."""
    from repro.parallel import context as pctx

    mesh = pctx.get_mesh()
    if mesh is None:
        return 1
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    return g if g > 0 and t % g == 0 else 1


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  Grouped sort-based dispatch (GShard-style):

      tokens reshaped to [G, T_g] with G sharded over the DP axes -> per-group
      top-k -> per-group sort by expert -> position-in-expert -> scatter into
      [G, E, C_g, d] slots (per-group capacity, overflow dropped) -> expert
      FFN einsum contracted over d with E sharded over 'model' (EP) -> gather
      back with routing weights.

    Every gather/scatter carries the G batch dim, so GSPMD keeps dispatch
    local per data shard; the [G, E, C, *] buffers are 2-D sharded
    (data × model).  A globally-sorted variant was measured 20+ GiB/device
    worse (see EXPERIMENTS.md §Perf, hypothesis log).
    """
    from repro.parallel import context as pctx

    mo: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    g = _moe_groups(t)
    tg = t // g
    e, k = mo.n_experts, mo.top_k
    xt = pctx.constrain(x.reshape(g, tg, d), pctx.BATCH, None, None)

    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)   # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, eids = lax.top_k(probs, k)                               # [G,Tg,k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style, computed over all tokens)
    gi = jnp.arange(g)[:, None]
    density = jnp.zeros((g, e), jnp.float32).at[
        jnp.broadcast_to(gi[..., None], eids.shape), eids].add(1.0)
    density = density.sum(0) / (t * k)
    router_prob = probs.mean((0, 1))
    aux = e * jnp.sum(density * router_prob) * mo.router_aux_weight

    cap = int(mo.capacity_factor * k * tg / e) + 1                    # C per (group, expert)
    tgk = tg * k

    # ---- gather-only dispatch.  The obvious scatter formulation
    # (slot_buf.at[g, e, c].set(tokens)) makes GSPMD's scatter partitioner
    # replicate both operands with full-size all-reduces (+95 GiB/device on
    # the 236B cell, see the §Perf hypothesis log); with the sort, every
    # expert's entries are a contiguous range, so slots can be *gathered*.
    flat_e = eids.reshape(g, tgk)                                     # [G,Tg*k]
    order = jnp.argsort(flat_e, axis=-1)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    inv_order = jnp.argsort(order, axis=-1)                           # entry -> sorted pos
    counts = jnp.zeros((g, e), jnp.int32).at[
        jnp.broadcast_to(gi, flat_e.shape), flat_e].add(1)            # tiny scatter
    seg_start = jnp.cumsum(counts, axis=-1) - counts                  # [G,E]

    # slot (e, c) reads sorted position seg_start[e] + c while c < counts[e]
    slot_src = seg_start[..., None] + jnp.arange(cap)[None, None]     # [G,E,C]
    slot_valid = jnp.arange(cap)[None, None] < counts[..., None]
    slot_src = jnp.clip(slot_src, 0, tgk - 1).reshape(g, e * cap)
    tok_of = order // k                                               # [G,Tg*k]
    slot_tok = jnp.take_along_axis(tok_of, slot_src, axis=1)          # [G,E*C]
    xs = jnp.take_along_axis(xt, slot_tok[..., None], axis=1)         # [G,E*C,d]
    slot_buf = jnp.where(slot_valid.reshape(g, e * cap, 1), xs, 0)
    slot_buf = slot_buf.reshape(g, e, cap, d)
    slot_buf = pctx.constrain(slot_buf, pctx.BATCH, pctx.MODEL, None, None)

    # expert FFN: [G,E,C,d] x [E,d,f] -> [G,E,C,f]; d contracted, E sharded
    h_g = jnp.einsum("gecd,edf->gecf", slot_buf, p["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("gecd,edf->gecf", slot_buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    # replicate the (small) expert outputs over 'model' for the local
    # combine-gather — this reshard is the EP "return" all-to-all
    y_e = pctx.constrain(y_e, pctx.BATCH, None, None, None)

    # combine: entry j (sorted) lives at flat slot sorted_e*C + pos; dropped
    # entries (pos >= C) are masked.  Un-sort via the inverse permutation and
    # fold k back into the token dim with a reshape+sum — no scatter.
    pos_in_e = jnp.arange(tgk)[None] - jnp.take_along_axis(
        seg_start, sorted_e, axis=-1)                                 # [G,Tg*k]
    dropped = pos_in_e >= cap
    slot_of = sorted_e * cap + jnp.clip(pos_in_e, 0, cap - 1)
    y_sorted = jnp.take_along_axis(
        y_e.reshape(g, e * cap, d), slot_of[..., None], axis=1)
    y_sorted = jnp.where(dropped[..., None], 0, y_sorted)
    y_entries = jnp.take_along_axis(y_sorted, inv_order[..., None], axis=1)
    contrib = y_entries * weights.reshape(g, tgk)[..., None].astype(x.dtype)
    out = contrib.reshape(g, tg, k, d).sum(axis=2)                    # [G,Tg,d]
    out = pctx.constrain(out, pctx.BATCH, None, None)

    if mo.n_shared:
        out = out + mlp_apply(p["shared"], xt, cfg)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"tok": _dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype, scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ks[1], (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.pos_embed == "learned":
        p["pos"] = _dense_init(ks[2], (cfg.learned_pos_max, cfg.d_model), dtype)
    return p


def embed_apply(p: dict, tokens: jax.Array, cfg: ModelConfig, dtype,
                positions: jax.Array | None = None) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(dtype)
    if cfg.name.startswith("gemma"):
        x = x * math.sqrt(cfg.d_model)
    if cfg.pos_embed == "learned" and positions is not None:
        x = x + jnp.take(p["pos"], positions, axis=0).astype(dtype)
    return x


def unembed_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ p["tok"].T.astype(x.dtype)
    else:
        logits = x @ p["unembed"].astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:  # mask padding rows out of softmax
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e9, logits.dtype), logits)
    return logits


def masked_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross entropy over labels >= 0.

    Uses the one-hot/logsumexp formulation rather than take_along_axis: the
    vocab dim stays 'model'-sharded end to end (a vocab gather makes GSPMD
    replicate the [B,S,V] logits — measured at +45 GiB/device on the 236B
    train cell; see EXPERIMENTS.md §Perf hypothesis log)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    oh = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.sum(oh * logits, axis=-1)
    nll = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
