"""Decoder-only transformer family.

Covers: deepseek-67b, qwen2-1.5b, qwen1.5-4b, gemma-7b (dense decoders),
internvl2-1b (decoder + patch-embedding stub prepended), granite-moe and
deepseek-v2-236b (MoE decoders, the latter with MLA attention and
first-k-dense layers).

Layers are scan-stacked: every layer's params live in one pytree whose
leaves carry a leading [L] axis, and the forward pass is a single
``lax.scan`` — keeps the HLO size O(1) in depth (95-layer deepseek-67b
compiles as fast as 2 layers) and is the shape MaxText-class frameworks use.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel import context as pctx
from . import layers as L


def _use_mla(cfg: ModelConfig) -> bool:
    return cfg.mla is not None


def _use_moe(cfg: ModelConfig, layer_is_dense: bool) -> bool:
    return cfg.moe is not None and not layer_is_dense


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, dtype, dense: bool = False) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg, dtype), "ln2": L.init_norm(cfg, dtype)}
    if _use_mla(cfg):
        p["attn"] = L.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = L.init_attention(k1, cfg, dtype)
    if _use_moe(cfg, dense):
        p["moe"] = L.init_moe(k2, cfg, dtype)
    else:
        d_ff = cfg.dense_d_ff if (dense and cfg.dense_d_ff) else (cfg.d_ff or cfg.dense_d_ff)
        p["mlp"] = L.init_mlp(k2, cfg, dtype, d_ff=d_ff)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ke, kl, kd, kv = jax.random.split(key, 4)
    n_scan = cfg.n_layers - cfg.first_k_dense
    layer_keys = jax.random.split(kl, n_scan)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    p = {
        "embed": L.init_embed(ke, cfg, dtype),
        "layers": stacked,
        "final_norm": L.init_norm(cfg, dtype),
    }
    if cfg.first_k_dense:
        dks = jax.random.split(kd, cfg.first_k_dense)
        p["dense_layers"] = [init_layer(k, cfg, dtype, dense=True) for k in dks]
    if cfg.frontend == "patch_embed":
        # projection from the (stubbed) vision tower's hidden to d_model
        p["patch_proj"] = L._dense_init(kv, (cfg.d_model, cfg.d_model), dtype)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_fwd(p, x, cfg, positions, *, cache=None, cache_index=None,
               window=None, dense=False):
    h = L.norm_apply(p["ln1"], x, cfg)
    if _use_mla(cfg):
        a, new_cache = L.mla_apply(p["attn"], h, cfg, positions,
                                   cache=cache, cache_index=cache_index)
    else:
        a, new_cache = L.attention_apply(p["attn"], h, cfg, positions,
                                         causal=True, window=window,
                                         cache=cache, cache_index=cache_index)
    x = x + a
    h = L.norm_apply(p["ln2"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if _use_moe(cfg, dense):
        m, aux = L.moe_apply(p["moe"], h, cfg)
    else:
        m = L.mlp_apply(p["mlp"], h, cfg)
    x = x + m
    x = pctx.constrain_acts(x)
    return x, new_cache, aux


def forward(
    params: dict,
    tokens: jax.Array,            # [B, S]
    cfg: ModelConfig,
    *,
    compute_dtype=jnp.bfloat16,
    patch_embeds: jax.Array | None = None,   # [B, P, d] (vlm stub)
    cache: dict | None = None,    # stacked caches {"k": [L,B,Smax,K,hd], ...}
    cache_index: int | jax.Array | None = None,
    remat: str = "full",
    window: int | None = None,
) -> tuple[jax.Array, dict | None, jax.Array]:
    """Returns (hidden [B,S,d], new_cache | None, aux_loss)."""
    b, s = tokens.shape
    base_pos = 0 if cache_index is None else cache_index
    x = L.embed_apply(params["embed"], tokens, cfg, compute_dtype)

    if patch_embeds is not None:
        pe = patch_embeds.astype(compute_dtype) @ params["patch_proj"].astype(compute_dtype)
        x = jnp.concatenate([pe, x], axis=1)
        s = x.shape[1]
    positions = base_pos + jnp.arange(s)[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (b, s))
    if cfg.pos_embed == "learned":
        x = x + jnp.take(params["embed"]["pos"],
                         jnp.minimum(positions, cfg.learned_pos_max - 1),
                         axis=0).astype(compute_dtype)
    x = pctx.constrain_acts(x)

    aux_total = jnp.zeros((), jnp.float32)

    # unstacked dense-FFN layers first (deepseek-v2 first_k_dense)
    dense_caches = []
    for i, dp in enumerate(params.get("dense_layers", [])):
        dcache = None if cache is None else jax.tree.map(lambda c: c[i], cache["dense"])
        x, ncache, aux = _layer_fwd(dp, x, cfg, positions, cache=dcache,
                                    cache_index=cache_index, window=window, dense=True)
        dense_caches.append(ncache)
        aux_total = aux_total + aux

    def body(carry, layer_in):
        xc, auxc = carry
        lp, lcache = layer_in
        xo, ncache, aux = _layer_fwd(lp, xc, cfg, positions, cache=lcache,
                                     cache_index=cache_index, window=window)
        return (xo, auxc + aux), ncache

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    scan_cache = None if cache is None else cache["scan"]
    (x, aux_total), new_scan_cache = lax.scan(
        body, (x, aux_total), (params["layers"], scan_cache))

    new_cache = None
    if cache is not None:
        new_cache = {"scan": new_scan_cache}
        if dense_caches:
            new_cache["dense"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *dense_caches)
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, new_cache, aux_total


def logits_fn(params, hidden, cfg):
    logits = L.unembed_apply(params["embed"], hidden, cfg)
    return pctx.constrain(logits, pctx.BATCH, None, pctx.MODEL)


# ---------------------------------------------------------------------------
# task heads: train loss / prefill / decode
# ---------------------------------------------------------------------------

def loss_fn(params, batch: dict, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            remat: str = "full") -> tuple[jax.Array, dict]:
    """Causal LM loss.  batch: tokens [B,S], labels [B,S] (-100 = masked),
    optional patch_embeds."""
    hidden, _, aux = forward(params, batch["tokens"], cfg,
                             compute_dtype=compute_dtype,
                             patch_embeds=batch.get("patch_embeds"),
                             remat=remat)
    labels = batch["labels"]
    if batch.get("patch_embeds") is not None:
        hidden = hidden[:, -labels.shape[1]:]  # loss over text positions only
    logits = logits_fn(params, hidden, cfg)
    loss = L.masked_xent(logits, labels)
    return loss + aux, {"nll": loss, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    n_scan = cfg.n_layers - cfg.first_k_dense
    if _use_mla(cfg):
        m = cfg.mla
        one = {
            "c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, 1, m.qk_rope_head_dim), dtype),
        }
    else:
        one = {
            "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, hd), dtype),
        }
    cache = {"scan": jax.tree.map(lambda z: jnp.broadcast_to(z, (n_scan, *z.shape)), one)}
    if cfg.first_k_dense:
        cache["dense"] = jax.tree.map(
            lambda z: jnp.broadcast_to(z, (cfg.first_k_dense, *z.shape)), one)
    return cache


def prefill(params, tokens, cfg: ModelConfig, cache, *, compute_dtype=jnp.bfloat16,
            patch_embeds=None, window=None):
    """Fill the cache from position 0; returns (last-token logits, cache)."""
    hidden, new_cache, _ = forward(params, tokens, cfg, compute_dtype=compute_dtype,
                                   cache=cache, cache_index=0, remat="none",
                                   patch_embeds=patch_embeds, window=window)
    logits = logits_fn(params, hidden[:, -1:], cfg)
    return logits[:, 0], new_cache


def decode_step(params, token, pos, cfg: ModelConfig, cache, *,
                compute_dtype=jnp.bfloat16, window=None):
    """One decode step.  token [B], pos scalar int32 (same for the batch —
    the serving engine aligns sequences); returns (logits [B,V], cache)."""
    hidden, new_cache, _ = forward(params, token[:, None], cfg,
                                   compute_dtype=compute_dtype,
                                   cache=cache, cache_index=pos, remat="none",
                                   window=window)
    logits = logits_fn(params, hidden, cfg)
    return logits[:, 0], new_cache
