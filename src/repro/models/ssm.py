"""Mamba2 (SSD) blocks and the Zamba2 hybrid (Mamba2 + shared attention).

The selective-state-space block follows the Mamba2 "state space duality"
chunked algorithm: quadratic attention *within* length-Q chunks (MXU-friendly
matmuls) and a linear recurrence *across* chunks (lax.scan over nc = S/Q
carries) — O(S·Q) work, O(1) state.  ``kernels/mamba_scan.py`` is the Pallas
version of the intra-chunk compute; this module is (and tests against) the
pure-jnp oracle.

Zamba2: 54 Mamba2 layers with ONE shared transformer block (attention + MLP)
inserted every ``attn_every`` layers — same weights at every insertion
(Zamba's parameter-sharing trick).  The forward is a scan over groups of
[attn_every] Mamba2 layers, with the shared block applied between groups.
At long context the shared attention runs with a sliding window
(cfg.sliding_window), keeping the whole model sub-quadratic.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, SSMConfig
from repro.parallel import context as pctx
from . import layers as L


def _dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim
    return s, d_inner, nheads, conv_dim


# ---------------------------------------------------------------------------
# Mamba2 block params
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg: ModelConfig, dtype) -> dict:
    s, d_inner, nheads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    in_cols = 2 * d_inner + 2 * s.state_dim + nheads  # z, x, B, C, dt
    return {
        "ln": L.init_norm(cfg, dtype),
        "in_proj": L._dense_init(ks[0], (cfg.d_model, in_cols), dtype),
        "conv_w": L._dense_init(ks[1], (conv_dim, s.conv_width), dtype, scale=0.1),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nheads,), dtype),          # A = -exp(A_log)
        "D": jnp.ones((nheads,), dtype),
        "dt_bias": jnp.zeros((nheads,), dtype),
        "gate_norm": jnp.ones((d_inner,), dtype),
        "out_proj": L._dense_init(ks[2], (d_inner, cfg.d_model), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, width W.  x [B,S,C]; w [C,W]; optional carried
    state [B,W-1,C] (decode).  Returns (y [B,S,C], new_state)."""
    width = w.shape[1]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)          # [B, S+W-1, C]
    y = sum(
        xx[:, i : i + x.shape[1]] * w[:, i].astype(x.dtype)
        for i in range(width)
    ) + b.astype(x.dtype)
    new_state = xx[:, -(width - 1):]
    return jax.nn.silu(y), new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Mamba2 SSD over a sequence.

    x  [B,S,H,P]   per-head inputs
    dt [B,S,H]     positive step sizes
    A  [H]         negative decay rates
    Bm [B,S,N], Cm [B,S,N]  input/output mixing (n_groups=1, shared by heads)
    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    # [nc, B, Q, ...] so one lax.scan walks chunks with the state carry —
    # peak live intermediate is per-chunk [B,Q,Q,H], never [B,S,Q,H].
    xc = x.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    bc = Bm.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    cc = Cm.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(hprev, inp):
        xk, dtk, bk, ck = inp                        # [B,Q,H,P],[B,Q,H],[B,Q,N]x2
        a = dtk * A[None, None, :]                   # [B,Q,H] (negative)
        cum = jnp.cumsum(a, axis=1)
        seg_end = cum[:, -1, :]                      # [B,H]
        # intra-chunk: L[i,j] = exp(cum_i - cum_j), i >= j
        li = cum[:, :, None, :] - cum[:, None, :, :]     # [B,Q,Q,H]
        lmat = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
        cbk = jnp.einsum("bin,bjn->bij", ck, bk)         # [B,Q,Q]
        w_intra = cbk[..., None] * lmat * dtk[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w_intra, xk)
        # state flowing out of this chunk
        decay_to_end = jnp.exp(seg_end[:, None, :] - cum)  # [B,Q,H]
        state_c = jnp.einsum("bjn,bjh,bjhp->bhnp", bk, decay_to_end * dtk, xk)
        # contribution of the carried state
        y_inter = jnp.einsum("bin,bih,bhnp->bihp", ck, jnp.exp(cum), hprev)
        hnew = hprev * jnp.exp(seg_end)[..., None, None] + state_c
        return hnew, y_intra + y_inter

    h0 = jnp.zeros((b, h, n, p), x.dtype)
    hlast, ys = lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * q, h, p)
    if pad:
        y = y[:, :s]
    return y, hlast


def mamba_block_apply(p, x, cfg: ModelConfig, *, state=None):
    """x [B,S,d].  state (decode): {"ssm": [B,H,N,P], "conv": [B,W-1,C]}.
    Returns (y, new_state) — new_state is None when state is None."""
    s_cfg, d_inner, nheads, conv_dim = _dims(cfg)
    res = x
    xn = L.norm_apply(p["ln"], x, cfg)
    proj = xn @ p["in_proj"].astype(x.dtype)
    z, xb = proj[..., :d_inner], proj[..., d_inner : d_inner + conv_dim]
    dt_raw = proj[..., d_inner + conv_dim :]
    conv_state = None if state is None else state["conv"]
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    xm = xb[..., :d_inner]
    Bm = xb[..., d_inner : d_inner + s_cfg.state_dim]
    Cm = xb[..., d_inner + s_cfg.state_dim :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    b, s, _ = x.shape
    xh = xm.reshape(b, s, nheads, s_cfg.head_dim)
    if state is None or s > 1:
        y, hlast = ssd_chunked(xh.astype(jnp.float32), dt, A,
                               Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                               s_cfg.chunk)
    else:
        # single-step recurrence (decode)
        hprev = state["ssm"].astype(jnp.float32)          # [B,H,N,P]
        dt1 = dt[:, 0]                                    # [B,H]
        dec = jnp.exp(dt1 * A[None, :])                   # [B,H]
        upd = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                         dt1, xh[:, 0].astype(jnp.float32))
        hlast = hprev * dec[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), hlast)[:, None]
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = L._rms(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = res + y @ p["out_proj"].astype(x.dtype)
    out = pctx.constrain(out, pctx.BATCH, None, None)
    new_state = None
    if state is not None:
        new_state = {"ssm": hlast.astype(state["ssm"].dtype), "conv": new_conv}
    return out, new_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------

def _shared_block_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(k2, cfg, dtype),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    assert cfg.attn_every and cfg.n_layers % cfg.attn_every == 0
    groups = cfg.n_layers // cfg.attn_every
    ke, km, ka = jax.random.split(key, 3)
    mkeys = jax.random.split(km, cfg.n_layers)
    # reshape is key-representation agnostic (typed keys: [n]; raw: [n, 2])
    mkeys = mkeys.reshape(groups, cfg.attn_every, *mkeys.shape[1:])
    stacked = jax.vmap(jax.vmap(lambda k: init_mamba_block(k, cfg, dtype)))(mkeys)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "mamba": stacked,                      # [G, E, ...] leaves
        "shared_attn": _shared_block_init(ka, cfg, dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }


def _attn_cache_len(cfg: ModelConfig, max_seq: int) -> int:
    return min(max_seq, cfg.sliding_window or max_seq)


def forward(params, tokens, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            cache=None, cache_index=None, remat="full"):
    """Returns (hidden, new_cache, aux=0).  cache:
    {"mamba": {ssm [G,E,B,H,N,P], conv [G,E,B,W-1,C]},
     "attn": {k/v [G, B, Lc, K, hd]}}"""
    b, s = tokens.shape
    x = L.embed_apply(params["embed"], tokens, cfg, compute_dtype)
    x = pctx.constrain_acts(x)
    groups = cfg.n_layers // cfg.attn_every
    base_pos = 0 if cache_index is None else cache_index
    positions = base_pos + jnp.arange(s)[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (b, s))
    window = cfg.sliding_window

    shared = params["shared_attn"]

    def group_body(carry, inp):
        xc = carry
        gparams, gcache = inp

        def inner(lp, xc2, lcache):
            return mamba_block_apply(lp, xc2, cfg, state=lcache)

        if remat == "full":
            inner = jax.checkpoint(inner)
        mcache = None if gcache is None else gcache["mamba"]
        # python-unrolled over the attn_every mamba blocks (small constant):
        # keeps their flops visible to HLO cost analysis (a scan here would
        # be counted once) and lets XLA pipeline across blocks.
        states = []
        for e in range(cfg.attn_every):
            lp = jax.tree.map(lambda a: a[e], gparams)
            lcache = None if mcache is None else jax.tree.map(lambda a: a[e], mcache)
            xc, nstate = inner(lp, xc, lcache)
            states.append(nstate)
        new_m = None
        if mcache is not None:
            new_m = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

        # shared attention block (same weights every group).  The decode
        # cache is a RING of length clen = min(max_seq, sliding_window):
        # position p lives in slot p % clen, keys stored pre-rotated at
        # absolute positions, so the window mask is simply "slot is filled".
        h = L.norm_apply(shared["ln1"], xc, cfg)
        acache = None if gcache is None else gcache["attn"]
        if acache is not None and s == 1:
            clen = acache["k"].shape[1]
            hd = cfg.resolved_head_dim
            q = L._proj(h, shared["attn"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
            k = L._proj(h, shared["attn"]["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
            v = L._proj(h, shared["attn"]["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            widx = cache_index % clen
            kc = lax.dynamic_update_slice_in_dim(
                acache["k"], k.astype(acache["k"].dtype), widx, axis=1)
            vc = lax.dynamic_update_slice_in_dim(
                acache["v"], v.astype(acache["v"].dtype), widx, axis=1)
            filled = jnp.minimum(cache_index + 1, clen)
            out = L.decode_attention(q, kc, vc, filled)
            a = out.reshape(b, 1, cfg.n_heads * hd) @ shared["attn"]["wo"].astype(h.dtype)
            new_a = {"k": kc, "v": vc}
        elif acache is not None:
            clen = acache["k"].shape[1]
            a, _ = L.attention_apply(
                shared["attn"], h, cfg, positions, causal=True, window=window,
                cache=None)
            # seed the ring with the last clen keys/values (slot p % clen
            # alignment holds because clen | stored-range start)
            hd = cfg.resolved_head_dim
            k = L._proj(h, shared["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
            v = L._proj(h, shared["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            if s >= clen:
                kw, vw = k[:, -clen:], v[:, -clen:]
            else:
                kw = lax.dynamic_update_slice_in_dim(
                    acache["k"], k.astype(acache["k"].dtype), 0, axis=1)
                vw = lax.dynamic_update_slice_in_dim(
                    acache["v"], v.astype(acache["v"].dtype), 0, axis=1)
            new_a = {"k": kw.astype(acache["k"].dtype),
                     "v": vw.astype(acache["v"].dtype)}
        else:
            a, _ = L.attention_apply(shared["attn"], h, cfg, positions,
                                     causal=True, window=window, cache=None)
            new_a = None
        xc = xc + a
        hh = L.norm_apply(shared["ln2"], xc, cfg)
        xc = xc + L.mlp_apply(shared["mlp"], hh, cfg)
        xc = pctx.constrain_acts(xc)
        new_gcache = None if gcache is None else {"mamba": new_m, "attn": new_a}
        return xc, new_gcache

    gcaches = None if cache is None else cache
    if remat == "full":
        # checkpoint the whole group (6 mamba blocks + shared attn): the
        # layer scan then stashes only the [B,S,d] carry per group, not the
        # SSD intermediates; inner per-block checkpoints bound the recompute.
        group_body = jax.checkpoint(group_body)
    x, new_cache = lax.scan(group_body, x, (params["mamba"], gcaches))
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, new_cache, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    s_cfg, d_inner, nheads, conv_dim = _dims(cfg)
    groups = cfg.n_layers // cfg.attn_every
    e = cfg.attn_every
    clen = _attn_cache_len(cfg, max_seq)
    hd = cfg.resolved_head_dim
    return {
        "mamba": {
            "ssm": jnp.zeros((groups, e, batch, nheads, s_cfg.state_dim,
                              s_cfg.head_dim), dtype),
            "conv": jnp.zeros((groups, e, batch, s_cfg.conv_width - 1, conv_dim), dtype),
        },
        "attn": {
            "k": jnp.zeros((groups, batch, clen, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((groups, batch, clen, cfg.n_kv_heads, hd), dtype),
        },
    }


def loss_fn(params, batch, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            remat="full"):
    hidden, _, _ = forward(params, batch["tokens"], cfg,
                           compute_dtype=compute_dtype, remat=remat)
    logits = L.unembed_apply(params["embed"], hidden, cfg)
    loss = L.masked_xent(logits, batch["labels"])
    return loss, {"nll": loss}


def prefill(params, tokens, cfg: ModelConfig, cache, *, compute_dtype=jnp.bfloat16):
    hidden, new_cache, _ = forward(params, tokens, cfg, compute_dtype=compute_dtype,
                                   cache=cache, cache_index=0, remat="none")
    logits = L.unembed_apply(params["embed"], hidden[:, -1:], cfg)
    return logits[:, 0], new_cache


def decode_step(params, token, pos, cfg: ModelConfig, cache, *,
                compute_dtype=jnp.bfloat16):
    hidden, new_cache, _ = forward(params, token[:, None], cfg,
                                   compute_dtype=compute_dtype,
                                   cache=cache, cache_index=pos, remat="none")
    logits = L.unembed_apply(params["embed"], hidden, cfg)
    return logits[:, 0], new_cache
