"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 100 --sync wrht --data corpus

On this CPU container use --smoke (reduced config, host device count 1).  On
real hardware drop --smoke and optionally --multi-pod; everything else is
identical — mesh construction, sharding, WRHT sync, checkpointing and the
fault-tolerance runtime are the same code path.
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data.pipeline import CorpusLM, SyntheticLM
from repro.parallel import context as pctx
from repro.runtime.fault_tolerance import FailureInjector
from repro.train import Trainer, TrainerOptions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sync", default="auto")
    ap.add_argument("--sync-m", type=int, default=17)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", choices=("corpus", "synthetic"), default="corpus")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2x2 (axes pod,data,model); default: no mesh")
    ap.add_argument("--fail-at", type=int, nargs="*", default=(),
                    help="inject failures at these steps (recovery demo)")
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = registry.get(args.arch, smoke=args.smoke)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5 + 1),
                     remat=args.remat, sync_algorithm=args.sync, sync_m=args.sync_m,
                     microbatches=args.microbatches)

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):] if len(dims) < 3 else ("pod", "data", "model")
        from jax.sharding import AxisType
        mesh = jax.make_mesh(dims, axes, axis_types=(AxisType.Auto,) * len(dims))
        pctx.set_mesh(mesh)

    src_cls = CorpusLM if args.data == "corpus" else SyntheticLM
    source = src_cls(cfg.vocab_size, args.seq, args.batch)
    injector = FailureInjector(tuple(args.fail_at)) if args.fail_at else None

    trainer = Trainer(cfg, tc, source, mesh=mesh,
                      options=TrainerOptions(ckpt_dir=args.ckpt_dir,
                                             ckpt_every=args.ckpt_every),
                      injector=injector)
    if mesh is not None:
        with jax.set_mesh(mesh):
            trainer.run(args.steps)
    else:
        trainer.run(args.steps)
    for h in trainer.history[-5:]:
        print(h)


if __name__ == "__main__":
    main()
