"""Production meshes.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — dryrun.py must pin XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))
