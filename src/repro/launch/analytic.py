"""Analytic FLOP accounting: MODEL_FLOPS and inner-scan corrections.

MODEL_FLOPS ("useful" flops, the roofline numerator):
    train   6 · N_active · tokens  + attention term (causal half)
    decode  2 · N_active · B       + KV-attention term (fwd only)
N_active counts matmul-participating params per token: embedding lookups
excluded, tied unembed *matmul* included, MoE routed experts scaled by
top_k / n_experts (6·N_active·D per the assignment).

Inner-scan corrections: XLA cost analysis counts while bodies once, so the
sequence-block loops (attention q/kv blocks, SSD chunks, xLSTM scans) are
undercounted even after depth extrapolation.  Each family's correction adds
(trip_count - 1) × per-iteration flops of those loops, with per-iteration
flops from the closed forms below (dominant matmul terms).
"""

from __future__ import annotations

import math
from dataclasses import replace

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api as mapi

QB, KVB = 512, 1024   # blocked_attention defaults (keep in sync with layers.py)


# ---------------------------------------------------------------------------
# parameter census
# ---------------------------------------------------------------------------

def _param_census(cfg: ModelConfig) -> dict:
    """Split parameter counts into embedding-lookup / routed-expert / rest."""
    specs = mapi.param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    embed = routed = rest = 0
    for path, leaf in flat:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        n = math.prod(leaf.shape)
        if "embed/tok" in p or "embed/pos" in p or "enc_pos" in p:
            embed += n
        elif "moe/w_" in p:
            routed += n
        else:
            rest += n
    return {"embed": embed, "routed": routed, "rest": rest}


def n_active(cfg: ModelConfig) -> float:
    c = _param_census(cfg)
    act = c["rest"]
    if cfg.moe is not None:
        act += c["routed"] * cfg.moe.top_k / cfg.moe.n_experts
    if cfg.tie_embeddings:
        act += cfg.vocab_size * cfg.d_model   # tied table used as unembed matmul
    return float(act)


# ---------------------------------------------------------------------------
# attention terms
# ---------------------------------------------------------------------------

def _attn_dims(cfg: ModelConfig) -> tuple[int, int]:
    """(qk flops dim, pv flops dim) per head-pair contraction."""
    if cfg.mla is not None:
        return (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim,
                cfg.mla.v_head_dim)
    hd = cfg.resolved_head_dim
    return hd, hd


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every     # one shared block per group
    if cfg.family == "xlstm":
        return 0
    return cfg.n_layers


def attention_model_flops(cfg: ModelConfig, b: int, s: int, causal_half: bool,
                          fwd_mult: float) -> float:
    """Useful attention flops (global, fwd_mult=3 for train fwd+bwd)."""
    dqk, dv = _attn_dims(cfg)
    h = cfg.n_heads
    eff = 0.5 * s * s if causal_half else float(s) * s
    win = cfg.sliding_window
    if win is not None and s > win:
        eff = min(eff, float(s) * win)
    per_layer = 2 * b * h * eff * (dqk + dv)
    total = _n_attn_layers(cfg) * per_layer
    if cfg.family == "encdec":
        # encoder self-attention (bidirectional) + decoder cross-attention
        es = cfg.encoder_seq
        total += cfg.encoder_layers * 2 * b * h * es * es * (dqk + dv)
        total += cfg.n_layers * 2 * b * h * s * es * (dqk + dv)
    return total * fwd_mult


# ---------------------------------------------------------------------------
# MODEL_FLOPS
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global useful flops for one step of the cell's kind."""
    b, s = shape.global_batch, shape.seq_len
    na = n_active(cfg)
    if shape.kind == "train":
        tokens = b * s
        if cfg.family == "encdec":
            tokens = b * s  # decoder tokens; encoder in attention term + rest
        return 6.0 * na * tokens + attention_model_flops(cfg, b, s, True, 3.0)
    if shape.kind == "prefill":
        tokens = b * s
        return 2.0 * na * tokens + attention_model_flops(cfg, b, s, True, 1.0)
    # decode: one token against an s-length KV cache
    dqk, dv = _attn_dims(cfg)
    ctx = s if cfg.sliding_window is None else min(s, cfg.sliding_window)
    attn = _n_attn_layers(cfg) * 2 * b * cfg.n_heads * ctx * (dqk + dv)
    if cfg.family == "encdec":
        attn += cfg.n_layers * 2 * b * cfg.n_heads * cfg.encoder_seq * (dqk + dv)
    return 2.0 * na * b + attn


# ---------------------------------------------------------------------------
# inner-scan corrections (executed-flops deltas vs once-counted loop bodies)
# ---------------------------------------------------------------------------

def _blocked_attn_correction(cfg: ModelConfig, b: int, sq: int, skv: int,
                             n_layers: int, mult: float) -> float:
    """blocked_attention runs nq*nkv block pairs; cost analysis sees one."""
    if sq <= 1:
        return 0.0
    dqk, dv = _attn_dims(cfg)
    qb, kvb = min(QB, sq), min(KVB, skv)
    sq_p = math.ceil(sq / qb) * qb
    skv_p = math.ceil(skv / kvb) * kvb
    per_layer = 2 * b * cfg.n_heads * (dqk + dv) * (sq_p * skv_p - qb * kvb)
    return n_layers * per_layer * mult


def inner_scan_correction(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Flops delta to ADD to depth-extrapolated HLO flops (global)."""
    b, s = shape.global_batch, shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    if shape.kind == "decode":
        return 0.0  # decode paths are scan-free per step
    total = 0.0
    fam = cfg.family
    if fam in ("decoder", "moe", "vlm"):
        sq = s + (cfg.frontend_seq if cfg.frontend == "patch_embed" else 0)
        total += _blocked_attn_correction(cfg, b, sq, sq, cfg.n_layers, mult)
    elif fam == "encdec":
        es = cfg.encoder_seq
        total += _blocked_attn_correction(cfg, b, es, es, cfg.encoder_layers, mult)
        total += _blocked_attn_correction(cfg, b, s, s, cfg.n_layers, mult)
        total += _blocked_attn_correction(cfg, b, s, es, cfg.n_layers, mult)
    elif fam == "hybrid":
        # shared attention blocks
        na = cfg.n_layers // cfg.attn_every
        total += _blocked_attn_correction(cfg, b, s, s, na, mult)
        # SSD chunk scan: (nc - 1) x per-chunk flops, per mamba layer
        sc = cfg.ssm
        d_inner = sc.expand * cfg.d_model
        nheads = d_inner // sc.head_dim
        q = min(sc.chunk, s)
        nc = math.ceil(s / q)
        n_st, p_hd = sc.state_dim, sc.head_dim
        per_chunk = (2 * b * q * q * n_st          # C·Bᵀ
                     + 2 * b * q * q * nheads * p_hd  # (CBᵀ∘L)·X
                     + 4 * b * q * n_st * nheads * p_hd)  # state out + carry in
        total += cfg.n_layers * (nc - 1) * per_chunk * mult
    elif fam == "xlstm":
        x = cfg.xlstm
        d = cfg.d_model
        d_i = int(x.proj_factor * d)
        pairs = cfg.n_layers // 2
        # mLSTM chunk scan
        from repro.models.xlstm import CHUNK
        q = min(CHUNK, s)
        nc = math.ceil(s / q)
        dh = d_i // x.n_heads
        per_chunk = (4 * b * q * q * d_i           # qk dot + weighted v
                     + 8 * b * q * d_i * dh)       # carry read + state update
        total += pairs * (nc - 1) * per_chunk * mult
        # sLSTM per-token scan
        dhs = d // x.n_heads
        per_step = (4 * b * d * d                  # wz/wo projections
                    + 4 * b * d * x.n_heads        # wi/wf
                    + 4 * b * x.n_heads * dhs * dhs)  # rz/ro recurrences
        total += pairs * (s - 1) * per_step * mult
    return total


# ---------------------------------------------------------------------------
# depth variants for 2-point extrapolation
# ---------------------------------------------------------------------------

def depth_unit(cfg: ModelConfig) -> int:
    """Layers added per unit of scan depth."""
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.family == "xlstm":
        return 2
    return 1


def scan_depth(cfg: ModelConfig) -> int:
    """Trip count of the (outermost) layer scan at full depth."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "xlstm":
        return cfg.n_layers // 2
    return cfg.n_layers - cfg.first_k_dense


def with_depth(cfg: ModelConfig, scan_trips: int) -> ModelConfig:
    """Config with the layer-scan trip count set to ``scan_trips``."""
    u = depth_unit(cfg)
    n = scan_trips * u + cfg.first_k_dense
    kw = {"n_layers": n}
    if cfg.family == "encdec":
        kw["encoder_layers"] = scan_trips
    return replace(cfg, **kw)


def extrapolate(f1: float, f2: float, d1: int, d2: int, full: int) -> float:
    """Linear 2-point extrapolation of a depth-linear cost."""
    slope = (f2 - f1) / (d2 - d1)
    return f1 + (full - d1) * slope
