"""Serving driver: batched requests against a (random- or checkpoint-) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import load_latest
from repro.configs import registry
from repro.models import api as mapi
from repro.serve import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch, smoke=args.smoke)
    api = mapi.get_api(cfg, remat="none")
    params = api.init(jax.random.key(args.seed))
    if args.ckpt_dir:
        restored, step = load_latest(args.ckpt_dir, {"params": params})
        if restored is not None:
            params = restored["params"]
            print(f"loaded checkpoint step {step}")

    eng = Engine(cfg, params, batch_slots=args.batch_slots, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(2, 12))
        eng.submit(list(rng.integers(1, cfg.vocab_size, plen)),
                   max_new_tokens=args.max_new)
    done = eng.run()
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in done)
    print(f"{len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt {r.prompt[:6]}... -> {r.output}")


if __name__ == "__main__":
    main()
