"""Post-compile HLO analysis: collective bytes, op census, roofline terms.

``collective_bytes`` is not in ``cost_analysis()``; we parse the compiled
(post-SPMD, per-device) HLO text: build an instruction-name -> byte-size map
from result shapes, then sum *operand* sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

KNOWN XLA LIMITATION (verified in this container): HloCostAnalysis visits a
``while`` body ONCE — scanned layers / sequence-block loops are undercounted
by their trip count.  The dry-run therefore lowers each cell at two reduced
depths and linearly extrapolates ("2-point depth extrapolation", exact for
the layer dimension), plus per-family analytic corrections for the inner
sequence-block loops (attention q/kv blocks, SSD chunks, xLSTM scans) —
see ``launch.dryrun`` and EXPERIMENTS.md §Methodology.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    count_by_kind: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def scaled(self, factor: float) -> "CollectiveStats":
        out = CollectiveStats()
        for k, v in self.bytes_by_kind.items():
            out.bytes_by_kind[k] = v * factor
        for k, v in self.count_by_kind.items():
            out.count_by_kind[k] = v
        return out

    def merged_with(self, other: "CollectiveStats", w: float = 1.0) -> "CollectiveStats":
        out = CollectiveStats()
        for src, ww in ((self, 1.0), (other, w)):
            for k, v in src.bytes_by_kind.items():
                out.bytes_by_kind[k] += v * ww
            for k, v in src.count_by_kind.items():
                out.count_by_kind[k] += int(v * ww)
        return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in (per-device) HLO text.

    Operand sizes are looked up from the result shapes of the producing
    instructions; for variadic collectives every operand is counted.
    """
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, _ = m.groups()
            sizes[name] = _shape_bytes(type_str)

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = next((c for c in COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        # operands: %refs inside the parens
        args = line[line.index("(") + 1 : line.rindex(")")]
        operand_names = re.findall(r"%?([\w\.\-]+)", args)
        b = 0
        for o in operand_names:
            if o in sizes:
                b += sizes[o]
        if b == 0:  # fallback: use result size
            b = _shape_bytes(type_str)
        stats.bytes_by_kind[kind] += b
        stats.count_by_kind[kind] += 1
    return stats


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e-class, per assignment)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (≈ per-chip effective here)


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def step_time_s(self) -> float:
        """No-overlap estimate: max of the three terms (they pipeline)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        dominant term's speed: (useful flops / peak) / step_time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS) / t

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
        }
