import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# Everything below (including repro imports) may now touch jax freely.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  1. build the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. lower the jit'd step (train_step / prefill / serve_step) from
     ShapeDtypeStruct stand-ins with full NamedShardings — NO allocation,
  3. compile; record memory_analysis (fits/chip?), cost_analysis
     (flops/bytes), and collective bytes parsed from the per-device HLO,
  4. repeat at two reduced scan depths and extrapolate the depth-linear
     costs to full depth (XLA counts while bodies once — see hlo_analysis),
  5. add the analytic inner-scan corrections + MODEL_FLOPS, emit roofline
     terms into experiments/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.launch import analytic
from repro.launch.hlo_analysis import Roofline, parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import api as mapi
from repro.parallel import context as pctx
from repro.parallel.sharding import (
    batch_partition_specs,
    cache_partition_specs,
    param_partition_specs,
)
from repro.train.train_step import abstract_train_state, make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_axes(mesh, global_batch: int | None = None):
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if global_batch is not None:
        import math as _m
        if global_batch % _m.prod(mesh.shape[a] for a in ba):
            return ()  # e.g. long_500k batch=1: replicate over DP axes
    return ba


def _train_config(cfg: ModelConfig, overrides: dict | None = None) -> TrainConfig:
    kw = dict(
        remat="full",
        fsdp=True,
        sync_algorithm="auto",
        # grad accumulation: bounds activation temps (logits especially) so
        # every arch fits 16 GB/chip; also the production overlap unit
        microbatches=8,
        opt_state_dtype="bfloat16" if mapi.param_count(cfg) > 1e11 else "float32",
        grad_accum_dtype="bfloat16" if mapi.param_count(cfg) > 1e11 else "float32",
    )
    if mapi.param_count(cfg) > 1e11:
        kw["microbatches"] = 16
    if overrides:
        kw.update(overrides)
    return TrainConfig(**kw)


# ---------------------------------------------------------------------------
# lowering one cell at one depth
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, tc: TrainConfig):
    """Returns (lowered, compiled).  Pure ShapeDtypeStruct inputs."""
    pctx.set_mesh(mesh)
    ba = _batch_axes(mesh, shape.global_batch)
    # ZeRO-3 shards params/optimizer over every DP axis (data AND pod)
    dp_all = tuple(a for a in ("data", "pod") if a in mesh.axis_names)
    fsdp_axis = dp_all if tc.fsdp else None

    if shape.kind == "train":
        state = abstract_train_state(cfg, tc)
        pspecs = param_partition_specs(state["params"], fsdp_axis)
        state_specs = {
            "params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "count": P()},
            "step": P(),
        }
        if "ef" in state:
            state_specs["ef"] = pspecs
        batch = mapi.train_batch_specs(cfg, shape)
        bspecs = batch_partition_specs(batch, ba)
        step = make_train_step(cfg, tc, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(_shardings(mesh, state_specs), _shardings(mesh, bspecs)),
            out_shardings=(_shardings(mesh, state_specs), None),
            donate_argnums=(0,),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(state, batch)

    elif shape.kind == "prefill":
        api = mapi.get_api(cfg, remat="none")
        params = mapi.param_specs(cfg, jnp.bfloat16)
        # weight-stationary TP when the TP-sharded weights fit comfortably;
        # 2D (data×model) sharding only when forced by capacity (236B-class).
        # 2D costs a per-step all-gather of every weight — §Perf iteration 7.
        serve_fsdp = dp_all if mapi.param_count(cfg) * 2 / 16 > 12 * 2**30 else None
        pspecs = param_partition_specs(params, serve_fsdp)
        batch = mapi.prefill_batch_specs(cfg, shape)
        bspecs = batch_partition_specs(batch, ba)
        cache = mapi.cache_specs(cfg, shape)
        cspecs = cache_partition_specs(cfg, cache, ba, mesh.shape["model"])

        def prefill_step(params, batch, cache):
            return api.prefill(params, batch, cache)

        jitted = jax.jit(
            prefill_step,
            in_shardings=(_shardings(mesh, pspecs), _shardings(mesh, bspecs),
                          _shardings(mesh, cspecs)),
            out_shardings=(None, _shardings(mesh, cspecs)),
            donate_argnums=(2,),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(params, batch, cache)

    else:  # decode
        api = mapi.get_api(cfg, remat="none")
        params = mapi.param_specs(cfg, jnp.bfloat16)
        serve_fsdp = dp_all if mapi.param_count(cfg) * 2 / 16 > 12 * 2**30 else None
        pspecs = param_partition_specs(params, serve_fsdp)
        cache = mapi.cache_specs(cfg, shape)
        cspecs = cache_partition_specs(cfg, cache, ba, mesh.shape["model"])
        dec_in = mapi.decode_input_specs(cfg, shape)
        tok_spec = NamedSharding(mesh, P(ba))
        pos_spec = NamedSharding(mesh, P())

        def serve_step(params, token, pos, cache):
            return api.decode(params, token, pos, cache)

        jitted = jax.jit(
            serve_step,
            in_shardings=(_shardings(mesh, pspecs), tok_spec, pos_spec,
                          _shardings(mesh, cspecs)),
            out_shardings=(None, _shardings(mesh, cspecs)),
            donate_argnums=(3,),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(params, dec_in["token"], dec_in["pos"], cache)

    compiled = lowered.compile()
    return lowered, compiled


def _costs(compiled) -> dict:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return {"flops": flops, "bytes": nbytes,
            "collective_bytes": stats.total_bytes,
            "collective_by_kind": dict(stats.bytes_by_kind),
            "collective_counts": dict(stats.count_by_kind)}


def _memory(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    args = out.get("argument_size_in_bytes", 0)
    alias = out.get("alias_size_in_bytes", 0)
    temp = out.get("temp_size_in_bytes", 0)
    outb = out.get("output_size_in_bytes", 0)
    # live working set: arguments + temps + non-aliased outputs
    out["per_device_hbm_bytes"] = args + temp + max(outb - alias, 0)
    return out


# ---------------------------------------------------------------------------
# full cell analysis with depth extrapolation
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tc_overrides: dict | None = None, verbose: bool = True) -> dict:
    cfg = registry.get(arch)
    shape = registry.get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tc = _train_config(cfg, tc_overrides)
    n_dev = mesh.devices.size
    t0 = time.time()

    # full-depth compile: exact memory analysis + baseline costs
    lowered, compiled = lower_cell(cfg, shape, mesh, tc)
    mem = _memory(compiled)
    raw = _costs(compiled)

    # depth-0/1 lowering for the while-body extrapolation.  XLA fully
    # unrolls a length-1 scan (body fully counted) and counts length>=2
    # bodies once, so  F(L) = F(0) + L*(F(1) - F(0))  is exact for costs
    # linear in depth (layer bodies, their collectives, per-layer optimizer).
    full = analytic.scan_depth(cfg)
    # cost lowerings run with microbatches=1: total flops/bytes are the same
    # as accumulated microbatches (same tokens), but nothing hides inside the
    # accumulation scan (whose body XLA cost analysis counts only once).
    tc_cost = dataclasses.replace(tc, microbatches=1)
    if full >= 2:
        c0 = _costs(lower_cell(analytic.with_depth(cfg, 0), shape, mesh, tc_cost)[1])
        c1 = _costs(lower_cell(analytic.with_depth(cfg, 1), shape, mesh, tc_cost)[1])
        flops = analytic.extrapolate(c0["flops"], c1["flops"], 0, 1, full)
        nbytes = analytic.extrapolate(c0["bytes"], c1["bytes"], 0, 1, full)
        coll = analytic.extrapolate(c0["collective_bytes"], c1["collective_bytes"],
                                    0, 1, full)
        # slope noise guard: per-layer costs are non-negative, so the
        # extrapolation can never go below the depth-1 measurement
        flops = max(flops, c1["flops"])
        nbytes = max(nbytes, c1["bytes"])
        coll = max(coll, c1["collective_bytes"])
    else:
        c1 = _costs(lower_cell(cfg, shape, mesh, tc_cost)[1])
        flops, nbytes, coll = c1["flops"], c1["bytes"], c1["collective_bytes"]

    # analytic corrections for inner sequence loops (global -> per device)
    corr = analytic.inner_scan_correction(cfg, shape) / n_dev
    flops += corr
    mf = analytic.model_flops(cfg, shape) / n_dev

    roof = Roofline(
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=coll,
        model_flops_per_device=mf,
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "ok": True,
        "seconds": round(time.time() - t0, 1),
        "memory": mem,
        "fits_16gb": mem["per_device_hbm_bytes"] < 16 * 2**30,
        "raw_cost_analysis": raw,
        "extrapolated": {"flops": flops, "bytes": nbytes,
                         "collective_bytes": coll,
                         "inner_scan_correction": corr},
        "roofline": roof.to_dict(),
        "train_config": {
            "sync": tc.sync_algorithm, "fsdp": tc.fsdp,
            "microbatches": tc.microbatches, "remat": tc.remat,
            "opt_state_dtype": tc.opt_state_dtype,
        } if shape.kind == "train" else None,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}: "
              f"hbm/dev={mem['per_device_hbm_bytes']/2**30:.2f}GiB "
              f"compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms -> {roof.bottleneck} "
              f"({result['seconds']}s)", flush=True)
    return result


def cell_path(arch: str, shape: str, multi_pod: bool) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    return OUT_DIR / f"{arch}__{shape}__{mesh}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=list(registry.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--sync", default=None, help="TrainConfig.sync_algorithm override")
    ap.add_argument("--tag", default=None, help="suffix for the output json")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    over = {"sync_algorithm": args.sync} if args.sync else None

    if args.all:
        cells = [(a, s) for a, s, skip in registry.cells() if not skip]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        out = cell_path(arch, shape, args.multi_pod)
        if args.tag:
            out = out.with_name(out.stem + f"__{args.tag}.json")
        if args.skip_existing and out.exists():
            print(f"[dryrun] skip {out.name}")
            continue
        try:
            result = run_cell(arch, shape, args.multi_pod, over)
        except Exception as e:  # record failures too — they are bugs to fix
            traceback.print_exc()
            result = {"arch": arch, "shape": shape,
                      "mesh": "2x16x16" if args.multi_pod else "16x16",
                      "ok": False, "error": f"{type(e).__name__}: {e}"}
        with open(out, "w") as f:
            json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
