"""Training loop: checkpointing, auto-resume, watchdog, failure recovery.

The loop is deliberately restart-transparent: the data source is a pure
function of the step index and the train state carries its own step counter,
so ``Trainer.run()`` after a crash (or an ``InjectedFailure``) resumes from
the latest checkpoint and produces bit-identical results to an uninterrupted
run — asserted by tests/test_fault_tolerance.py.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from pathlib import Path

import jax

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import shard_batch
from repro.runtime.fault_tolerance import (
    FailureInjector, FaultManager, InjectedFailure, StepWatchdog)
from .train_step import make_train_state, make_train_step

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerOptions:
    ckpt_dir: str | Path = "checkpoints"
    ckpt_every: int = 50
    keep_n: int = 3
    max_restarts: int = 3
    watchdog_threshold: float = 3.0
    log_every: int = 10
    # what a flagged straggler triggers: "log" (record only), "checkpoint"
    # (force an early checkpoint so the likely restart loses less), or a
    # callable(StragglerEvent) for custom policies (e.g. re-shard/elastic)
    straggler_policy: object = "log"


@dataclass
class Trainer:
    cfg: ModelConfig
    tc: TrainConfig
    source: object                      # .batch(step) -> host batch dict
    mesh: object | None = None
    options: TrainerOptions = field(default_factory=TrainerOptions)
    injector: FailureInjector | None = None
    # the closed-loop fault-management path (DESIGN.md §14): detector-driven
    # masks into replan(); the injector's degrade_at stays as the manual
    # escape hatch for deterministic tests
    fault_manager: FaultManager | None = None

    def __post_init__(self):
        policy = self.options.straggler_policy
        if not callable(policy) and policy not in ("log", "checkpoint"):
            raise ValueError(
                f"unknown straggler_policy {policy!r} "
                "(expected 'log', 'checkpoint' or a callable)")
        self.ckpt = Checkpointer(self.options.ckpt_dir, keep_n=self.options.keep_n)
        self.watchdog = StepWatchdog(self.options.watchdog_threshold,
                                     on_straggler=self._on_straggler)
        raw_step = make_train_step(self.cfg, self.tc, self.mesh)
        # the online re-plan controller (planned_sharded only): kept off the
        # jitted callable, which jax.jit would strip (DESIGN.md §12)
        self.controller = getattr(raw_step, "controller", None)
        self._step_fn = jax.jit(raw_step)
        self._plan_codes = (None if self.controller is None
                            else self.controller.arrays())
        self._ckpt_requested = False
        self.history: list[dict] = []
        if self.fault_manager is not None:
            self.fault_manager.attach(self.replan)

    # --------------------------------------------------------- fault hooks
    def _on_straggler(self, event):
        if self.fault_manager is not None:
            # stragglers are a pre-failure symptom — feed the detector
            # (DESIGN.md §14) before applying the local policy
            self.fault_manager.observe_straggler(event)
        policy = self.options.straggler_policy
        if callable(policy):
            policy(event)
            return
        log.warning("straggler at step %d: %.3fs vs median %.3fs",
                    event.step, event.duration_s, event.median_s)
        if policy == "checkpoint":
            self._ckpt_requested = True

    def replan(self, failure_mask=None):
        """Swap in degraded (or restored-healthy) gradient-sync schedules
        for the running jitted step (DESIGN.md §12).  The watchdog/injector
        path calls this with the reported
        :class:`~repro.core.topology.FailureMask`; the new plan takes effect
        on the next step with **no retrace** — the strategy-code arrays are
        traced inputs of the already-compiled step."""
        if self.controller is None:
            raise RuntimeError(
                "replan() needs the online re-plan controller — only the "
                "sharded modes (sync_algorithm='planned_sharded' or "
                "'planned_pipelined') build one")
        self._plan_codes = self.controller.replan(failure_mask)
        log.warning("re-planned gradient sync (mask=%s, %.1f ms)",
                    self.controller.failures,
                    1e3 * self.controller.last_replan_s)
        return self._plan_codes

    # -------------------------------------------------------------- state
    def init_or_restore(self):
        state = make_train_state(self.cfg, self.tc, jax.random.key(self.tc.seed))
        steps = self.ckpt.steps()
        if steps:
            state = self.ckpt.restore(steps[-1], state)
            log.info("restored checkpoint at step %d", steps[-1])
        return state

    # ---------------------------------------------------------------- run
    def run(self, total_steps: int | None = None):
        total = total_steps if total_steps is not None else self.tc.total_steps
        restarts = 0
        while True:
            try:
                return self._run_inner(total)
            except InjectedFailure as e:
                restarts += 1
                log.warning("%s — restart %d/%d", e, restarts,
                            self.options.max_restarts)
                if restarts > self.options.max_restarts:
                    raise

    def _run_inner(self, total: int):
        state = self.init_or_restore()
        step = int(jax.device_get(state["step"]))
        while step < total:
            if self.fault_manager is not None:
                # primary replan path: telemetry -> hysteresis -> mask
                # (DESIGN.md §14); infeasible proposals keep the previous
                # plan per the manager's ReplanPolicy
                self.fault_manager.on_step(step)
            if self.injector is not None:
                self.injector.check(step)
                mask = self.injector.degradation(step)
                if mask is not None:
                    self.replan(mask)
            host_batch = self.source.batch(step)
            batch = shard_batch(host_batch, self.mesh)
            self.watchdog.start()
            if self._plan_codes is not None:
                state, metrics = self._step_fn(state, batch, self._plan_codes)
            else:
                state, metrics = self._step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = self.watchdog.stop(step)
            step += 1
            if step % self.options.log_every == 0 or step == total:
                m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                m.update(step=step, sec_per_step=dt)
                self.history.append(m)
                log.info("step %d loss %.4f (%.2fs)", step, m["loss"], dt)
            if self._ckpt_requested:
                self._ckpt_requested = False
                log.warning("straggler policy: forcing early checkpoint at "
                            "step %d", step)
                self.ckpt.save(step, state)
            if step % self.options.ckpt_every == 0 or step == total:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state
