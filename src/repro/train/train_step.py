"""Train step: loss -> grad -> (WRHT) gradient sync -> AdamW.

Gradient-sync modes (``TrainConfig.sync_algorithm``):

  auto          pure GSPMD: batch sharded over ('pod','data'); XLA inserts
                the gradient all-reduce.  Baseline, FSDP-compatible.
  psum|ring|rd|bt|wrht
                the step body runs inside shard_map, *manual* over the DP
                axes ('model' stays auto/GSPMD for TP): gradients are synced
                explicitly by repro.core.collectives, per size-capped bucket.
                With multiple DP axes the chosen algorithm runs per level
                innermost->outermost — exactly the paper's hierarchical-group
                structure with pods as top-level WRHT groups.
  hier_faithful | hier_scatter
                the mesh-factorized WRHT port (full-vector psum per level /
                reduce-scatter down + all-gather up).
  planned       per-bucket α–β planner choice (core.planner), the Lemma-1
                machinery deciding flat vs tree vs hierarchical per size;
                every bucket is planned once at setup via the amortized
                ``planner.plan_buckets`` batch API (DESIGN.md §10) and each
                traced step dispatches from the precomputed plan.
  planned_sharded
                ZeRO-style sharded sync (DESIGN.md §11): each bucket runs a
                planned reduce-scatter down the DP axes then a planned
                all-gather back up — between the phases every device holds
                only its owned shard, so the bytes moved are the
                bandwidth-optimal 2·(S-1)/S·d instead of the monolithic
                all-reduce's per-step full vector.  Both phases are planned
                per bucket through ``planner.plan_buckets(collective=...)``
                (ring pass vs the single-step all-to-all finisher).
  planned_pipelined
                planned_sharded with the bucket loop software-pipelined
                (DESIGN.md §13): bucket k+1's reduce-scatter is issued
                before bucket k's all-gather is drained
                (``bucketing.bucketed_apply_pipelined``), so the two ride
                one composed ring schedule (``core.compose``) — the planner
                costs the interleaving via ``plan_buckets(depth=...)`` and
                the RS+AG pair fuses onto disjoint wavelengths.  Per-bucket
                numerics are identical to planned_sharded.
  planned_compressed | planned_sharded_compressed
                the planned / planned_sharded sync with bits-per-element as
                a plan axis (DESIGN.md §15): at setup each bucket is swept
                over ``compress_bits`` wire widths and the cheapest wins —
                small latency-bound buckets *decline* compression because
                the quantize overhead exceeds the β saving.  Compressed
                buckets run int8/int4 symmetric quantization with per-block
                scales and error feedback (the residual rides in the train
                state and is checkpointed); the planned collective reduces
                the dequantized values, so convergence follows the EF-SGD
                guarantee.  The chosen widths are frozen per run — an
                online re-plan (SyncController) swaps strategies only,
                never widths, preserving the zero-retrace property.

``compress_pod_axis`` swaps the pod level for int8+error-feedback recursive
doubling (cross-pod links are the scarce resource at 512+ chips).
"""

from __future__ import annotations

import math
import time
from collections import OrderedDict
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import bucketing, compression, planner
from repro.core import collectives as C
from repro.models import api as mapi
from repro.optim import adamw_init, adamw_update, make_lr_schedule

MANUAL_ALGOS = ("psum", "ring", "rd", "bt", "wrht", "hier_faithful",
                "hier_scatter", "planned", "planned_sharded",
                "planned_pipelined", "planned_compressed",
                "planned_sharded_compressed")

# modes that plan per-(axis, bucket) RS/AG schedules at setup and support
# the no-retrace online re-plan path (SyncController)
SHARDED_ALGOS = ("planned_sharded", "planned_pipelined",
                 "planned_sharded_compressed")

# modes that carry EF residual state and quantize each bucket to the
# planner-chosen wire width before its collective (DESIGN.md §15)
COMPRESSED_ALGOS = ("planned_compressed", "planned_sharded_compressed")


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("data", "pod") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def make_train_state(cfg: ModelConfig, tc: TrainConfig, key) -> dict:
    api = mapi.get_api(cfg, compute_dtype=_dtype(tc.compute_dtype), remat=tc.remat)
    params = api.init(key, _dtype(tc.param_dtype))
    state = {
        "params": params,
        "opt": adamw_init(params, _dtype(tc.opt_state_dtype)),
        "step": jnp.zeros((), jnp.int32),
    }
    if tc.compress_pod_axis or tc.sync_algorithm in COMPRESSED_ALGOS:
        state["ef"] = compression.init_ef_state(params)
    return state


def abstract_train_state(cfg: ModelConfig, tc: TrainConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: make_train_state(cfg, tc, k), key)


# ---------------------------------------------------------------------------
# gradient sync (explicit modes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GradSyncPlans:
    """Setup-time product of the amortized planner (DESIGN.md §10): the
    gradient bucket partition plus one schedule choice per (DP axis,
    bucket).  For ``"planned_sharded"`` the monolithic per-axis plan is
    replaced by a reduce-scatter plan and an all-gather plan per (axis,
    bucket) (DESIGN.md §11).

    ``bits`` (the compressed modes, DESIGN.md §15) is the per-bucket wire
    width the planner's compression sweep picked at setup — 32 on buckets
    that declined.  It is frozen for the run: :meth:`SyncController.replan`
    re-plans *strategies* under the frozen widths so the traced step's
    quantization graph never changes (no retrace)."""

    spec: bucketing.BucketSpec
    plans: dict[str, tuple[planner.Plan, ...]]   # DP axis -> per-bucket plan
    rs_plans: dict[str, tuple[planner.Plan, ...]] | None = None
    ag_plans: dict[str, tuple[planner.Plan, ...]] | None = None
    bits: tuple[int, ...] | None = None          # per-bucket wire width


def _plan_axis_with_bits(size, bucket_bytes, bits, cost, backend, failures,
                         collective: str = "allreduce", depth: int = 1):
    """Plan one DP axis's buckets at *fixed* per-bucket wire widths by
    grouping buckets of equal width into one batched planner call each —
    the frozen-bits path of a compressed re-plan (widths never re-swept)."""
    out: list = [None] * len(bucket_bytes)
    groups: dict[int, list[int]] = {}
    for i, w in enumerate(bits):
        groups.setdefault(int(w), []).append(i)
    for w, idx in groups.items():
        sub = planner.plan_buckets(
            size, [bucket_bytes[i] for i in idx], cost, backend=backend,
            collective=collective, failures=failures, depth=depth, bits=w)
        for i, pl in zip(idx, sub):
            out[i] = pl
    return tuple(out)


def plan_gradient_sync(grads, tc: TrainConfig, mesh,
                       cost: planner.CostParams | None = None,
                       backend: str = "analytic",
                       sharded: bool = False,
                       failures=None,
                       depth: int = 1,
                       compress: bool = False,
                       bits_overrides=None) -> GradSyncPlans:
    """Partition the gradient pytree into size-capped buckets and plan every
    bucket's schedule for every DP axis in one batched planner call.

    ``grads`` may be abstract (``jax.ShapeDtypeStruct`` leaves) — only
    shapes/dtypes are read, so ``make_train_step`` runs this once at setup
    instead of re-planning inside every trace.  Bucket bytes are counted in
    the wire dtype (``tc.sync_dtype``), matching what each collective
    actually moves.

    ``sharded=True`` plans the ``"planned_sharded"`` mode: per (DP axis,
    bucket), a ``reduce_scatter`` plan for the way down and an
    ``all_gather`` plan for the way back up (DESIGN.md §11) — the
    all-gather sees the shard left by every axis *inside* it, so its byte
    count shrinks by the already-scattered factors, exactly what
    ``_sharded_sync_axes`` executes.

    ``failures`` re-plans every (axis, bucket) choice against a degraded
    ring (:class:`~repro.core.topology.FailureMask`, DESIGN.md §12) — the
    online re-plan path (:class:`SyncController`) calls back in here with
    the mask the watchdog/injector reported.

    ``depth > 1`` (``"planned_pipelined"``) costs each reduce-scatter plan
    against its composed RS+AG interleaving (``core.compose``, DESIGN.md
    §13): winning buckets carry ``detail["pipeline"]`` with the measured
    composed-vs-serial gain, and their ``cost_s`` is the amortized
    per-constituent share of the composed total.

    ``compress=True`` (the ``*_compressed`` modes, DESIGN.md §15) sweeps
    each bucket over ``tc.compress_bits`` wire widths on the *first* DP
    axis (the outermost sync level, which moves the most bytes), freezes
    the winning width per bucket — ``GradSyncPlans.bits`` — and plans every
    remaining axis/phase at those fixed widths, since a bucket is quantized
    once before its first collective and stays compressed on the wire
    through all levels.  ``bits_overrides`` skips the sweep and plans at
    the given per-bucket widths — the re-plan path, which must keep the
    widths the traced step was compiled with.
    """
    spec = bucketing.plan_buckets(grads, tc.bucket_bytes)
    itemsize = jnp.dtype(_dtype(tc.sync_dtype)).itemsize
    bucket_bytes = [s * itemsize for s in spec.bucket_sizes]
    axes = dp_axes_of(mesh)
    bits = tuple(int(w) for w in bits_overrides) if bits_overrides else None
    if not sharded:
        if not compress and bits is None:
            plans = {
                ax: tuple(planner.plan_buckets(mesh.shape[ax], bucket_bytes,
                                               cost, backend=backend,
                                               failures=failures))
                for ax in axes
            }
            return GradSyncPlans(spec, plans)
        plans = {}
        for ax in axes:
            if bits is None:
                swept = planner.plan_buckets(
                    mesh.shape[ax], bucket_bytes, cost, backend=backend,
                    failures=failures,
                    bits_candidates=tuple(tc.compress_bits))
                bits = tuple(int(p.detail.get("bits", 32)) for p in swept)
                plans[ax] = tuple(swept)
            else:
                plans[ax] = _plan_axis_with_bits(
                    mesh.shape[ax], bucket_bytes, bits, cost, backend,
                    failures)
        return GradSyncPlans(spec, plans, bits=bits)
    rs_plans, ag_plans = {}, {}
    shard_bytes = list(bucket_bytes)
    for ax in axes:
        size = mesh.shape[ax]
        if compress and bits is None:
            swept = planner.plan_buckets(
                size, shard_bytes, cost, backend=backend,
                collective="reduce_scatter", failures=failures, depth=depth,
                bits_candidates=tuple(tc.compress_bits))
            bits = tuple(int(p.detail.get("bits", 32)) for p in swept)
            rs_plans[ax] = tuple(swept)
        elif bits is not None:
            rs_plans[ax] = _plan_axis_with_bits(
                size, shard_bytes, bits, cost, backend, failures,
                collective="reduce_scatter", depth=depth)
        else:
            rs_plans[ax] = tuple(planner.plan_buckets(
                size, shard_bytes, cost, backend=backend,
                collective="reduce_scatter", failures=failures, depth=depth))
        if bits is not None:
            ag_plans[ax] = _plan_axis_with_bits(
                size, shard_bytes, bits, cost, backend, failures,
                collective="all_gather")
        else:
            ag_plans[ax] = tuple(planner.plan_buckets(
                size, shard_bytes, cost, backend=backend,
                collective="all_gather", failures=failures))
        shard_bytes = [b / size for b in shard_bytes]
    return GradSyncPlans(spec, {}, rs_plans=rs_plans, ag_plans=ag_plans,
                         bits=bits)


def _dispatch_planned(flat, axis, size, plan: planner.Plan):
    """Run one bucket's planned schedule on one DP axis."""
    if plan.strategy == "flat":
        return lax.psum(flat, axis)
    if plan.strategy == "rd":
        return C.allreduce_rd(flat, axis, size)
    if plan.strategy == "wrht_tree":
        return C.allreduce_wrht_tree(
            flat, axis, size, m=plan.m,
            alltoall_max=plan.m if plan.alltoall else None)
    # hier_scatter on one axis == ring reduce-scatter + all-gather
    return C.allreduce_ring(flat, axis, size)


def _dispatch_rs(flat, axis, size, plan: planner.Plan):
    """One bucket's planned reduce-scatter on one DP axis (DESIGN.md §11)."""
    if size == 1:
        return flat
    if plan.strategy == "alltoall":
        return C.reduce_scatter_alltoall(flat, axis, size)
    return C.reduce_scatter_ring(flat, axis, size)


def _dispatch_ag(shard, axis, size, plan: planner.Plan):
    """One bucket's planned all-gather on one DP axis (DESIGN.md §11)."""
    if size == 1:
        return shard
    if plan.strategy == "alltoall":
        return C.all_gather_alltoall(shard, axis, size)
    return C.all_gather_ring(shard, axis, size)


# ---------------------------------------------------------------------------
# online re-plan (DESIGN.md §12): traced strategy codes + SyncController
# ---------------------------------------------------------------------------

# the planned_sharded strategy menu per (axis, bucket, phase) is exactly
# {ring pass, single-step all-to-all}; encoding the choice as a traced int32
# makes the jitted step a *function of the plan*, so a mid-run re-plan swaps
# schedules by feeding new arrays — never by retracing
STRAT_RING = 0
STRAT_ALLTOALL = 1


def _plan_code(plan: planner.Plan) -> int:
    return STRAT_ALLTOALL if plan.strategy == "alltoall" else STRAT_RING


def _dispatch_rs_dyn(flat, axis, size, code):
    """Traced-code twin of :func:`_dispatch_rs` — both branches are traced
    once, the running plan picks at execution time.  The code array is
    replicated across devices, so every device takes the same branch."""
    if size == 1:
        return flat
    return lax.cond(code == STRAT_ALLTOALL,
                    lambda x: C.reduce_scatter_alltoall(x, axis, size),
                    lambda x: C.reduce_scatter_ring(x, axis, size),
                    flat)


def _dispatch_ag_dyn(shard, axis, size, code):
    """Traced-code twin of :func:`_dispatch_ag`."""
    if size == 1:
        return shard
    return lax.cond(code == STRAT_ALLTOALL,
                    lambda x: C.all_gather_alltoall(x, axis, size),
                    lambda x: C.all_gather_ring(x, axis, size),
                    shard)


def _sharded_rs_axes(flat, axes, sizes, plans: GradSyncPlans, i,
                     codes=None):
    """The way down of the sharded sync (DESIGN.md §11): reduce-scatter
    bucket ``i`` over every DP axis, innermost first.  Returns the owned
    shard plus the pre-scatter lengths the all-gather needs to slice
    padding back off."""
    lengths = []
    for ax in axes:
        lengths.append(flat.shape[0])
        if codes is not None:
            flat = _dispatch_rs_dyn(flat, ax, sizes[ax], codes[f"rs:{ax}"][i])
        else:
            flat = _dispatch_rs(flat, ax, sizes[ax], plans.rs_plans[ax][i])
    return flat, lengths


def _sharded_ag_axes(flat, lengths, axes, sizes, plans: GradSyncPlans, i,
                     codes=None):
    """The way back up: all-gather bucket ``i``'s shard over the DP axes in
    reverse, slicing each level back to the length it scattered (the ring
    bodies pad internally)."""
    for ax, length in zip(reversed(axes), reversed(lengths)):
        if codes is not None:
            flat = _dispatch_ag_dyn(flat, ax, sizes[ax], codes[f"ag:{ax}"][i])
        else:
            flat = _dispatch_ag(flat, ax, sizes[ax], plans.ag_plans[ax][i])
        flat = flat[:length]
    return flat


def _sharded_sync_axes(flat, axes, sizes, plans: GradSyncPlans, i,
                       codes=None):
    """RS down the DP axes, AG back up: between the phases every device
    holds only its owned shard of the bucket (ZeRO-style, DESIGN.md §11).

    ``codes`` (the :meth:`SyncController.arrays` pytree) switches bucket
    dispatch to the traced strategy codes — the no-retrace re-plan path.

    ``"planned_pipelined"`` runs the same two halves but staggered across
    buckets (:func:`bucketing.bucketed_apply_pipelined`), so per-bucket
    numerics are identical between the two modes."""
    flat, lengths = _sharded_rs_axes(flat, axes, sizes, plans, i, codes=codes)
    return _sharded_ag_axes(flat, lengths, axes, sizes, plans, i, codes=codes)


class SyncController:
    """Online re-planner for the ``planned_sharded`` / ``planned_pipelined``
    gradient sync (DESIGN.md §12).

    Owns the current :class:`GradSyncPlans` and publishes it as a pytree of
    replicated int32 *strategy-code* arrays (one per DP axis and phase,
    indexed by bucket).  The jitted train step takes that pytree as a traced
    argument, so :meth:`replan` — invoked by the trainer when the watchdog
    or injector reports a :class:`~repro.core.topology.FailureMask` — swaps
    every (axis, bucket) schedule by re-running the planner under the mask
    and feeding the new arrays into the *already-compiled* step.  No
    retrace: the arrays' shapes and dtypes never change.

    ``last_replan_s`` records the wall-clock planner latency of the most
    recent re-plan (what ``benchmarks/bench_degraded.py`` reports).

    Plans are memoized per mask fingerprint (a small LRU over
    :class:`GradSyncPlans`), so a *recovery* replan — the fault-management
    loop shrinking the mask back toward healthy (DESIGN.md §14) — reuses
    the already-computed plan instead of re-running the planner:
    ``last_replan_cached`` reports whether the most recent :meth:`replan`
    was such a hit.
    """

    MEMO_CAP = 8

    def __init__(self, abstract_grads, tc: TrainConfig, mesh,
                 cost: planner.CostParams | None = None,
                 backend: str = "analytic") -> None:
        self._grads = abstract_grads
        self._tc = tc
        self._mesh = mesh
        self._cost = cost
        self._backend = backend
        # planned_pipelined plans each bucket against its composed RS+AG
        # interleaving (DESIGN.md §13); planned_sharded costs serially
        self.depth = (tc.pipeline_depth
                      if tc.sync_algorithm == "planned_pipelined" else 1)
        # compressed mode: sweep per-bucket wire widths once here; every
        # re-plan below re-picks strategies at these *frozen* widths so the
        # compiled step's quantization graph is untouched (DESIGN.md §15)
        self.compress = tc.sync_algorithm in COMPRESSED_ALGOS
        self.failures = None
        self.last_replan_s: float | None = None
        self.last_replan_cached = False
        self.replan_count = 0
        self.plans = plan_gradient_sync(abstract_grads, tc, mesh, cost,
                                        backend, sharded=True,
                                        depth=self.depth,
                                        compress=self.compress)
        # seed the memo with the healthy plan: recovery back to the empty
        # mask is always a hit (DESIGN.md §14)
        self._plan_memo = OrderedDict({self._memo_key(None): self.plans})

    @staticmethod
    def _memo_key(failure_mask) -> str:
        return "healthy" if failure_mask is None else failure_mask.fingerprint()

    def arrays(self) -> dict:
        """The current plan as traced jit inputs: ``{"rs:<axis>"|"ag:<axis>"
        -> int32[n_buckets]}`` strategy codes, replicated across devices."""
        enc = {}
        for phase, plans in (("rs", self.plans.rs_plans),
                             ("ag", self.plans.ag_plans)):
            for ax in dp_axes_of(self._mesh):
                enc[f"{phase}:{ax}"] = jnp.asarray(
                    [_plan_code(p) for p in plans[ax]], jnp.int32)
        return enc

    def replan(self, failure_mask=None) -> dict:
        """Re-plan every (DP axis, bucket) schedule under ``failure_mask``
        (``None`` or an empty mask restores the healthy plan) and return the
        new strategy-code arrays.  Raises
        :class:`~repro.core.wrht.DegradedInfeasibleError` when the mask
        leaves no feasible schedule — the previous plan stays installed."""
        if failure_mask is not None and failure_mask.empty:
            failure_mask = None
        key = self._memo_key(failure_mask)
        t0 = time.perf_counter()
        if key in self._plan_memo:
            plans = self._plan_memo[key]
            self._plan_memo.move_to_end(key)
            self.last_replan_cached = True
        else:
            plans = plan_gradient_sync(self._grads, self._tc, self._mesh,
                                       self._cost, self._backend,
                                       sharded=True, failures=failure_mask,
                                       depth=self.depth,
                                       compress=self.compress,
                                       bits_overrides=(self.plans.bits
                                                       if self.compress
                                                       else None))
            self._plan_memo[key] = plans
            while len(self._plan_memo) > self.MEMO_CAP:
                self._plan_memo.popitem(last=False)
            self.last_replan_cached = False
        self.last_replan_s = time.perf_counter() - t0
        self.plans = plans
        self.failures = failure_mask
        self.replan_count += 1
        return self.arrays()


def _sync_one_axis(flat, axis, size, alg, m):
    if alg == "psum":
        return lax.psum(flat, axis)
    if alg == "ring":
        return C.allreduce_ring(flat, axis, size)
    if alg == "rd":
        return C.allreduce_rd(flat, axis, size)
    if alg == "bt":
        return C.allreduce_bt(flat, axis, size)
    if alg == "wrht":
        return C.allreduce_wrht_tree(flat, axis, size, m=m,
                                     alltoall_max=max(2, m // 2))
    raise ValueError(alg)


def sync_gradients(grads, tc: TrainConfig, mesh, ef_state=None,
                   sync_plans: GradSyncPlans | None = None,
                   plan_codes=None):
    """Explicit gradient sync over the manual DP axes.  Returns (mean grads,
    new_ef_state | None).  Must run inside shard_map (manual DP axes).

    ``sync_plans`` carries the setup-time bucket partition and per-bucket
    schedule choices for the ``"planned"`` mode; when absent they are
    derived on the spot (plan-cache-warm, but re-done per trace).

    ``plan_codes`` (the sharded modes, :data:`SHARDED_ALGOS`) is the traced
    strategy-code
    pytree of :meth:`SyncController.arrays`: bucket dispatch switches to
    ``lax.cond`` on the codes so a re-plan swaps schedules without a
    retrace (DESIGN.md §12)."""
    axes = dp_axes_of(mesh)
    sizes = {a: mesh.shape[a] for a in axes}
    total = math.prod(sizes.values())
    alg = tc.sync_algorithm
    new_ef = None

    if tc.compress_pod_axis and "pod" in axes and ef_state is not None:
        # inner axes with the configured algorithm, pod axis compressed
        inner = tuple(a for a in axes if a != "pod")

        def bucket_fn_inner(flat, nbytes):
            for ax in inner:
                flat = _sync_one_axis(flat, ax, sizes[ax],
                                      alg if alg in ("psum", "ring", "rd", "bt", "wrht") else "psum",
                                      tc.sync_m)
            return flat

        grads = bucketing.bucketed_allreduce(grads, bucket_fn_inner,
                                             tc.bucket_bytes)
        grads, new_ef = compression.ef_allreduce_tree(
            grads, ef_state, "pod", sizes["pod"])
        # ef path returns pod-mean; finish the mean over inner axes
        scale = 1.0 / math.prod(sizes[a] for a in inner) if inner else 1.0
        grads = jax.tree.map(lambda g: g * scale, grads)
        return grads, new_ef

    if alg in ("hier_faithful", "hier_scatter"):
        mode = "faithful" if alg == "hier_faithful" else "scatter"

        def bucket_fn(flat, nbytes):
            return C.hierarchical_allreduce(
                flat, axes, tuple(sizes[a] for a in axes), mode=mode)

    elif alg == "planned":
        plans = sync_plans or plan_gradient_sync(grads, tc, mesh)

        def bucket_fn(flat, nbytes, i):
            for ax in axes:
                flat = _dispatch_planned(flat, ax, sizes[ax],
                                         plans.plans[ax][i])
            return flat

        grads = bucketing.bucketed_apply_indexed(
            grads, bucket_fn, plans.spec, sync_dtype=_dtype(tc.sync_dtype))
        grads = jax.tree.map(lambda g: g / total, grads)
        return grads, new_ef

    elif alg == "planned_compressed":
        plans = sync_plans or plan_gradient_sync(grads, tc, mesh,
                                                 compress=True)
        if ef_state is None:
            ef_state = jax.tree.map(jnp.zeros_like, grads)

        def bucket_fn(flat, nbytes, i):
            for ax in axes:
                flat = _dispatch_planned(flat, ax, sizes[ax],
                                         plans.plans[ax][i])
            return flat

        grads, new_ef = bucketing.bucketed_apply_compressed(
            grads, ef_state, bucket_fn, plans.spec, bits=plans.bits,
            block=tc.compress_block, fused=tc.compress_fused_kernel,
            sync_dtype=_dtype(tc.sync_dtype))
        grads = jax.tree.map(lambda g: g / total, grads)
        return grads, new_ef

    elif alg == "planned_sharded_compressed":
        plans = sync_plans or plan_gradient_sync(grads, tc, mesh,
                                                 sharded=True, compress=True)
        if ef_state is None:
            ef_state = jax.tree.map(jnp.zeros_like, grads)

        def bucket_fn(flat, nbytes, i):
            return _sharded_sync_axes(flat, axes, sizes, plans, i,
                                      codes=plan_codes)

        grads, new_ef = bucketing.bucketed_apply_compressed(
            grads, ef_state, bucket_fn, plans.spec, bits=plans.bits,
            block=tc.compress_block, fused=tc.compress_fused_kernel,
            sync_dtype=_dtype(tc.sync_dtype))
        grads = jax.tree.map(lambda g: g / total, grads)
        return grads, new_ef

    elif alg == "planned_sharded":
        plans = sync_plans or plan_gradient_sync(grads, tc, mesh,
                                                 sharded=True)

        def bucket_fn(flat, nbytes, i):
            return _sharded_sync_axes(flat, axes, sizes, plans, i,
                                      codes=plan_codes)

        grads = bucketing.bucketed_apply_indexed(
            grads, bucket_fn, plans.spec, sync_dtype=_dtype(tc.sync_dtype))
        grads = jax.tree.map(lambda g: g / total, grads)
        return grads, new_ef

    elif alg == "planned_pipelined":
        plans = sync_plans or plan_gradient_sync(
            grads, tc, mesh, sharded=True, depth=tc.pipeline_depth)

        def rs_fn(flat, nbytes, i):
            return _sharded_rs_axes(flat, axes, sizes, plans, i,
                                    codes=plan_codes)

        def ag_fn(shard, lengths, nbytes, i):
            return _sharded_ag_axes(shard, lengths, axes, sizes, plans, i,
                                    codes=plan_codes)

        grads = bucketing.bucketed_apply_pipelined(
            grads, rs_fn, ag_fn, plans.spec, depth=tc.pipeline_depth,
            sync_dtype=_dtype(tc.sync_dtype))
        grads = jax.tree.map(lambda g: g / total, grads)
        return grads, new_ef

    else:
        def bucket_fn(flat, nbytes):
            for ax in axes:
                flat = _sync_one_axis(flat, ax, sizes[ax], alg, tc.sync_m)
            return flat

    grads = bucketing.bucketed_allreduce(grads, bucket_fn, tc.bucket_bytes,
                                         sync_dtype=_dtype(tc.sync_dtype))
    grads = jax.tree.map(lambda g: g / total, grads)
    return grads, new_ef


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------

def _microbatched_grads(loss_fn, params, batch, n_micro: int,
                        accum_dtype=jnp.float32):
    """Gradient accumulation over n_micro splits of the batch leading dim."""
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    mb = jax.tree.map(split, batch)

    def body(carry, mbatch):
        loss_acc, grads_acc = carry
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(accum_dtype), grads_acc, grads)
        return (loss_acc + loss, grads_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (loss_sum, grads), _ = lax.scan(body, (jnp.zeros(()), zeros), mb)
    scale = 1.0 / n_micro
    grads = jax.tree.map(lambda g: g * scale, grads)
    return loss_sum * scale, {}, grads


def make_train_step(cfg: ModelConfig, tc: TrainConfig, mesh=None):
    """Returns a function (state, batch) -> (state, metrics).

    auto mode: call under jit with sharded args.  Manual modes: the returned
    function already wraps shard_map over the DP axes; jit it directly.

    For the sharded modes (``"planned_sharded"`` / ``"planned_pipelined"``)
    the returned function additionally accepts an
    optional third argument ``plan_codes`` — the traced strategy-code pytree
    of :meth:`SyncController.arrays` — and carries the controller as a
    ``.controller`` attribute.  Feeding ``controller.replan(mask)``'s arrays
    into the jitted step swaps every (axis, bucket) schedule without a
    retrace (DESIGN.md §12); omitting the argument keeps the static
    setup-time plan, so existing callers are unchanged.
    """
    api = mapi.get_api(cfg, compute_dtype=_dtype(tc.compute_dtype), remat=tc.remat)
    lr_fn = make_lr_schedule(tc)

    # amortized planning: partition the (abstract) gradients into buckets
    # and plan every bucket's schedule ONCE here — each traced step then
    # just dispatches bucket i to its precomputed plan (DESIGN.md §10)
    sync_plans = None
    controller = None
    if (tc.sync_algorithm in ("planned", "planned_compressed") + SHARDED_ALGOS
            and mesh is not None and dp_axes_of(mesh)):
        g_dtype = _dtype(tc.grad_accum_dtype if tc.microbatches > 1
                         else tc.param_dtype)
        abstract_params = abstract_train_state(cfg, tc)["params"]
        abstract_grads = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, g_dtype), abstract_params)
        if tc.sync_algorithm in SHARDED_ALGOS:
            controller = SyncController(abstract_grads, tc, mesh)
            sync_plans = controller.plans
        else:
            sync_plans = plan_gradient_sync(
                abstract_grads, tc, mesh,
                compress=tc.sync_algorithm == "planned_compressed")

    def loss_fn(params, batch):
        return api.loss(params, batch)

    def step_body(state, batch, plan_codes=None):
        loss, metrics, grads = _microbatched_grads(
            loss_fn, state["params"], batch, tc.microbatches,
            accum_dtype=_dtype(tc.grad_accum_dtype))
        new_ef = None
        if tc.sync_algorithm in MANUAL_ALGOS:
            grads, new_ef = sync_gradients(grads, tc, mesh, state.get("ef"),
                                           sync_plans=sync_plans,
                                           plan_codes=plan_codes)
            loss = lax.pmean(loss, dp_axes_of(mesh))
        lr = lr_fn(state["step"])
        params, opt, om = adamw_update(grads, state["opt"], state["params"], lr, tc)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if "ef" in state:
            new_state["ef"] = new_ef if new_ef is not None else state["ef"]
        return new_state, {"loss": loss, "lr": lr, **om}

    if tc.sync_algorithm not in MANUAL_ALGOS:
        return step_body

    assert mesh is not None, "manual sync modes need the mesh"
    dp = dp_axes_of(mesh)

    def _shard_map(fn, in_specs, out_specs):
        try:
            sm = jax.shard_map
        except AttributeError:  # pre-jax.shard_map fallback
            from jax.experimental.shard_map import shard_map as sm_old

            return sm_old(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=set(dp), check_vma=False)

    # state replicated over DP axes, sharded over 'model' per param rules is
    # delegated to GSPMD ('model' stays an auto axis inside shard_map).
    state_specs = P()   # replicated across manual axes
    batch_spec = P(dp)  # batch leading dim split across manual DP axes

    def batch_specs_tree(batch):
        return jax.tree.map(lambda _: batch_spec, batch)

    def wrapped(state, batch, plan_codes=None):
        if plan_codes is None:
            f = _shard_map(
                step_body,
                in_specs=(state_specs,
                          jax.tree.map(lambda _: batch_spec, batch)),
                out_specs=(state_specs, P()),
            )
            return f(state, batch)
        # the strategy codes ride in replicated (P()) so every device takes
        # the same lax.cond branch — a requirement for the collectives inside
        f = _shard_map(
            step_body,
            in_specs=(state_specs,
                      jax.tree.map(lambda _: batch_spec, batch),
                      jax.tree.map(lambda _: P(), plan_codes)),
            out_specs=(state_specs, P()),
        )
        return f(state, batch, plan_codes)

    wrapped.controller = controller
    return wrapped
