from .pipeline import CorpusLM, SyntheticLM, make_batch_iter, shard_batch  # noqa: F401
