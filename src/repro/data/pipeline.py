"""Host-side data pipeline: deterministic sources, sharded placement, prefetch.

Two sources:
  * ``SyntheticLM`` — deterministic per-step random tokens (throughput /
    dry-run / fault-tolerance tests: batch at step k is a pure function of
    (seed, k), so a restarted run sees identical data).
  * ``CorpusLM`` — a small byte-level corpus with real next-byte structure so
    example training runs show a *decreasing* loss.

``shard_batch`` places host numpy onto the mesh with batch over
('pod','data'); ``make_batch_iter`` adds background-thread prefetch.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_DEFAULT_CORPUS = (
    b"the quick brown fox jumps over the lazy dog. "
    b"all-reduce in optical interconnects reuses wavelengths hierarchically. "
    b"communication time is dominated by the number of steps when the "
    b"reconfiguration delay is large. "
) * 64


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        toks = rng.integers(0, self.vocab_size,
                            (self.global_batch, self.seq_len + 1), dtype=np.int64)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclass
class CorpusLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus: bytes = _DEFAULT_CORPUS

    def __post_init__(self):
        data = np.frombuffer(self.corpus, np.uint8).astype(np.int32)
        self._data = data % self.vocab_size

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        starts = rng.integers(0, len(self._data) - self.seq_len - 1,
                              self.global_batch)
        rows = np.stack([self._data[s : s + self.seq_len + 1] for s in starts])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def shard_batch(batch: dict, mesh=None, extra_specs: dict | None = None) -> dict:
    """Place a host batch on devices, batch-dim over ('pod','data')."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    names = [a for a in ("pod", "data") if a in mesh.axis_names]
    out = {}
    for k, v in batch.items():
        spec = P(tuple(names), *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def make_batch_iter(source, mesh=None, start_step: int = 0, prefetch: int = 2):
    """Background-prefetching iterator over (step, device_batch)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, shard_batch(source.batch(step), mesh)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
