"""Partitioning rules: params / batch / KV-cache PartitionSpec trees.

Rules are matched on the flattened key path (suffix substrings), so every
family's params get TP ('model') on the obvious contraction dims, optional
FSDP/ZeRO-3 ('data') on the other dim, and replication for small leaves.
Leading stacked-layer axes ([L] from scan stacking, [G,E] for zamba groups)
are auto-padded with None — rules describe the *trailing* dims.

KV caches shard batch over ('pod','data') and, because GQA kv-head counts
(2..8) often do not divide the 16-way model axis, fall back to sharding
head_dim over 'model' (always a multiple of 16 here).  The MLA latent cache
shards its latent dim over 'model' (576/16) — without that, DeepSeek-V2's
decode_32k cache alone is 18 GB/chip.
"""

from __future__ import annotations

import re
from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# (pattern, trailing-dims spec builder) — first match wins.
# fsdp -> the data axis or None; tp -> 'model'.


def _param_rules(fsdp):
    tp = "model"
    return [
        # biases first — they must not fall through to the weight rules
        (r"(bq|bk|bv|b_up)$", (tp,)),
        (r"(b_down|bi|bf|conv_b|dt_bias)$", None),
        (r"embed/tok$", (tp, fsdp)),
        (r"embed/unembed$", (fsdp, tp)),
        (r"embed/pos$", (None, tp)),
        (r"enc_pos$", (None, tp)),
        (r"patch_proj$", (fsdp, tp)),
        # MoE stacked experts: EP over model on the expert dim
        (r"router$", (fsdp, None)),
        (r"moe/w_gate$", (tp, fsdp, None)),
        (r"moe/w_up$", (tp, fsdp, None)),
        (r"moe/w_down$", (tp, None, fsdp)),
        # MLA
        (r"wq_a$", (fsdp, None)),
        (r"wq_b$", (None, tp)),
        (r"wkv_a$", (fsdp, None)),
        (r"wkv_b$", (None, tp)),
        # attention / generic projections: output-dim TP for QKV+up,
        # input-dim TP for the down/out projections
        (r"attn/wo$", (tp, fsdp)),
        (r"w_down$", (tp, fsdp)),
        (r"out_proj$", (tp, fsdp)),
        (r"down$", (tp, fsdp)),          # mlstm down
        (r"ff_down$", (tp, fsdp)),
        (r"conv_w$", (tp, None)),
        (r"(wq|wk|wv|w_gate|w_up|up|in_proj|ff_up|wz)$", (fsdp, tp)),
        (r"(wi|wf|wo)$", (fsdp, None)),  # xlstm gate projections [d, H]
        (r"(rz|ro)$", (None, None, None)),
        (r"(ri|rf)$", (None, None)),
        (r".*", None),                   # 1-D scales/biases etc: replicate
    ]


def _spec_for(path: str, ndim: int, rules) -> P:
    for pat, trailing in rules:
        if re.search(pat, path):
            if trailing is None:
                return P()
            t = list(trailing)
            if len(t) > ndim:      # smoke configs may drop dims — replicate
                return P()
            pad = [None] * (ndim - len(t))
            return P(*pad, *t)
    return P()


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def param_partition_specs(params_tree, fsdp_axis: str | None = None):
    """PartitionSpec tree mirroring ``params_tree`` (works on abstract trees)."""
    rules = _param_rules(fsdp_axis)

    def leaf_spec(path, leaf):
        return _spec_for(_path_str(path), len(leaf.shape), rules)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def batch_partition_specs(batch_tree, batch_axes: Sequence[str]):
    ba = tuple(batch_axes)

    def leaf_spec(_, leaf):
        return P(ba, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_tree)


def cache_partition_specs(cfg: ModelConfig, cache_tree, batch_axes: Sequence[str],
                          model_size: int = 16):
    """Decode/prefill cache specs.  Batch dim position differs per family."""
    ba = tuple(batch_axes)
    tp = "model"

    def leaf_spec(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if "c_kv" in p:        # [L?, B, S, r] — shard latent over model
            pad = [None] * (nd - 3)
            r = leaf.shape[-1]
            return P(*pad, ba, None, tp if r % model_size == 0 else None)
        if "k_rope" in p:      # [L?, B, S, 1, dr]
            pad = [None] * (nd - 4)
            dr = leaf.shape[-1]
            return P(*pad, ba, None, None, tp if dr % model_size == 0 else None)
        if re.search(r"(^|/)(k|v)$", p) or "self/" in p or "cross/" in p:
            # attention KV: [L?, B, S, K, hd]
            pad = [None] * (nd - 4)
            kh, hd = leaf.shape[-2], leaf.shape[-1]
            if kh % model_size == 0:
                return P(*pad, ba, None, tp, None)
            if hd % model_size == 0:
                return P(*pad, ba, None, None, tp)
            return P(*pad, ba, None, None, None)
        if "mamba/ssm" in p:   # [G, E, B, H, N, Pd]
            h = leaf.shape[3]
            return P(None, None, ba, tp if h % model_size == 0 else None, None, None)
        if "mamba/conv" in p:  # [G, E, B, W-1, C]
            c = leaf.shape[-1]
            return P(None, None, ba, None, tp if c % model_size == 0 else None)
        if "slstm" in p or "mlstm" in p:
            # tuples [pairs, B, ...]: batch at dim 1
            return P(None, ba, *([None] * (nd - 2)))
        # fallback: assume batch at dim 1 when stacked, dim 0 otherwise
        if nd >= 2:
            return P(None, ba, *([None] * (nd - 2)))
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)
