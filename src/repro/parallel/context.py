"""Process-global mesh context + activation sharding constraints.

Launch code installs the mesh once (``set_mesh``); model code calls
``constrain(x, *axes)`` freely — it is a no-op when no mesh is installed
(CPU smoke tests) or when a named axis is absent from the installed mesh
(e.g. 'pod' on the single-pod mesh).
"""

from __future__ import annotations

from jax.sharding import Mesh, PartitionSpec as P

import jax

_MESH: Mesh | None = None

# canonical logical axes
BATCH = "__batch__"   # maps to ("pod", "data") when present
MODEL = "__model__"   # maps to ("model",)

# sequence parallelism: when enabled, layer-boundary activations shard their
# sequence dim over 'model' (GSPMD then lowers the Megatron-TP all-reduces
# to reduce-scatter + all-gather and shards the norm/residual compute)
_SEQUENCE_PARALLEL = False


def set_sequence_parallel(on: bool) -> None:
    global _SEQUENCE_PARALLEL
    _SEQUENCE_PARALLEL = on


def constrain_acts(x):
    """Layer-boundary activation constraint [B, S, d]."""
    if _SEQUENCE_PARALLEL:
        return constrain(x, BATCH, MODEL, None)
    return constrain(x, BATCH, None, None)


def set_mesh(mesh: Mesh | None) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Mesh | None:
    return _MESH


def _resolve(axis) -> tuple[str, ...] | None:
    if _MESH is None:
        return None
    names = set(_MESH.axis_names)
    if axis == BATCH:
        return tuple(a for a in ("pod", "data") if a in names) or None
    if axis == MODEL:
        return ("model",) if "model" in names else None
    if axis is None:
        return None
    if isinstance(axis, str):
        return (axis,) if axis in names else None
    got = tuple(a for a in axis if a in names)
    return got or None


def spec(*axes) -> P:
    """Build a PartitionSpec resolving logical axes against the mesh."""
    return P(*[_resolve(a) for a in axes])


def model_axis_size() -> int:
    if _MESH is None or "model" not in _MESH.axis_names:
        return 1
    return _MESH.shape["model"]


def _manual_axes() -> frozenset[str]:
    """Mesh axes currently under manual shard_map control (must be omitted
    from sharding constraints issued by model code running inside)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        return frozenset(am.manual_axes)
    except Exception:
        return frozenset()


def constrain(x, *axes):
    """with_sharding_constraint against the installed mesh (no-op without).

    Transparently drops axes that are manual in the enclosing shard_map —
    the same model code runs under pure GSPMD ("auto" sync) and inside the
    manual-DP region (explicit WRHT sync)."""
    if _MESH is None:
        return x
    manual = _manual_axes()
    resolved = []
    for a in axes:
        r = _resolve(a)
        if r is not None:
            r = tuple(n for n in r if n not in manual) or None
        resolved.append(r)
    spec = P(*resolved)
    try:
        # bare PartitionSpec resolves against the context (abstract) mesh —
        # required inside shard_map, where axis types are Manual and a
        # NamedSharding over the Auto-typed concrete mesh would mismatch
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(_MESH, spec))
