from .checkpointer import Checkpointer, load_latest  # noqa: F401
