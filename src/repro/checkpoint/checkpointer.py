"""Sharded, integrity-checked, async-capable checkpointing with elastic reshard.

Layout per step:  <dir>/step_<k>/
    manifest.json      {step, leaf paths, shapes, dtypes, crc32 per leaf, flat hash}
    arrays.npz         one entry per leaf (host-gathered)

Restore takes a *target* mesh + sharding-spec tree: leaves are device_put
with the new sharding, so a checkpoint written on a (16,16) mesh restores
onto (2,16,16) or a shrunken (8,16) mesh unchanged — the elastic-scaling
path (tested in tests/test_checkpoint.py).

Async save: the host gather happens synchronously (cheap vs. training step),
the compression+fsync happens on a background thread; ``wait()`` joins.
Retention keeps the newest ``keep_n`` steps, never deleting a step that has
not finished writing (crash-safe: a step directory is published by renaming
``_tmp_step_<k>`` -> ``step_<k>`` after fsync).
"""

from __future__ import annotations

import json
import os
import re
import threading
import zlib
from pathlib import Path

import numpy as np

import jax
from jax.sharding import NamedSharding


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep_n: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        paths, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        manifest = {
            "step": step,
            "leaves": [
                {
                    "path": p,
                    "shape": list(a.shape),
                    "dtype": str(a.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes()),
                }
                for p, a in zip(paths, host)
            ],
        }

        def write():
            tmp = self.dir / f"_tmp_step_{step}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / "arrays.npz", **{p: a for p, a in zip(paths, host)})
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = self.dir / f"step_{step}"
            if final.exists():
                import shutil

                shutil.rmtree(final)
            tmp.rename(final)
            self._retain()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep_n]:
            import shutil

            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, target_tree, mesh=None, spec_tree=None,
                strict_crc: bool = True):
        """Restore into the structure of ``target_tree`` (a pytree of arrays
        or ShapeDtypeStructs).  With ``mesh``+``spec_tree``: device_put each
        leaf with the (possibly different-mesh) sharding — elastic restore."""
        d = self.dir / f"step_{step}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        data = np.load(d / "arrays.npz")
        by_path = {e["path"]: e for e in manifest["leaves"]}

        paths, leaves, treedef = _flatten_with_paths(target_tree)
        specs = None
        if spec_tree is not None:
            # PartitionSpec is a pytree leaf; structures must match
            specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: x is None)
            if len(specs) != len(leaves):
                raise ValueError("spec_tree structure does not match target_tree")

        out = []
        for i, (p, proto) in enumerate(zip(paths, leaves)):
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p}")
            arr = data[p]
            ent = by_path[p]
            if strict_crc:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != ent["crc32"]:
                    raise IOError(f"crc mismatch for {p} (corrupt checkpoint)")
            if tuple(arr.shape) != tuple(proto.shape):
                raise ValueError(
                    f"shape mismatch for {p}: ckpt {arr.shape} vs target {proto.shape}")
            if mesh is not None and specs is not None:
                out.append(jax.device_put(arr, NamedSharding(mesh, specs[i])))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out)


def load_latest(directory: str | Path, target_tree, mesh=None, spec_tree=None):
    ckpt = Checkpointer(directory)
    steps = ckpt.steps()
    if not steps:
        return None, -1
    step = steps[-1]
    return ckpt.restore(step, target_tree, mesh=mesh, spec_tree=spec_tree), step
