"""Two-tier plan cache: WRHT schedules + compiled timing profiles.

The amortized planning layer of DESIGN.md §10.  A network plan is a
first-class, reused artifact (TopoOpt's thesis): the expensive part of
planning — building a collective schedule and compiling it to a
:class:`~repro.core.timing.ScheduleProfile` — depends only on the
*d-independent structure* ``(collective, n, w, m, alltoall, max_hops,
rwa)``, never on the payload size, so one cache entry serves every bucket
size, every ``OpticalParams`` flavour and every timing mode.  Since PR 5
the key carries the *collective* (DESIGN.md §11) — schedules of different
collectives never mix, and the :data:`SCHEMA_VERSION` bump makes every
pre-collective on-disk artifact invisible.

Two tiers:

* **memory** — an in-process LRU of ``(schedule, profile)`` pairs, the
  successor of the ad-hoc ``functools.lru_cache`` wrappers that used to
  live in ``simulator._cached_wrht_schedule`` and ``timing._wrht_profile``
  (both now delegate here).
* **disk** — an optional ``.npz`` artifact per key (JSON metadata + the
  profile's stacked arrays), so a planning server restart — or a training
  job re-launch — skips both build and compile.  Every artifact carries a
  :data:`SCHEMA_VERSION` stamp in its filename *and* metadata; entries
  written under any other version are invisible (invalidation by version
  bump, never by mutation).

Build/validation contract: ``schedule(key)`` always returns a **fully
validated** schedule (``wrht.build_schedule(validate=True)``).  Profiles
may additionally be *published* by the batched auto-tuner
(:func:`~repro.core.timing.tune_wrht` → :meth:`PlanCache.put_profile`);
those are compiled from the batched builder's construction, which is
golden-tested bit-identical to the validated per-candidate path.
"""

from __future__ import annotations

import json
import os
import zipfile
from collections import OrderedDict
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from . import wrht
from .topology import FailureMask, Ring

# v5: PlanKey gained the `bits` wire-width axis (DESIGN.md §15) — a
# compressed plan's profile carries width-scaled payload classes, so an
# int8 profile can never be served for an fp32 key or vice versa.  v4
# artifacts (no bits stamp) are invisible under v5, as v3 (no depth stamp)
# were under v4, v2 (no mask stamp) under v3 and v1 (pre-collective)
# under v2.
SCHEMA_VERSION = 5


@dataclass(frozen=True)
class PlanKey:
    """The d-independent identity of one scheduled-collective plan.

    ``m=None`` means the builder's default fan-out (Lemma 1 capped by the
    hop budget); ``max_hops=None`` means no insertion-loss constraint.
    ``collective`` names the scheduled collective (``wrht.COLLECTIVES``);
    callers should normalize ``(m, alltoall)`` through
    :func:`~repro.core.wrht.collective_plan_fields` so keys never fragment
    on axes a collective does not have.  ``failures`` is the
    :class:`~repro.core.topology.FailureMask` the plan routes around
    (``None`` = healthy ring); the mask is canonical and hashable, so it
    rides in the key directly and its :meth:`fingerprint` stamps the
    artifact filename.  ``depth`` is the pipeline depth (DESIGN.md §13):
    ``depth=1`` is the plain collective; ``depth>1`` caches the *composed*
    schedule of the depth-k pipeline (``collective`` alternating with its
    partner phase — RS↔AG — via ``compose.build_pipeline_schedule``).
    ``bits`` is the wire width per element (DESIGN.md §15): the schedule
    *structure* is width-independent, but the cached profile's payload
    classes are width-scaled, so compressed and full-precision plans never
    share an entry or an artifact.
    """

    n: int
    w: int
    m: int | None = None
    alltoall: bool = True
    max_hops: int | None = None
    rwa: str = "fast"
    collective: str = "allreduce"
    failures: FailureMask | None = None
    depth: int = 1
    bits: int = 32

    def __post_init__(self) -> None:
        # an empty mask IS the healthy ring — normalize so both spellings
        # land on one cache entry and one artifact
        if self.failures is not None and self.failures.empty:
            object.__setattr__(self, "failures", None)
        if self.depth < 1:
            raise ValueError("pipeline depth must be >= 1")
        if self.bits < 1 or self.bits > 32:
            raise ValueError("wire width must satisfy 1 <= bits <= 32")

    def failure_fingerprint(self) -> str:
        return "ok" if self.failures is None else self.failures.fingerprint()

    def filename(self) -> str:
        m = "auto" if self.m is None else str(self.m)
        h = "inf" if self.max_hops is None else str(self.max_hops)
        return (f"{self.collective}-n{self.n}-w{self.w}-m{m}"
                f"-a2a{int(self.alltoall)}-H{h}-{self.rwa}"
                f"-F{self.failure_fingerprint()}-D{self.depth}"
                f"-B{self.bits}.v{SCHEMA_VERSION}.npz")

    def meta(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "n": self.n, "w": self.w, "m": self.m,
            "alltoall": self.alltoall, "max_hops": self.max_hops,
            "rwa": self.rwa, "collective": self.collective,
            "failure_fingerprint": self.failure_fingerprint(),
            "failures": (None if self.failures is None
                         else self.failures.to_lists()),
            "depth": self.depth,
            "bits": self.bits,
        }


@dataclass
class CacheStats:
    """Hit/miss accounting: every ``schedule()``/``profile()`` lookup
    increments exactly one of the first three counters."""

    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    evictions: int = 0
    disk_writes: int = 0

    @property
    def hits(self) -> int:
        return self.memory_hits + self.disk_hits

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def snapshot(self) -> "CacheStats":
        """Frozen copy of the current counters — pair with :meth:`delta` to
        measure one operation's cache traffic (the recovery-replan tests
        assert a shrinking mask is a pure hit, DESIGN.md §14)."""
        return CacheStats(self.memory_hits, self.disk_hits, self.misses,
                          self.evictions, self.disk_writes)

    def delta(self, since: "CacheStats") -> "CacheStats":
        """Counter increments since ``since`` (an earlier :meth:`snapshot`)."""
        return CacheStats(self.memory_hits - since.memory_hits,
                          self.disk_hits - since.disk_hits,
                          self.misses - since.misses,
                          self.evictions - since.evictions,
                          self.disk_writes - since.disk_writes)


class PlanCache:
    """Two-tier (memory LRU + optional disk) cache of WRHT plans."""

    def __init__(self, capacity: int = 1024,
                 disk_dir: str | os.PathLike | None = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.disk_dir = Path(disk_dir) if disk_dir is not None else None
        if self.disk_dir is not None:
            self.disk_dir.mkdir(parents=True, exist_ok=True)
        self.stats = CacheStats()
        # key -> {"schedule": WRHTSchedule | None, "profile": Profile | None}
        self._entries: "OrderedDict[PlanKey, dict]" = OrderedDict()

    # ------------------------------------------------------------------
    # memory tier
    # ------------------------------------------------------------------

    def _touch(self, key: PlanKey) -> dict:
        entry = self._entries.get(key)
        if entry is None:
            entry = {"schedule": None, "profile": None}
            self._entries[key] = entry
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
        else:
            self._entries.move_to_end(key)
        return entry

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    # ------------------------------------------------------------------
    # lookups
    # ------------------------------------------------------------------

    def _build_schedule(self, key: PlanKey):
        # payload-independent structure (the bits_override / payload-class
        # convention): build with d=1 and fully validate, exactly like the
        # historical simulator._cached_wrht_schedule.  depth>1 keys build
        # the composed pipeline (DESIGN.md §13): constituents are fully
        # validated, then interleaved with fused RWA; the composed result
        # is structurally validated (conflict-free fused batches, every
        # constituent step present in order).
        if key.depth > 1:
            from . import compose

            composed = compose.build_pipeline_schedule(
                key.collective, key.n, key.w, 1.0, key.depth, m=key.m,
                allow_alltoall=key.alltoall, validate=True, rwa=key.rwa,
                max_hops=key.max_hops, failures=key.failures,
            )
            compose.validate_composed(composed)
            return composed
        return wrht.build_collective_schedule(
            key.collective, key.n, key.w, 1.0, m=key.m,
            allow_alltoall=key.alltoall, validate=True, rwa=key.rwa,
            max_hops=key.max_hops, failures=key.failures,
        )

    def _schedule_nostat(self, key: PlanKey):
        entry = self._touch(key)
        if entry["schedule"] is None:
            entry["schedule"] = self._build_schedule(key)
        return entry["schedule"]

    def schedule(self, key: PlanKey):
        """The validated schedule for ``key`` (build + store on miss):
        a :class:`~repro.core.wrht.WRHTSchedule`, or a
        :class:`~repro.core.compose.ComposedSchedule` for depth>1 keys."""
        entry = self._touch(key)
        if entry["schedule"] is not None:
            self.stats.memory_hits += 1
        else:
            self.stats.misses += 1
            entry["schedule"] = self._build_schedule(key)
        return entry["schedule"]

    def peek_profile(self, key: PlanKey):
        """The cached profile for ``key`` — memory tier then disk tier —
        or ``None`` without building anything.  The batched tuner peeks
        before compiling so a restarted process with a disk tier skips both
        build and compile for every candidate it has seen."""
        entry = self._touch(key)
        if entry["profile"] is not None:
            self.stats.memory_hits += 1
            return entry["profile"]
        prof = self._disk_load(key)
        if prof is not None:
            self.stats.disk_hits += 1
            entry["profile"] = prof
            return prof
        self.stats.misses += 1
        return None

    def profile(self, key: PlanKey):
        """The compiled :class:`~repro.core.timing.ScheduleProfile` for
        ``key``: memory tier, then disk tier, then build + compile."""
        from . import timing

        prof = self.peek_profile(key)
        if prof is not None:
            return prof
        sched = self._schedule_nostat(key)
        ring = Ring(max(key.n, 2), key.w)
        if key.depth > 1:
            # composed pipeline: the fused step list compiles through the
            # same profile machinery with the union of the constituents'
            # payload classes (disk round-trip unchanged — the profile
            # arrays are structure-only)
            prof = timing.ScheduleProfile.from_composed(
                sched, ring, validate=False, width_bits=key.bits)
        else:
            # the builder fully validated the schedule; the collective's
            # payload accounting (constant full vector, or d/n chunks for
            # the ring passes and the all-to-all) becomes the profile's
            # payload class, width-scaled by the key's wire bits
            divisors = wrht.COLLECTIVES[key.collective].payload_divisors(
                key.n)
            prof = timing.ScheduleProfile.from_steps(
                sched.steps, ring, validate=False,
                classes=(timing.PayloadClass(divisors, key.bits),))
        self.put_profile(key, prof)
        return prof

    def put_profile(self, key: PlanKey, profile, schedule=None) -> None:
        """Publish a compiled profile (the batched tuner's insertion path);
        written through to the disk tier when one is configured."""
        entry = self._touch(key)
        entry["profile"] = profile
        if schedule is not None:
            entry["schedule"] = schedule
        self._disk_store(key, profile)

    def clear(self) -> None:
        """Drop the memory tier and reset the counters (disk artifacts are
        kept — delete the directory to clear the disk tier)."""
        self._entries.clear()
        self.stats = CacheStats()

    # ------------------------------------------------------------------
    # disk tier
    # ------------------------------------------------------------------

    def _disk_store(self, key: PlanKey, profile) -> None:
        if self.disk_dir is None:
            return
        from . import timing

        meta, arrays = timing.profile_to_arrays(profile)
        meta["key"] = key.meta()
        path = self.disk_dir / key.filename()
        # unique temp name: concurrent writers of the same key (two training
        # jobs sharing one cache dir) must never interleave into one file —
        # whoever replaces last wins, atomically
        tmp = path.with_suffix(f".{os.getpid()}-{os.urandom(4).hex()}.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, meta=np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8), **arrays)
        os.replace(tmp, path)
        self.stats.disk_writes += 1

    def _disk_load(self, key: PlanKey):
        if self.disk_dir is None:
            return None
        path = self.disk_dir / key.filename()
        if not path.exists():
            return None
        from . import timing

        try:
            with np.load(path) as data:
                meta = json.loads(bytes(data["meta"]).decode())
                if meta.get("key", {}).get("schema_version") != SCHEMA_VERSION:
                    return None  # stale schema: invisible, never migrated
                arrays = {k: data[k] for k in data.files if k != "meta"}
        except (OSError, ValueError, KeyError, zipfile.BadZipFile,
                json.JSONDecodeError):
            return None  # unreadable/corrupt artifact: treat as a miss
        return timing.profile_from_arrays(meta, arrays)


# ---------------------------------------------------------------------------
# process-default instance (what simulator/timing delegate to)
# ---------------------------------------------------------------------------

_default: PlanCache | None = None


def get_default() -> PlanCache:
    """The process-wide cache.  The disk tier is off unless the
    ``REPRO_PLAN_CACHE_DIR`` environment variable names a directory."""
    global _default
    if _default is None:
        _default = PlanCache(disk_dir=os.environ.get("REPRO_PLAN_CACHE_DIR"))
    return _default


def set_default(cache: PlanCache | None) -> None:
    global _default
    _default = cache
