"""Gradient compression for cross-pod sync (distributed-optimization trick).

Cross-pod ICI/DCN links are the scarcest bandwidth at 512+ chips, so the
trainer can quantize the pod-axis exchange to int8 with per-bucket scales.
Error feedback (Seide et al. / EF-SGD) keeps SGD unbiased-in-the-limit: the
residual of each step's quantization is added back before the next step's
compression.  The EF accumulator lives in the train state (a pytree mirroring
the gradients).

Exchange pattern: recursive-doubling over the pod axis with quantized
payloads — log2(P) steps, each moving bytes/4 (fp32→int8) per chip, which the
planner's α–β model credits as a 4× β-term reduction on that axis.

Since PR 9 the planned stack consumes this module too: ``ef_compress_blocks``
is the per-bucket, per-block-scale EF step behind
``sync_algorithm="planned_compressed"`` (DESIGN.md §15), optionally backed by
the fused pallas quantize+bucketize kernel in ``kernels/quant.py``.  Bits per
element is a first-class plan axis (``PlanKey.bits``), so the planner — not
this module — decides where compression pays.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class QuantChunk(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # f32 scalar scale


def quantize(x: jax.Array, bits: int = 8) -> QuantChunk:
    """Symmetric linear quantization with a per-tensor scale."""
    qmax = float(2 ** (bits - 1) - 1)
    if x.size == 0:  # zero-size leaves (e.g. depth-0 scan stacks)
        return QuantChunk(x.astype(jnp.int8), jnp.ones((), jnp.float32))
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return QuantChunk(q, scale.astype(jnp.float32))


def dequantize(c: QuantChunk, dtype=jnp.float32) -> jax.Array:
    return c.q.astype(dtype) * c.scale.astype(dtype)


def rd_axis_valid(axis_size: int) -> bool:
    """True iff recursive doubling is defined on this axis (power of two)."""
    return axis_size >= 1 and not (axis_size & (axis_size - 1))


def compressed_allreduce_rd(
    x: jax.Array, axis_name: str, axis_size: int, bits: int = 8
) -> jax.Array:
    """All-reduce with int8-quantized recursive-doubling exchanges.

    Every hop transmits (int8 payload, f32 scale); the local accumulator
    stays full precision.  Bytes on the wire per chip: log2(S) · n/4 of the
    fp32 cost (plus one scalar per hop).

    Only defined on power-of-two axes; callers should check
    :func:`rd_axis_valid` at plan time and route other sizes through
    :func:`compressed_allreduce` (which falls back to the ring RS+AG pass).
    """
    s = axis_size
    if s == 1:
        return x
    if s & (s - 1):
        raise ValueError(
            f"compressed_allreduce_rd requires a power-of-two axis size, "
            f"got {s}; use compressed_allreduce() to route non-power-of-two "
            f"axes through the ring RS+AG path"
        )
    acc = x.astype(jnp.float32)
    for k in range(int(math.log2(s))):
        bit = 1 << k
        perm = [(i, i ^ bit) for i in range(s)]
        q = quantize(acc, bits)
        recv_q = lax.ppermute(q.q, axis_name, perm)
        recv_scale = lax.ppermute(q.scale, axis_name, perm)
        acc = acc + recv_q.astype(jnp.float32) * recv_scale
    return acc.astype(x.dtype)


def compressed_allreduce(
    x: jax.Array, axis_name: str, axis_size: int, bits: int = 8
) -> jax.Array:
    """Compressed all-reduce with eager axis-size routing.

    Power-of-two axes take the quantized recursive-doubling exchange;
    everything else falls back to the ring RS+AG pass
    (:func:`collectives.allreduce_ring`) on the full-precision payload — the
    planned stack's shape, always defined.  The routing decision is made
    here, eagerly, from the static ``axis_size``, so no bare ValueError can
    fire mid-trace.
    """
    if axis_size == 1:
        return x
    if rd_axis_valid(axis_size):
        return compressed_allreduce_rd(x, axis_name, axis_size, bits)
    from . import collectives as C

    return C.allreduce_ring(x, axis_name, axis_size)


def ef_compress(grad: jax.Array, residual: jax.Array, bits: int = 8):
    """Error-feedback step: compress (grad + residual), return the quantized
    value to transmit and the new residual."""
    target = grad + residual
    c = quantize(target, bits)
    deq = dequantize(c, target.dtype)
    return c, target - deq


def init_ef_state(grads: jax.Array | dict) -> jax.Array | dict:
    return jax.tree.map(jnp.zeros_like, grads)


def ef_allreduce_tree(
    grads,
    ef_state,
    axis_name: str,
    axis_size: int,
    bits: int = 8,
):
    """Pytree-level error-feedback compressed all-reduce over one axis.

    Returns (synced_grads, new_ef_state).  Each leaf is compressed with EF,
    exchanged via quantized recursive doubling, and averaged.
    """
    def leaf(g, e):
        c, new_e = ef_compress(g, e, bits)
        deq = dequantize(c, jnp.float32)
        summed = compressed_allreduce(deq, axis_name, axis_size, bits)
        return (summed / axis_size).astype(g.dtype), new_e

    # Unzip over the flattened leaves instead of tree-mapping with
    # ``is_leaf=tuple``: model pytrees whose *leaves* are tuples (or whose
    # containers are) would otherwise be misparsed as (synced, residual)
    # pairs.  flatten/unflatten keeps arbitrary treedefs intact.
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(ef_state)
    outs = [leaf(g, e) for g, e in zip(g_leaves, e_leaves)]
    synced = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return synced, new_ef


def ef_compress_blocks(
    flat: jax.Array,
    residual: jax.Array,
    *,
    bits: int = 8,
    block: int = 1024,
    fused: bool = False,
    interpret: bool | None = None,
):
    """Per-block-scale error-feedback compression of one flat bucket.

    The planned-compressed hot path (DESIGN.md §15): compresses
    ``flat + residual`` with one symmetric scale per ``block`` elements and
    returns ``(deq, new_residual)`` where ``deq`` is the dequantized wire
    value (what the planned collective actually reduces) and
    ``new_residual = target - deq`` feeds the next step's EF accumulator.

    ``fused=True`` routes through the pallas quantize+bucketize kernel
    (``kernels.ops.ef_quantize_bucketize``); the jnp path below is the
    bit-exact fallback and the kernel's oracle shape.  ``bits >= 32`` is the
    identity (no compression, residual zero).
    """
    if bits >= 32 or flat.size == 0:
        return flat, jnp.zeros_like(residual)
    if fused:
        from ..kernels import ops as kops

        _q, _s, deq, new_r, n = kops.ef_quantize_bucketize(
            flat, residual, block=block, bits=bits, interpret=interpret)
        return deq[:n].astype(flat.dtype), new_r[:n].astype(residual.dtype)
    qmax = float(2 ** (bits - 1) - 1)
    n = flat.shape[0]
    pad = (-n) % block
    target = flat.astype(jnp.float32) + residual.astype(jnp.float32)
    tp = jnp.pad(target, (0, pad)) if pad else target
    tb = tp.reshape(-1, block)
    # reciprocal multiply, matching the fused kernel bit-for-bit (quant.py)
    scales = jnp.maximum(jnp.max(jnp.abs(tb), axis=1), 1e-30) * (1.0 / qmax)
    q = jnp.clip(jnp.round(tb / scales[:, None]), -qmax, qmax)
    deq = (q * scales[:, None]).reshape(-1)[:n]
    return deq.astype(flat.dtype), (target - deq).astype(residual.dtype)
