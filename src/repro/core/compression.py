"""Gradient compression for cross-pod sync (distributed-optimization trick).

Cross-pod ICI/DCN links are the scarcest bandwidth at 512+ chips, so the
trainer can quantize the pod-axis exchange to int8 with per-bucket scales.
Error feedback (Seide et al. / EF-SGD) keeps SGD unbiased-in-the-limit: the
residual of each step's quantization is added back before the next step's
compression.  The EF accumulator lives in the train state (a pytree mirroring
the gradients).

Exchange pattern: recursive-doubling over the pod axis with quantized
payloads — log2(P) steps, each moving bytes/4 (fp32→int8) per chip, which the
planner's α–β model credits as a 4× β-term reduction on that axis.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class QuantChunk(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # f32 scalar scale


def quantize(x: jax.Array, bits: int = 8) -> QuantChunk:
    """Symmetric linear quantization with a per-tensor scale."""
    qmax = float(2 ** (bits - 1) - 1)
    if x.size == 0:  # zero-size leaves (e.g. depth-0 scan stacks)
        return QuantChunk(x.astype(jnp.int8), jnp.ones((), jnp.float32))
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return QuantChunk(q, scale.astype(jnp.float32))


def dequantize(c: QuantChunk, dtype=jnp.float32) -> jax.Array:
    return c.q.astype(dtype) * c.scale.astype(dtype)


def compressed_allreduce_rd(
    x: jax.Array, axis_name: str, axis_size: int, bits: int = 8
) -> jax.Array:
    """All-reduce with int8-quantized recursive-doubling exchanges.

    Every hop transmits (int8 payload, f32 scale); the local accumulator
    stays full precision.  Bytes on the wire per chip: log2(S) · n/4 of the
    fp32 cost (plus one scalar per hop).
    """
    s = axis_size
    if s == 1:
        return x
    if s & (s - 1):
        raise ValueError("compressed RD needs a power-of-two axis")
    acc = x.astype(jnp.float32)
    for k in range(int(math.log2(s))):
        bit = 1 << k
        perm = [(i, i ^ bit) for i in range(s)]
        q = quantize(acc, bits)
        recv_q = lax.ppermute(q.q, axis_name, perm)
        recv_scale = lax.ppermute(q.scale, axis_name, perm)
        acc = acc + recv_q.astype(jnp.float32) * recv_scale
    return acc.astype(x.dtype)


def ef_compress(grad: jax.Array, residual: jax.Array, bits: int = 8):
    """Error-feedback step: compress (grad + residual), return the quantized
    value to transmit and the new residual."""
    target = grad + residual
    c = quantize(target, bits)
    deq = dequantize(c, target.dtype)
    return c, target - deq


def init_ef_state(grads: jax.Array | dict) -> jax.Array | dict:
    return jax.tree.map(jnp.zeros_like, grads)


def ef_allreduce_tree(
    grads,
    ef_state,
    axis_name: str,
    axis_size: int,
    bits: int = 8,
):
    """Pytree-level error-feedback compressed all-reduce over one axis.

    Returns (synced_grads, new_ef_state).  Each leaf is compressed with EF,
    exchanged via quantized recursive doubling, and averaged.
    """
    def leaf(g, e):
        c, new_e = ef_compress(g, e, bits)
        deq = dequantize(c, jnp.float32)
        summed = compressed_allreduce_rd(deq, axis_name, axis_size, bits)
        return (summed / axis_size).astype(g.dtype), new_e

    pairs = jax.tree.map(leaf, grads, ef_state)
    synced = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda p: isinstance(p, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda p: isinstance(p, tuple))
    return synced, new_ef
