"""Routing and Wavelength Assignment (RWA) for one communication step.

The paper (Sec. III-C-2) notes that within each WRHT subgroup the
communications must be wavelength-conflict-free, and that classic greedy
assignment (First Fit / Best Fit) suffices because different subgroups never
share ring segments.

The production implementation here is array-based: each directed lightpath is
a ring arc ``(start, hops)`` on one of the two fiber lanes, per-segment
occupancy is a ``uint64`` bitmask (bit λ set iff wavelength λ is busy on that
segment), and First Fit is "OR the masks along the arc, take the lowest clear
bit".  Two further structural facts make it effectively free at scale:

* arcs on the same lane conflict only if they lie in the same *covered run*
  (maximal contiguous union of arcs), so the greedy decomposes exactly into
  independent per-run subproblems — computed with one difference-array sweep;
* WRHT steps consist of hundreds of translated copies of the same subgroup
  pattern, so identical runs (same relative arcs in the same processing
  order) are solved once and the assignment is broadcast to every copy.

Assignment order is longest-path-first with ties broken by input order —
identical to :func:`first_fit_assign_reference` (the original per-object
greedy, kept verbatim), and enforced bit-for-bit by the golden-equivalence
test in ``tests/test_rwa_equivalence.py``.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Sequence

import numpy as np

from .topology import FailureMask, Transfer, TransferBatch, path_segments


class WavelengthConflictError(ValueError):
    pass


class InsertionLossError(ValueError):
    """A lightpath exceeds the insertion-loss hop budget (Sec. III)."""


class FailedResourceError(ValueError):
    """A schedule touches a resource the :class:`FailureMask` marks dead —
    a cut fiber span, a dead per-node wavelength, or a dead transceiver
    (DESIGN.md §12).  Raised by the validators; the degraded builder routes
    around failures so its output never trips this."""


# ---------------------------------------------------------------------------
# Failure-mask enforcement (DESIGN.md §12).
# ---------------------------------------------------------------------------

def _covers_dead_segment(batch: TransferBatch, n: int,
                         failures: FailureMask) -> np.ndarray:
    """Bool per row: the lightpath covers a cut span on its lane."""
    lane, start, hops = batch.arcs(n)
    dead = failures.segment_dead(n)
    if not dead.any():
        return np.zeros(len(batch), dtype=bool)
    # prefix-sum of dead segments per lane -> covered-count per arc in O(1)
    csum = np.concatenate([np.zeros((2, 1)), np.cumsum(dead, axis=1)], axis=1)
    end = start + hops           # may exceed n: arc wraps the origin
    wrap = np.minimum(end - n, n)
    covered = (csum[lane, np.minimum(end, n)] - csum[lane, start]
               + np.where(wrap > 0, csum[lane, np.maximum(wrap, 0)], 0.0))
    return covered > 0


def _uses_dead_transceiver(batch: TransferBatch, n: int,
                           failures: FailureMask) -> np.ndarray:
    """Bool per row: src transmits or dst receives on a dead Tx/Rx lane."""
    lane = batch.arcs(n)[0]
    dead = failures.transceiver_dead(n)
    if not dead.any():
        return np.zeros(len(batch), dtype=bool)
    return dead[batch.src % n, lane] | dead[batch.dst % n, lane]


def validate_failures(transfers, n: int, failures: FailureMask | None,
                      check_wavelengths: bool = True) -> None:
    """Reject any transfer touching a dead resource (DESIGN.md §12).

    Checks, in order: cut fiber spans (path covers a dead ``(lane,
    segment)``), dead transceivers (endpoint adds/drops on a dead lane),
    and — when ``check_wavelengths`` and the batch is assigned — dead
    per-node wavelengths (endpoint adds/drops a dead λ).  Raises
    :exc:`FailedResourceError` on the first offender.
    """
    if failures is None or failures.empty:
        return
    batch = TransferBatch.coerce(transfers)
    if len(batch) == 0:
        return
    bad = _covers_dead_segment(batch, n, failures)
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        raise FailedResourceError(
            f"transfer {int(batch.src[i])}->{int(batch.dst[i])} traverses a "
            f"dead fiber span (lane {int(batch.arcs(n)[0][i])})"
        )
    bad = _uses_dead_transceiver(batch, n, failures)
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        raise FailedResourceError(
            f"transfer {int(batch.src[i])}->{int(batch.dst[i])} uses a dead "
            f"transceiver (lane {int(batch.arcs(n)[0][i])})"
        )
    if check_wavelengths and failures.dead_wavelengths:
        forbid = failures.forbidden_lambda_bits(n)
        lam = batch.wavelength
        for i in range(len(batch)):
            lm = int(lam[i])
            if lm < 0:
                continue
            if ((forbid[int(batch.src[i]) % n] >> lm) & 1
                    or (forbid[int(batch.dst[i]) % n] >> lm) & 1):
                raise FailedResourceError(
                    f"transfer {int(batch.src[i])}->{int(batch.dst[i])} "
                    f"adds/drops dead wavelength {lm}"
                )


def _first_fit_forbidden(batch: TransferBatch, n: int, w: int,
                         failures: FailureMask) -> TransferBatch:
    """First Fit honoring per-node forbidden wavelengths.

    Same processing order as the reference greedy (longest-path-first,
    stable ties), but each transfer's candidate set additionally excludes
    every λ dead at its src or dst.  Per-node forbidden sets break the
    translation-symmetry dedup of the fast path, so this is a plain
    dict-based greedy — degraded operation is rare and schedules are built
    once per plan-cache key, so the cost is immaterial (EXPERIMENTS.md
    §Degraded records it).
    """
    lane, start, hops = batch.arcs(n)
    forbid = failures.forbidden_lambda_bits(n)
    full = (1 << w) - 1
    order = np.argsort(-hops, kind="stable")
    occ: dict[tuple[int, int], int] = {}
    lam = np.empty(len(batch), dtype=np.int64)
    for i in order.tolist():
        l, s, h = int(lane[i]), int(start[i]), int(hops[i])
        used = forbid[int(batch.src[i]) % n] | forbid[int(batch.dst[i]) % n]
        segs = [(l, (s + k) % n) for k in range(h)]
        for key in segs:
            used |= occ.get(key, 0)
        free = ~used & full
        if free == 0:
            raise WavelengthConflictError(
                f"step needs more than the {w} available wavelengths under "
                f"the failure mask (transfer "
                f"{int(batch.src[i])}->{int(batch.dst[i])})"
            )
        lm = (free & -free).bit_length() - 1
        bit = 1 << lm
        for key in segs:
            occ[key] = occ.get(key, 0) | bit
        lam[i] = lm
    return batch.with_wavelengths(lam)


# ---------------------------------------------------------------------------
# Insertion-loss hop budget (physical-layer constraint).
# ---------------------------------------------------------------------------

def validate_hop_budget(transfers, n: int, max_hops: int) -> None:
    """Reject any lightpath longer than the insertion-loss hop budget.

    Vectorized like the conflict check: hop counts come straight from the
    arc representation.  Raises :exc:`InsertionLossError` on the first
    offender (a signal traversing more than ``max_hops`` MRR banks arrives
    below receiver sensitivity — see ``topology.PhysicalParams``).
    """
    batch = TransferBatch.coerce(transfers)
    if len(batch) == 0:
        return
    hops = batch.arcs(n)[2]
    bad = hops > max_hops
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        raise InsertionLossError(
            f"transfer {int(batch.src[i])}->{int(batch.dst[i])} traverses "
            f"{int(hops[i])} segments, exceeding the insertion-loss hop "
            f"budget of {max_hops}"
        )


def split_overlong_arcs(transfers, n: int, max_hops: int) -> list[TransferBatch]:
    """Relay decomposition of a step whose arcs may exceed the hop budget.

    Every lightpath longer than ``max_hops`` is cut into a chain of
    O/E/O-regenerated sub-paths of at most ``max_hops`` segments; the relay
    nodes are the ring nodes ``max_hops`` apart along the original path.
    Sub-path ``k`` of every chain lands in sub-step ``k`` (store-and-forward:
    a relay must finish receiving before it retransmits), so the return value
    is a list of sub-step batches to be scheduled *in order*.  Paths already
    within budget appear only in sub-step 0.

    Wavelengths are reset to unassigned (-1) on every returned batch — the
    caller re-runs RWA per sub-step, since relay chains change the conflict
    structure.
    """
    if max_hops < 1:
        raise ValueError("max_hops must be >= 1")
    batch = TransferBatch.coerce(transfers)
    if len(batch) == 0:
        return [batch]
    hops = batch.arcs(n)[2]
    chain_len = np.maximum(1, -(-hops // max_hops))  # ceil
    out: list[TransferBatch] = []
    for k in range(int(chain_len.max())):
        sel = np.flatnonzero(chain_len > k)
        direction = batch.direction[sel]
        src_k = (batch.src[sel] + k * max_hops * direction) % n
        seg_h = np.minimum(hops[sel] - k * max_hops, max_hops)
        dst_k = (src_k + seg_h * direction) % n
        out.append(TransferBatch(
            src_k, dst_k, direction, batch.bits[sel],
            np.full(sel.size, -1, dtype=np.int64),
        ))
    return out


# ---------------------------------------------------------------------------
# Reference implementation (original greedy, kept as the golden oracle).
# ---------------------------------------------------------------------------

def first_fit_assign_reference(
    transfers: Sequence[Transfer], n: int, w: int
) -> list[Transfer]:
    """Assign wavelengths greedily (First Fit, [18] in the paper).

    Transfers are processed longest-path-first (a standard RWA heuristic:
    long lightpaths are the hardest to place).  Raises if more than ``w``
    wavelengths would be needed.
    """
    # (direction, segment) -> set of wavelengths in use
    occupancy: dict[tuple[int, int], set[int]] = {}

    def segs(t: Transfer) -> list[tuple[int, int]]:
        return [(t.direction, s) for s in path_segments(t.src, t.dst, n, t.direction)]

    order = sorted(range(len(transfers)), key=lambda i: -len(segs(transfers[i])))
    assigned: list[Transfer | None] = [None] * len(transfers)
    for i in order:
        t = transfers[i]
        used = set()
        for key in segs(t):
            used |= occupancy.get(key, set())
        lam = next(l for l in range(w + len(transfers) + 1) if l not in used)
        if lam >= w:
            raise WavelengthConflictError(
                f"step needs wavelength {lam} but only {w} available "
                f"(transfer {t.src}->{t.dst})"
            )
        for key in segs(t):
            occupancy.setdefault(key, set()).add(lam)
        assigned[i] = replace(t, wavelength=lam)
    return [t for t in assigned if t is not None]


# ---------------------------------------------------------------------------
# Vectorized implementation.
# ---------------------------------------------------------------------------

def _solve_first_fit(
    rel_start: list[int],
    hops: list[int],
    w: int,
    seg_count: int,
    circular: bool,
) -> np.ndarray:
    """First-Fit one conflict component, arcs given in processing order.

    ``rel_start``/``hops`` are run-local coordinates: unless ``circular``
    (the run covers the whole ring), every arc is the contiguous slice
    ``[s, s+h)`` of a ``seg_count``-long occupancy array, so the inner OR /
    mark are single NumPy slice ops — O(1) NumPy calls per segment range.
    """
    words = (w + 63) // 64
    occ = np.zeros((words, seg_count), dtype=np.uint64)
    full = (1 << w) - 1
    lam_out = np.empty(len(rel_start), dtype=np.int64)
    for i, (s, h) in enumerate(zip(rel_start, hops)):
        e = s + h
        used = 0
        for j in range(words):
            row = occ[j]
            if e <= seg_count:
                u = int(np.bitwise_or.reduce(row[s:e]))
            else:  # circular run: arc wraps the origin
                u = int(np.bitwise_or.reduce(row[s:])) | int(
                    np.bitwise_or.reduce(row[: e - seg_count])
                )
            used |= u << (64 * j)
        free = ~used & full
        if free == 0:
            raise WavelengthConflictError(
                f"step needs more than the {w} available wavelengths "
                f"(arc start={s} hops={h})"
            )
        lam = (free & -free).bit_length() - 1
        word, bit = divmod(lam, 64)
        mask = np.uint64(1 << bit)
        row = occ[word]
        if e <= seg_count:
            row[s:e] |= mask
        else:
            row[s:] |= mask
            row[: e - seg_count] |= mask
        lam_out[i] = lam
    return lam_out


def _lane_components(
    start: np.ndarray, hops: np.ndarray, n: int
) -> tuple[np.ndarray, np.ndarray, bool]:
    """Label conflict components of same-lane arcs via a coverage sweep.

    Returns ``(comp_id, base, circular)``: per-arc component id, per-component
    base segment (run start, so local coords ``(seg - base) % n`` are
    contiguous), and whether the single run covers the entire ring (only then
    can local arcs wrap).
    """
    diff = np.zeros(n + 1, dtype=np.int64)
    end = start + hops
    wraps = end > n
    np.add.at(diff, start, 1)
    np.add.at(diff, np.where(wraps, n, end), -1)
    if wraps.any():
        diff[0] += int(wraps.sum())
        np.add.at(diff, end[wraps] - n, -1)
    covered = np.cumsum(diff[:n]) > 0
    if covered.all():
        return np.zeros(len(start), dtype=np.int64), np.zeros(1, dtype=np.int64), True
    prev = np.empty_like(covered)
    prev[0] = covered[-1]
    prev[1:] = covered[:-1]
    run_start = covered & ~prev
    ids = np.cumsum(run_start) - 1
    n_runs = int(ids[-1]) + 1
    # a run straddling the origin has its start late in the array; segments
    # before the first run_start belong to it (cumsum gave them id -1)
    ids = np.where(ids < 0, n_runs - 1, ids)
    bases = np.flatnonzero(run_start)
    return ids[start], bases, False


def _assign_arcs_component(
    lane: np.ndarray, start: np.ndarray, hops: np.ndarray,
    n: int, w: int, cache: dict,
) -> np.ndarray:
    """Component path of First Fit on raw arc arrays of ONE step.

    Processing order is longest-first with ties broken by row order — the
    reference greedy's order.  ``cache`` is the translated-component dedup
    table ``(circular, n, w, local starts, hops) -> assignment``; sharing
    one dict across many steps (the batched schedule builder does,
    DESIGN.md §10) — and even across ring sizes and wavelength budgets —
    is sound because the key fully determines the greedy's input.
    """
    t_count = lane.size
    order = np.argsort(-hops, kind="stable")  # longest-first, stable ties
    lam = np.empty(t_count, dtype=np.int64)

    # ---- component labeling per lane (the two fibers never interact) ----
    comp = np.empty(t_count, dtype=np.int64)
    base = np.empty(t_count, dtype=np.int64)
    circular_lane = [False, False]
    next_comp = 0
    for lane_id in (0, 1):
        sel = lane == lane_id
        if not sel.any():
            continue
        ids, bases, circ = _lane_components(start[sel], hops[sel], n)
        comp[sel] = ids + next_comp
        base[sel] = bases[ids]
        circular_lane[lane_id] = circ
        next_comp += len(bases)

    rel = (start - base) % n

    # ---- group arcs by component, preserving global processing order ----
    comp_in_order = comp[order]
    grouped = order[np.argsort(comp_in_order, kind="stable")]
    comp_sorted = comp[grouped]
    bounds = np.flatnonzero(np.r_[True, comp_sorted[1:] != comp_sorted[:-1]])
    bounds = np.append(bounds, t_count)

    # ---- dedupe translated components, solve one representative each ----
    for b, e in zip(bounds[:-1].tolist(), bounds[1:].tolist()):
        members = grouped[b:e]
        rs = rel[members]
        hp = hops[members]
        circ = circular_lane[int(lane[members[0]])]
        key = (circ, n, w, rs.tobytes(), hp.tobytes())
        sub = cache.get(key)
        if sub is None:
            seg_count = n if circ else int((rs + hp).max())
            sub = _solve_first_fit(rs.tolist(), hp.tolist(), w, seg_count, circ)
            cache[key] = sub
        lam[members] = sub
    return lam


def first_fit_assign(
    transfers, n: int, w: int, max_hops: int | None = None,
    failures: FailureMask | None = None,
) -> TransferBatch:
    """Vectorized First Fit: bit-identical to the reference greedy.

    Accepts a :class:`TransferBatch` (or any ``Transfer`` sequence, coerced)
    and returns a new batch with wavelengths assigned.  Raises
    :exc:`WavelengthConflictError` iff the reference would.  When
    ``max_hops`` is given, arcs exceeding the insertion-loss hop budget are
    rejected with :exc:`InsertionLossError` before any assignment (such
    paths must be relayed via :func:`split_overlong_arcs` first).

    With a non-empty ``failures`` mask, routes touching a dead span or
    transceiver are rejected (:exc:`FailedResourceError` — the degraded
    builder must re-route before calling RWA) and the assignment honors
    per-node dead wavelengths via the forbidden-aware greedy.
    """
    batch = TransferBatch.coerce(transfers)
    t_count = len(batch)
    if t_count == 0:
        return batch
    if max_hops is not None:
        validate_hop_budget(batch, n, max_hops)
    if failures is not None and not failures.empty:
        validate_failures(batch, n, failures, check_wavelengths=False)
        if failures.dead_wavelengths:
            return _first_fit_forbidden(batch, n, w, failures)
    lane, start, hops = batch.arcs(n)

    if t_count <= 32:
        # tiny step: component machinery costs more than it saves
        order = np.argsort(-hops, kind="stable")
        lam = np.empty(t_count, dtype=np.int64)
        sel = order.tolist()
        st = [int(start[i]) for i in sel]
        hp = [int(hops[i]) for i in sel]
        ln = [int(lane[i]) for i in sel]
        for lane_id in (0, 1):
            idxs = [k for k, l in enumerate(ln) if l == lane_id]
            if not idxs:
                continue
            sub = _solve_first_fit(
                [st[k] for k in idxs], [hp[k] for k in idxs], w, n, True
            )
            for k, v in zip(idxs, sub.tolist()):
                lam[sel[k]] = v
        return batch.with_wavelengths(lam)

    lam = _assign_arcs_component(lane, start, hops, n, w, {})
    return batch.with_wavelengths(lam)


def first_fit_assign_concat(
    transfers, ptr, n: int, w: int,
    max_hops: int | None = None, cache: dict | None = None,
    failures: FailureMask | None = None,
) -> TransferBatch:
    """First-Fit RWA over concatenated independent steps (DESIGN.md §10).

    ``ptr`` is an int array ``[S+1]`` of offset pointers: rows
    ``[ptr[i], ptr[i+1])`` of ``transfers`` form step ``i``.  Each step is
    assigned independently — wavelength occupancy resets at every pointer
    boundary — so the result is bit-identical to calling
    :func:`first_fit_assign` on each slice (the ≤32-transfer fast path of
    the per-step entry point is a pure shortcut: both routes replay the
    reference greedy, enforced by ``tests/test_rwa_equivalence.py``).

    What the concatenation buys is *sharing*: the dedup table is one dict
    for all steps, and via ``cache`` it can be carried across calls — the
    batched multi-candidate schedule builder reuses one table across every
    candidate's relay sub-steps, and a broadcast step's components are the
    lane-mirrored image of its reduce step's, so the mirror assignments are
    cache hits.

    Memoization happens at two levels, both exploiting ring symmetries:

    * per step and lane, keyed on the translation-normalized arc multiset
      ``((start − start[0]) mod n, hops)`` — the ring is rotation-symmetric
      and its two fiber lanes are independent and interchangeable, so a
      translated (or lane-mirrored) step resolves without touching the
      greedy at all.  Relay chains are the big winner: every interior
      sub-step of a chain set is a translation of the first.
    * per conflict component inside an unseen step (the table
      ``first_fit_assign`` uses within one step).

    A non-empty ``failures`` mask disables both memo levels — per-node dead
    wavelengths break translation symmetry — and each step falls back to
    the forbidden-aware greedy (occupancy still resets at every pointer
    boundary).  Dead spans/transceivers on any route raise
    :exc:`FailedResourceError` up front.
    """
    batch = TransferBatch.coerce(transfers)
    ptr = np.asarray(ptr, dtype=np.int64)
    if ptr.size < 1 or ptr[0] != 0 or ptr[-1] != len(batch):
        raise ValueError("ptr must run from 0 to len(transfers)")
    if len(batch) == 0:
        return batch
    if max_hops is not None:
        validate_hop_budget(batch, n, max_hops)
    if failures is not None and not failures.empty:
        validate_failures(batch, n, failures, check_wavelengths=False)
        if failures.dead_wavelengths:
            lam = np.empty(len(batch), dtype=np.int64)
            for lo, hi in zip(ptr[:-1].tolist(), ptr[1:].tolist()):
                if lo == hi:
                    continue
                sub = TransferBatch(
                    batch.src[lo:hi], batch.dst[lo:hi],
                    batch.direction[lo:hi], batch.bits[lo:hi],
                    batch.wavelength[lo:hi],
                )
                lam[lo:hi] = _first_fit_forbidden(sub, n, w,
                                                  failures).wavelength
            return batch.with_wavelengths(lam)
    lane, start, hops = batch.arcs(n)
    if cache is None:
        cache = {}
    lam = np.empty(len(batch), dtype=np.int64)
    zero_lane: dict[int, np.ndarray] = {}
    for lo, hi in zip(ptr[:-1].tolist(), ptr[1:].tolist()):
        if lo == hi:
            continue
        ln = lane[lo:hi]
        # the two fibers never interact and First Fit is per-lane greedy, so
        # assign each lane of the step on its own (order within a lane is
        # the global longest-first order restricted to it — identical)
        for lane_id in (0, 1):
            sel = np.flatnonzero(ln == lane_id)
            if sel.size == 0:
                continue
            st = start[lo:hi][sel]
            hp = hops[lo:hi][sel]
            rel = (st - st[0]) % n
            key = ("step", n, w, rel.tobytes(), hp.tobytes())
            sub = cache.get(key)
            if sub is None:
                zeros = zero_lane.get(sel.size)
                if zeros is None:
                    zeros = zero_lane[sel.size] = np.zeros(sel.size,
                                                           dtype=np.int64)
                sub = _assign_arcs_component(zeros, st, hp, n, w, cache)
                cache[key] = sub
            lam[lo + sel] = sub
    return batch.with_wavelengths(lam)


def validate_no_conflicts(
    transfers, n: int, w: int, max_hops: int | None = None,
    failures: FailureMask | None = None,
) -> None:
    """Check wavelength-conflict-freedom of an already-assigned step.

    Vectorized: expand every transfer into its directed segments, build
    ``(lane, segment, λ)`` keys, sort, and look for adjacent duplicates.
    With ``max_hops`` set, the insertion-loss hop budget is checked first
    (:exc:`InsertionLossError`); with a non-empty ``failures`` mask, any
    transfer touching a dead span/transceiver/λ is rejected
    (:exc:`FailedResourceError`).
    """
    batch = TransferBatch.coerce(transfers)
    if len(batch) == 0:
        return
    if max_hops is not None:
        validate_hop_budget(batch, n, max_hops)
    if failures is not None and not failures.empty:
        validate_failures(batch, n, failures)
    lam = batch.wavelength
    if (lam < 0).any():
        i = int(np.flatnonzero(lam < 0)[0])
        raise WavelengthConflictError(f"unassigned wavelength on {batch[i]}")
    if (lam >= w).any():
        i = int(np.flatnonzero(lam >= w)[0])
        raise WavelengthConflictError(
            f"wavelength {int(lam[i])} out of range (w={w})"
        )
    lane, start, hops = batch.arcs(n)
    total = int(hops.sum())
    if total == 0:
        return
    tid = np.repeat(np.arange(len(batch)), hops)
    first = np.cumsum(hops) - hops
    offs = np.arange(total) - first[tid]
    seg = (start[tid] + offs) % n
    key = (lane[tid] * n + seg) * (int(lam.max()) + 1) + lam[tid]
    order = np.argsort(key, kind="stable")
    ks = key[order]
    dup = np.flatnonzero(ks[1:] == ks[:-1])
    if dup.size:
        a, b = tid[order[dup[0]]], tid[order[dup[0] + 1]]
        ta, tb = batch[int(a)], batch[int(b)]
        raise WavelengthConflictError(
            f"conflict on dir={ta.direction} "
            f"segment={int(seg[order[dup[0]]])} lambda={ta.wavelength}: "
            f"{ta.src}->{ta.dst} vs {tb.src}->{tb.dst}"
        )


def validate_no_conflicts_reference(
    transfers: Sequence[Transfer], n: int, w: int
) -> None:
    """Original dict-based validator (oracle for the equivalence tests)."""
    occupancy: dict[tuple[int, int, int], Transfer] = {}
    for t in transfers:
        if t.wavelength < 0:
            raise WavelengthConflictError(f"unassigned wavelength on {t}")
        if t.wavelength >= w:
            raise WavelengthConflictError(
                f"wavelength {t.wavelength} out of range (w={w})"
            )
        for seg in path_segments(t.src, t.dst, n, t.direction):
            key = (t.direction, seg, t.wavelength)
            if key in occupancy:
                o = occupancy[key]
                raise WavelengthConflictError(
                    f"conflict on dir={t.direction} segment={seg} "
                    f"lambda={t.wavelength}: {o.src}->{o.dst} vs {t.src}->{t.dst}"
                )
            occupancy[key] = t


def wavelengths_used(transfers) -> int:
    if isinstance(transfers, TransferBatch):
        return 1 + transfers.max_wavelength
    return 0 if not transfers else 1 + max(t.wavelength for t in transfers)
