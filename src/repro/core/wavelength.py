"""Routing and Wavelength Assignment (RWA) for one communication step.

The paper (Sec. III-C-2) notes that within each WRHT subgroup the
communications must be wavelength-conflict-free, and that classic greedy
assignment (First Fit / Best Fit) suffices because different subgroups never
share ring segments.  We implement First Fit over the directed-segment
occupancy map, plus a validator used by both the simulator and the property
tests.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Sequence

from .topology import Transfer, path_segments


class WavelengthConflictError(ValueError):
    pass


def first_fit_assign(
    transfers: Sequence[Transfer], n: int, w: int
) -> list[Transfer]:
    """Assign wavelengths greedily (First Fit, [18] in the paper).

    Transfers are processed longest-path-first (a standard RWA heuristic:
    long lightpaths are the hardest to place).  Raises if more than ``w``
    wavelengths would be needed.
    """
    # (direction, segment) -> set of wavelengths in use
    occupancy: dict[tuple[int, int], set[int]] = {}

    def segs(t: Transfer) -> list[tuple[int, int]]:
        return [(t.direction, s) for s in path_segments(t.src, t.dst, n, t.direction)]

    order = sorted(range(len(transfers)), key=lambda i: -len(segs(transfers[i])))
    assigned: list[Transfer | None] = [None] * len(transfers)
    for i in order:
        t = transfers[i]
        used = set()
        for key in segs(t):
            used |= occupancy.get(key, set())
        lam = next(l for l in range(w + len(transfers) + 1) if l not in used)
        if lam >= w:
            raise WavelengthConflictError(
                f"step needs wavelength {lam} but only {w} available "
                f"(transfer {t.src}->{t.dst})"
            )
        for key in segs(t):
            occupancy.setdefault(key, set()).add(lam)
        assigned[i] = replace(t, wavelength=lam)
    return [t for t in assigned if t is not None]


def validate_no_conflicts(transfers: Sequence[Transfer], n: int, w: int) -> None:
    """Check wavelength-conflict-freedom of an already-assigned step."""
    occupancy: dict[tuple[int, int, int], Transfer] = {}
    for t in transfers:
        if t.wavelength < 0:
            raise WavelengthConflictError(f"unassigned wavelength on {t}")
        if t.wavelength >= w:
            raise WavelengthConflictError(
                f"wavelength {t.wavelength} out of range (w={w})"
            )
        for seg in path_segments(t.src, t.dst, n, t.direction):
            key = (t.direction, seg, t.wavelength)
            if key in occupancy:
                o = occupancy[key]
                raise WavelengthConflictError(
                    f"conflict on dir={t.direction} segment={seg} "
                    f"lambda={t.wavelength}: {o.src}->{o.dst} vs {t.src}->{t.dst}"
                )
            occupancy[key] = t


def wavelengths_used(transfers: Sequence[Transfer]) -> int:
    return 0 if not transfers else 1 + max(t.wavelength for t in transfers)
