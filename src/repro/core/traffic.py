"""Multi-tenant optical traffic simulator (DESIGN.md §16).

Every engine below this layer times ONE collective in isolation; the
paper's premise — WDM wavelengths as the scarce shared resource — only
bites when many jobs contend for the same ring.  This module is the
job-level discrete-event simulator of that contention: concurrent tenants
(Poisson or trace-driven arrivals, heterogeneous collective/payload mixes)
submit planned collectives that queue for one optical ring, and admitted
groups run *concurrently* as a :class:`~repro.core.compose.ComposedSchedule`
timed by :func:`~repro.core.simulator.simulate_composed`.

Wavelength policies (the contention knob):

* ``"shared"`` — every tenant draws on the full λ pool; the admitted
  group is fused by :func:`~repro.core.compose.compose_schedules`, whose
  per-slot First-Fit RWA over the union :class:`TransferBatch` grants
  cross-tenant overlap when the wavelengths fit and *serializes* the slot
  when they don't.  Full pool per job at low load, RWA contention at high.
* ``"partitioned"`` — the pool is split evenly among the registered
  tenants; each tenant's schedule is built under its sub-budget ``w/K``
  and shifted into its own λ range, so cross-tenant fusion is
  conflict-free *by construction* (:func:`compose_partitioned` zips the
  constituents slot-by-slot with no RWA pass).  Perfect isolation, paid
  for with narrower — hence longer — per-tenant schedules even when the
  ring is otherwise idle.

Service discipline: FIFO with at most one in-flight job per tenant per
group (a tenant's own collectives are ordered — successive training steps,
successive serve rounds — while distinct tenants are mutually concurrent),
bounded by ``max_concurrent`` fused jobs and an optional ``max_queue``
admission cap.

Re-planning: per-tenant schedules are memoized in an LRU plan memo keyed
on the d-independent build inputs *and* the tenant's partition slice —
the same recovery pattern as the trainer's
``SyncController`` plan memo (DESIGN.md §14).  A tenant joining or
leaving re-partitions the pool and therefore re-plans every survivor;
returning to a previously seen tenant set is a pure memo hit
(``last_replan_cached``), which ``tests/test_traffic.py`` pins.

Zero-contention invariant: a single tenant submitting one job — under
either policy — composes a depth-1 schedule that is bit-identical to the
uncomposed one, so its latency equals ``simulate_composed`` on the same
schedule exactly (the ``benchmarks/bench_traffic.py`` anchor cell).
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from . import compose, simulator, step_models, wrht
from .topology import Ring, TransferBatch


# ---------------------------------------------------------------------------
# Jobs, tenants, sources
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CollectiveJob:
    """One planned collective submitted to the shared ring."""

    tenant: str
    arrival_s: float
    collective: str = "allreduce"
    d_bits: float = 32.0 * 2**20 * 8

    def __post_init__(self) -> None:
        object.__setattr__(self, "collective",
                           wrht.coerce_collective(self.collective))
        if self.arrival_s < 0:
            raise ValueError("arrival_s must be >= 0")
        if self.d_bits <= 0:
            raise ValueError("d_bits must be > 0")


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's arrival process and collective mix.

    ``rate_hz`` is the Poisson job-arrival rate; ``join_s``/``leave_s``
    bound the tenant's registration window (arrivals only inside it, and —
    under the partitioned policy — the tenant owns a λ slice only while
    registered, so joins/leaves re-partition the pool).
    """

    name: str
    rate_hz: float = 1.0
    d_bits: float = 32.0 * 2**20 * 8
    collective: str = "allreduce"
    join_s: float = 0.0
    leave_s: float | None = None

    def registered_at(self, t: float) -> bool:
        return self.join_s <= t and (self.leave_s is None or t < self.leave_s)


@runtime_checkable
class TrafficSource(Protocol):
    """Anything that can emit a job trace for a horizon."""

    def jobs(self, horizon_s: float) -> list[CollectiveJob]:
        ...


class PoissonSource:
    """Seeded Poisson arrivals per tenant, clipped to the tenant's
    registration window.  Deterministic for a fixed ``(tenants, seed)``."""

    def __init__(self, tenants: Sequence[TenantSpec], seed: int = 0) -> None:
        self.tenants = tuple(tenants)
        if len({t.name for t in self.tenants}) != len(self.tenants):
            raise ValueError("tenant names must be unique")
        self.seed = seed

    def jobs(self, horizon_s: float) -> list[CollectiveJob]:
        out: list[CollectiveJob] = []
        for k, spec in enumerate(self.tenants):
            if spec.rate_hz <= 0:
                continue
            rng = np.random.default_rng([self.seed, k])
            t = spec.join_s
            end = min(horizon_s, spec.leave_s
                      if spec.leave_s is not None else horizon_s)
            while True:
                t += rng.exponential(1.0 / spec.rate_hz)
                if t >= end:
                    break
                out.append(CollectiveJob(spec.name, t, spec.collective,
                                         spec.d_bits))
        out.sort(key=lambda j: (j.arrival_s, j.tenant))
        return out


class TraceSource:
    """A fixed, explicit job trace (replayable measurements)."""

    def __init__(self, jobs: Sequence[CollectiveJob]) -> None:
        self._jobs = sorted(jobs, key=lambda j: (j.arrival_s, j.tenant))

    def jobs(self, horizon_s: float) -> list[CollectiveJob]:
        return [j for j in self._jobs if j.arrival_s < horizon_s]


def scale_jobs(jobs: Sequence[CollectiveJob],
               load: float) -> list[CollectiveJob]:
    """Offered-load sweep on a *fixed* arrival sample path: dividing every
    arrival time by ``load`` compresses (load > 1) or dilates (load < 1)
    the same trace, so queueing delay grows with ``load`` along the same
    sample path — the monotonicity ``bench_traffic`` asserts — instead of
    comparing unrelated random draws."""
    if load <= 0:
        raise ValueError("load must be > 0")
    return [replace(j, arrival_s=j.arrival_s / load) for j in jobs]


# ---------------------------------------------------------------------------
# serve.Engine as a traffic source (the inference tenant)
# ---------------------------------------------------------------------------

def kv_bits_per_token(cfg, bits: int = 16) -> float:
    """Wire size of one token's K+V rows across all layers (the sharded KV
    shape an inference all-gather moves)."""
    return 2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim * bits


def activation_bits_per_token(cfg, bits: int = 16) -> float:
    """Wire size of one token's residual-stream activations (what a
    tensor-parallel decode step all-gathers)."""
    return float(cfg.d_model) * bits


class ServingTrafficSource:
    """``serve.Engine`` rounds as inference collectives (DESIGN.md §16).

    Each :class:`~repro.serve.engine.RoundStats` in an engine's
    ``round_log`` becomes two all-gather jobs sized from the model's
    sharded shapes: the *prefill* all-gather moves the round's freshly
    written KV rows (``admitted × prefill_len`` tokens at
    :func:`kv_bits_per_token`), the *decode* all-gather the
    tensor-parallel activations aggregated over the round's decode steps
    (``admitted × decode_steps`` tokens at
    :func:`activation_bits_per_token`).  Rounds arrive ``round_period_s``
    apart — inference all-gathers that compete with training all-reduces
    in the shared-ring simulation.
    """

    def __init__(self, cfg, round_log: Sequence, *, tenant: str = "serve",
                 round_period_s: float = 1e-3, start_s: float = 0.0,
                 compute_bits: int = 16,
                 collective: str = "all_gather") -> None:
        self.cfg = cfg
        self.round_log = list(round_log)
        self.tenant = tenant
        self.round_period_s = round_period_s
        self.start_s = start_s
        self.compute_bits = compute_bits
        self.collective = collective

    @classmethod
    def from_engine(cls, engine, **kw) -> "ServingTrafficSource":
        """Wrap a live :class:`~repro.serve.engine.Engine` — call after
        ``engine.run()`` so ``round_log`` is populated."""
        return cls(engine.cfg, engine.round_log, **kw)

    def jobs(self, horizon_s: float) -> list[CollectiveJob]:
        out: list[CollectiveJob] = []
        for k, r in enumerate(self.round_log):
            t = self.start_s + k * self.round_period_s
            if t >= horizon_s:
                break
            out.append(CollectiveJob(
                self.tenant, t, self.collective,
                r.admitted * r.prefill_len
                * kv_bits_per_token(self.cfg, self.compute_bits)))
            if r.decode_steps > 0:
                out.append(CollectiveJob(
                    self.tenant, t, self.collective,
                    r.admitted * r.decode_steps
                    * activation_bits_per_token(self.cfg,
                                                self.compute_bits)))
        return out


# ---------------------------------------------------------------------------
# Partitioned cross-tenant composition
# ---------------------------------------------------------------------------

def shift_wavelengths(sched: wrht.WRHTSchedule, base: int,
                      w_total: int) -> wrht.WRHTSchedule:
    """Move a schedule built under a sub-budget into its λ partition:
    every assigned wavelength is offset by ``base`` and the schedule's
    budget is re-stamped to the full pool (the constituent then validates
    under the composed ring).  Batch identity is preserved per *input*
    batch — a ring pass sharing one batch across steps keeps sharing the
    shifted one, so the timing profile's segment dedup still applies."""
    if base == 0 and sched.w == w_total:
        return sched
    shifted: dict[int, TransferBatch] = {}
    steps = []
    for st in sched.steps:
        b = st.transfers
        nb = shifted.get(id(b))
        if nb is None:
            nb = b.with_wavelengths(b.wavelength + base)
            shifted[id(b)] = nb
        steps.append(wrht.Step(st.kind, st.level, nb, chunks=st.chunks))
    return replace(sched, w=w_total, steps=steps)


def compose_partitioned(
    schedules: Sequence[wrht.WRHTSchedule], n: int, w: int,
    max_hops: int | None = None,
) -> compose.ComposedSchedule:
    """Zip ``k`` partition-disjoint schedules slot-by-slot.

    The constituents occupy disjoint λ ranges (built under sub-budgets and
    shifted by :func:`shift_wavelengths`), so slot ``t`` simply
    concatenates every constituent's step ``t`` — no RWA pass, no
    serialization fallback, conflict-free by construction
    (``validate_composed`` re-checks this).  Single-constituent slots keep
    the original :class:`~repro.core.wrht.Step` object, so ``k = 1``
    composition is bit-identical to the uncomposed schedule — the same
    depth-1 invariant as :func:`~repro.core.compose.compose_schedules`."""
    schedules = tuple(schedules)
    if not schedules:
        raise ValueError("need at least one schedule to compose")
    lens = [len(s.steps) for s in schedules]
    steps: list[compose.ComposedStep] = []
    for t in range(max(lens)):
        live = [(j, schedules[j].steps[t])
                for j in range(len(schedules)) if t < lens[j]]
        if len(live) == 1:
            j, st = live[0]
            steps.append(compose.ComposedStep(
                st.transfers,
                (compose.ComposedPart(j, t, 0, len(st.transfers)),)))
            continue
        cat, _ = wrht._concat_batches([st.transfers for _, st in live])
        ptr = np.zeros(len(live) + 1, dtype=np.int64)
        np.cumsum([len(st.transfers) for _, st in live], out=ptr[1:])
        parts = tuple(
            compose.ComposedPart(j, t, int(ptr[i]), int(ptr[i + 1]))
            for i, (j, _) in enumerate(live))
        steps.append(compose.ComposedStep(cat, parts))
    return compose.ComposedSchedule(n=n, w=w, schedules=schedules,
                                    steps=steps, max_hops=max_hops)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class JobRecord:
    job: CollectiveJob
    start_s: float     # service start (group start)
    finish_s: float    # service end (group end)
    group: int         # index into TrafficResult.groups

    @property
    def latency_s(self) -> float:
        """Queueing + service: what the tenant observes."""
        return self.finish_s - self.job.arrival_s

    @property
    def wait_s(self) -> float:
        return self.start_s - self.job.arrival_s


@dataclass(frozen=True)
class GroupRecord:
    """One service batch: the jobs fused onto the ring together."""

    index: int
    start_s: float
    service_s: float
    jobs: tuple[CollectiveJob, ...]
    slots: int
    serial_slots: int
    fused_slots: int
    composed: compose.ComposedSchedule | None = None  # keep_schedules only

    @property
    def finish_s(self) -> float:
        return self.start_s + self.service_s


@dataclass
class TrafficResult:
    policy: str
    n: int
    w: int
    timing: str
    jobs: list[JobRecord] = field(default_factory=list)
    groups: list[GroupRecord] = field(default_factory=list)
    rejected: list[CollectiveJob] = field(default_factory=list)
    replans: int = 0             # plan-memo misses (schedules actually built)
    replan_memo_hits: int = 0    # plan-memo hits (join/leave recovery path)
    repartitions: int = 0        # registered-set changes observed at service

    def latencies(self, tenant: str | None = None) -> np.ndarray:
        lat = [r.latency_s for r in self.jobs
               if tenant is None or r.job.tenant == tenant]
        return np.asarray(lat, dtype=np.float64)

    def percentile(self, q: float, tenant: str | None = None) -> float:
        lat = self.latencies(tenant)
        if lat.size == 0:
            return math.nan
        return float(np.percentile(lat, q))

    @property
    def tenants(self) -> list[str]:
        return sorted({r.job.tenant for r in self.jobs})

    def summary(self) -> dict:
        """The benchmark row: p50/p99 overall and per tenant, plus fusion
        and admission accounting."""
        out = {
            "policy": self.policy, "n": self.n, "w": self.w,
            "jobs": len(self.jobs), "rejected": len(self.rejected),
            "groups": len(self.groups),
            "p50_s": self.percentile(50), "p99_s": self.percentile(99),
            "mean_s": (float(self.latencies().mean())
                       if self.jobs else math.nan),
            "replans": self.replans,
            "replan_memo_hits": self.replan_memo_hits,
            "repartitions": self.repartitions,
            "fused_groups": sum(1 for g in self.groups if len(g.jobs) > 1),
            "slots_saved": sum(g.serial_slots - g.slots
                               for g in self.groups),
        }
        out["per_tenant"] = {
            t: {"jobs": int(sum(1 for r in self.jobs if r.job.tenant == t)),
                "p50_s": self.percentile(50, t),
                "p99_s": self.percentile(99, t)}
            for t in self.tenants
        }
        return out


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------

POLICIES = ("shared", "partitioned")


class RingTrafficSim:
    """Job-level contention simulator for one optical ring.

    ``max_concurrent`` bounds the jobs fused per service group (admission
    control, on top of the one-job-per-tenant rule); ``max_queue`` rejects
    arrivals beyond the backlog cap (``None`` = unbounded FIFO).
    ``memo_cap`` bounds the per-tenant schedule plan memo (LRU), the
    join/leave recovery path: ``last_replan_cached`` mirrors the trainer's
    ``SyncController`` contract (DESIGN.md §14).
    """

    def __init__(self, n: int, p: step_models.OpticalParams | None = None,
                 *, policy: str = "shared", max_concurrent: int = 4,
                 max_queue: int | None = None, timing: str | None = None,
                 keep_schedules: bool = False, memo_cap: int = 64) -> None:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r} "
                             f"(expected one of {POLICIES})")
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        self.n = n
        self.p = p or step_models.OpticalParams()
        self.w = self.p.wavelengths
        self.policy = policy
        self.max_concurrent = max_concurrent
        self.max_queue = max_queue
        self.timing = timing or self.p.timing
        self.keep_schedules = keep_schedules
        self.memo_cap = memo_cap
        self.max_hops = Ring(max(n, 2), self.w,
                             bandwidth_bps=self.p.bandwidth_bps,
                             reconfig_delay_s=self.p.reconfig_delay_s,
                             physical=self.p.physical).max_hops
        # plan memo: d-independent-ish build inputs + the partition slice
        self._plan_memo: "OrderedDict[tuple, wrht.WRHTSchedule]" = \
            OrderedDict()
        # composed-group memo: tuple of plan keys -> (composed, timing stats)
        self._group_memo: "OrderedDict[tuple, tuple]" = OrderedDict()
        self.replans = 0
        self.replan_memo_hits = 0
        self.last_replan_cached = False

    # -- planning ---------------------------------------------------------

    def _plan_key(self, job: CollectiveJob, w_eff: int,
                  base: int) -> tuple:
        return (job.collective, float(job.d_bits), w_eff, base)

    def _plan(self, job: CollectiveJob, w_eff: int,
              base: int) -> wrht.WRHTSchedule:
        """The job's schedule inside its λ slice, through the LRU plan
        memo.  A repartition changes ``(w_eff, base)`` and therefore
        misses; returning to a previously seen partition hits."""
        key = self._plan_key(job, w_eff, base)
        sched = self._plan_memo.get(key)
        if sched is not None:
            self._plan_memo.move_to_end(key)
            self.replan_memo_hits += 1
            self.last_replan_cached = True
            return sched
        sched = wrht.build_collective_schedule(
            job.collective, self.n, w_eff, job.d_bits,
            bandwidth_bps=self.p.bandwidth_bps,
            reconfig_delay_s=self.p.reconfig_delay_s,
            validate=False, max_hops=self.max_hops)
        sched = shift_wavelengths(sched, base, self.w)
        self._plan_memo[key] = sched
        while len(self._plan_memo) > self.memo_cap:
            self._plan_memo.popitem(last=False)
        self.replans += 1
        self.last_replan_cached = False
        return sched

    def _partition(self, registered: Sequence[str]) -> dict[str, tuple]:
        """Even static split of the pool among the registered tenants:
        tenant ``k`` (in name order) owns ``[k·w/K, (k+1)·w/K)``."""
        names = sorted(registered)
        w_eff = self.w // len(names)
        if w_eff < 1:
            raise ValueError(
                f"partitioned policy cannot split w={self.w} wavelengths "
                f"among {len(names)} tenants")
        return {t: (w_eff, k * w_eff) for k, t in enumerate(names)}

    def _compose_group(self, group: Sequence[CollectiveJob],
                       registered: Sequence[str]) -> tuple:
        """(composed, service stats) for one admitted group, memoized on
        the per-job plan keys."""
        if self.policy == "partitioned":
            slices = self._partition(registered)
            keys = tuple(self._plan_key(j, *slices[j.tenant])
                         for j in group)
        else:
            keys = tuple(self._plan_key(j, self.w, 0) for j in group)
        hit = self._group_memo.get(keys)
        if hit is not None:
            self._group_memo.move_to_end(keys)
            # a group hit implies every constituent plan was reused
            self.replan_memo_hits += len(group)
            self.last_replan_cached = True
            return hit
        if self.policy == "partitioned":
            scheds = [self._plan(j, *slices[j.tenant]) for j in group]
            composed = compose_partitioned(scheds, self.n, self.w,
                                           max_hops=self.max_hops)
        else:
            scheds = [self._plan(j, self.w, 0) for j in group]
            composed = compose.compose_schedules(scheds,
                                                 max_hops=self.max_hops)
        res = simulator.simulate_composed(
            composed, max(j.d_bits for j in group), self.p,
            timing=self.timing)
        out = (composed, float(res.total_s))
        self._group_memo[keys] = out
        while len(self._group_memo) > self.memo_cap:
            self._group_memo.popitem(last=False)
        return out

    # -- the event loop ---------------------------------------------------

    def run(self, source: "TrafficSource | Sequence[CollectiveJob]",
            horizon_s: float | None = None,
            tenants: Sequence[TenantSpec] | None = None) -> TrafficResult:
        """Serve a job trace to completion (arrivals stop at ``horizon_s``;
        the queue always drains).  ``tenants`` supplies the registration
        timeline for the partitioned policy — defaulting to the source's
        own specs (:class:`PoissonSource`) or to always-registered tenants
        derived from the trace."""
        if isinstance(source, (list, tuple)):
            jobs = sorted(source, key=lambda j: (j.arrival_s, j.tenant))
            if horizon_s is not None:
                jobs = [j for j in jobs if j.arrival_s < horizon_s]
        else:
            if horizon_s is None:
                raise ValueError("a TrafficSource needs an explicit horizon")
            jobs = source.jobs(horizon_s)
        if tenants is None:
            if isinstance(source, PoissonSource):
                tenants = source.tenants
            else:
                tenants = tuple(TenantSpec(name, rate_hz=0.0)
                                for name in sorted({j.tenant for j in jobs}))
        byname = {t.name: t for t in tenants}

        replans0, hits0 = self.replans, self.replan_memo_hits
        result = TrafficResult(self.policy, self.n, self.w, self.timing)
        queue: list[CollectiveJob] = []
        t = 0.0
        i = 0
        prev_registered: frozenset[str] | None = None
        while i < len(jobs) or queue:
            if not queue:
                t = max(t, jobs[i].arrival_s)
            # pull every arrival up to the current clock (the ring just
            # freed, or idles until this arrival); admission-control the
            # backlog per arrival
            while i < len(jobs) and jobs[i].arrival_s <= t:
                if (self.max_queue is not None
                        and len(queue) >= self.max_queue):
                    result.rejected.append(jobs[i])
                else:
                    queue.append(jobs[i])
                i += 1
            if not queue:
                continue
            # FIFO group formation, at most one job per tenant: a tenant's
            # own collectives are ordered, tenants are mutually concurrent
            group: list[CollectiveJob] = []
            seen: set[str] = set()
            rest: list[CollectiveJob] = []
            for j in queue:
                if len(group) < self.max_concurrent and j.tenant not in seen:
                    group.append(j)
                    seen.add(j.tenant)
                else:
                    rest.append(j)
            queue = rest
            # the registered set at service time drives the λ partition;
            # tenants of in-flight jobs stay registered until served
            registered = frozenset(
                name for name, spec in byname.items()
                if spec.registered_at(t)) | seen
            if prev_registered is not None and registered != prev_registered:
                result.repartitions += 1
            prev_registered = registered
            composed, service_s = self._compose_group(group,
                                                      sorted(registered))
            gi = len(result.groups)
            result.groups.append(GroupRecord(
                index=gi, start_s=t, service_s=service_s, jobs=tuple(group),
                slots=composed.num_steps,
                serial_slots=composed.serial_steps,
                fused_slots=composed.fused_steps,
                composed=composed if self.keep_schedules else None))
            finish = t + service_s
            for j in group:
                result.jobs.append(JobRecord(j, t, finish, gi))
            t = finish
        result.jobs.sort(key=lambda r: (r.job.arrival_s, r.job.tenant))
        result.replans = self.replans - replans0
        result.replan_memo_hits = self.replan_memo_hits - hits0
        return result
