"""Payload-vectorized schedule timing (DESIGN.md §9).

PR 1 made schedule *construction* an array program; this module does the
same for schedule *evaluation*.  A ``list[wrht.Step]`` is compiled once into
a :class:`ScheduleProfile` — stacked per-step arrays (step→segment map,
flattened src/dst/hops, per-transfer payload-class ids) — and an entire grid
of payload sizes ``d_bits`` (shape ``[D]``) is then timed for any of the
three engines (lockstep / event / overlap) in broadcasted NumPy passes:

* **lockstep** — for a fixed schedule the total is affine in ``d`` between
  flit boundaries: every step's duration is ``max over transfers of
  ser(frac·d) + prop(hops)``.  Serialization depends only on the transfer's
  *payload class* (the exact division chain producing its bits from ``d``)
  and propagation only on its hop count, so each step collapses at compile
  time to its unique ``(class, hops)`` candidate pairs and the whole grid
  evaluates as one ``[D, candidates]`` max-reduce per schedule.
* **event / overlap** — the per-node readiness recurrence of
  ``simulator.simulate_steps_event`` runs once over ``[D, n]`` arrays
  instead of ``D`` separate Python walks; duplicate-endpoint max-scatters
  are pre-grouped at compile time so the inner loop is pure ``reduceat``.

Numbers are **bit-identical** to the per-point
:func:`repro.core.simulator.run_optical` path — same division chains, same
flit arithmetic, same accumulation order, same analytic shortcuts for the
flat ring and the lock-step H-Ring — pinned by
``tests/test_timing_grid.py``.

Front-ends:

* :func:`evaluate_grid` — ``algorithms × N × d_bits × timing`` in one call
  with cross-point schedule/profile caching; what the sweep benchmarks use.
* :func:`tune_wrht` — simulator-backed auto-tuner: sweep every feasible
  WRHT fan-out ``m`` (and the final all-to-all on/off) through the batched
  engine, return the simulated argmin.  Wired into
  ``run_optical(m="auto")`` and ``planner.plan_bucket(backend="simulated")``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace

import numpy as np

from . import simulator, step_models, wrht
from .topology import CW, FailureMask, Ring, TransferBatch
from .wavelength import InsertionLossError, validate_no_conflicts


@dataclass(frozen=True)
class PayloadClass:
    """How one group of transfers derives its bits from the payload ``d``.

    ``bits(d) = d / divisors[0] / divisors[1] / ... · width_bits/32`` — kept
    as the explicit division *chain* (not a collapsed fraction) so the
    floating-point result is bit-identical to the schedule builders'.  E.g.
    the H-Ring inter-group chunk is ``(d / g) / n_groups``, which differs in
    the last ulp from ``d / (g · n_groups)``.

    ``width_bits`` is the wire width per element (DESIGN.md §15): ``d`` is
    always the *logical* fp32 payload, and a compressed schedule's β-term
    shrinks by the exact factor ``width_bits/32``.  The supported widths
    (32/16/8/4) are power-of-two fractions of 32, so the scaling is a pure
    FP exponent shift that commutes with the division chain — width-scaled
    evaluation at ``d`` is bit-identical to width-32 evaluation at
    ``d·width_bits/32``.  Class *matching* in :meth:`ScheduleProfile.from_steps`
    uses :meth:`structural_bits` (chain only): builders emit width-32
    structure, width is a pure evaluation-time attribute.
    """

    divisors: tuple[float, ...] = ()
    width_bits: float = 32.0

    def bits(self, d: np.ndarray) -> np.ndarray:
        b = self.structural_bits(d)
        if self.width_bits != 32.0:
            b = b * (self.width_bits / 32.0)
        return b

    def structural_bits(self, d: np.ndarray) -> np.ndarray:
        b = np.asarray(d, dtype=np.float64)
        for q in self.divisors:
            b = b / q
        return b


FULL_VECTOR = PayloadClass()  # every transfer carries the constant full d


@dataclass(frozen=True)
class _Scatter:
    """Compile-time grouping of one segment's endpoint updates.

    ``vals[:, perm]`` reduced at ``ptr`` gives the per-unique-node max, so
    the event engine's duplicate-safe max-scatter (``np.maximum.at`` in the
    per-point engine) becomes one C-speed ``reduceat`` over the grid.  When
    every endpoint is distinct (flat ring, binary tree, H-Ring — only WRHT
    representatives drain several members at once) ``direct`` marks that no
    grouping is needed at all and the update is a plain fancy assignment.
    """

    nodes: np.ndarray   # unique endpoint ids               [G]
    perm: np.ndarray    # argsort of the endpoint column    [T]
    ptr: np.ndarray     # group starts into perm            [G]
    direct: bool        # all endpoints unique: skip the reduceat

    def apply(self, ready: np.ndarray, vals: np.ndarray,
              buf: np.ndarray | None = None) -> None:
        """``ready[node] = max(ready[node], max of node's vals)``.

        ``ready`` is ``[n, D]`` and ``vals`` ``[T, D]`` — node-major layout,
        so every gather/scatter runs on axis 0, NumPy's fast path.  ``buf``
        (shape ``[T, D]``, direct case only) makes the update allocation-free
        for the hot repeated-segment loop.
        """
        if self.direct:
            if buf is not None:
                # mode="clip" keeps take() on its fast unbuffered path; the
                # node ids are always in range so it never actually clips
                np.take(ready, self.nodes, axis=0, out=buf, mode="clip")
                np.maximum(buf, vals, out=buf)
                ready[self.nodes] = buf
            else:
                ready[self.nodes] = np.maximum(ready[self.nodes], vals)
            return
        gmax = np.maximum.reduceat(vals[self.perm], self.ptr, axis=0)
        ready[self.nodes] = np.maximum(ready[self.nodes], gmax)


def _scatter(idx: np.ndarray) -> _Scatter:
    perm = np.argsort(idx, kind="stable")
    sorted_idx = idx[perm]
    marks = np.empty(sorted_idx.size, dtype=bool)
    marks[0] = True
    np.not_equal(sorted_idx[1:], sorted_idx[:-1], out=marks[1:])
    ptr = np.flatnonzero(marks)
    if ptr.size == idx.size:  # no duplicates: update in input order
        return _Scatter(idx, perm, ptr, True)
    return _Scatter(sorted_idx[ptr], perm, ptr, False)


class ScheduleProfile:
    """A ``list[wrht.Step]`` compiled to stacked arrays for grid evaluation.

    Steps sharing one ``TransferBatch`` object (the flat ring repeats one
    batch for all ``2(N-1)`` steps; H-Ring repeats its intra/inter templates)
    collapse to a single *segment*: transfers are stored once, validated
    once, and the per-step view is just an index into the segment table.
    """

    __slots__ = (
        "n", "num_steps", "max_wavelengths", "step_seg", "seg_ptr",
        "src", "dst", "hops", "cls", "classes", "cand_ptr", "cand_cls",
        "cand_hops", "scatter_src", "scatter_dst",
    )

    def __init__(self) -> None:  # populated by from_steps
        pass

    @classmethod
    def from_steps(
        cls,
        steps: list[wrht.Step],
        ring: Ring,
        classes: tuple[PayloadClass, ...] = (FULL_VECTOR,),
        d_ref: float = 1.0,
        validate: bool = True,
        seg_cache: dict | None = None,
    ) -> "ScheduleProfile":
        """Compile ``steps`` against ``ring``.

        ``classes`` lists the payload classes present in the schedule; each
        transfer is matched to its class by comparing the batch's build-time
        bits against ``class.bits(d_ref)`` (exact float equality — both were
        produced by the same division chain).  With the default single
        ``FULL_VECTOR`` class the batch bits are ignored (the
        ``bits_override`` convention of the WRHT/BT simulators).

        ``validate`` runs the conflict/hop-budget check once per unique
        segment — the per-point engines re-validated every step of every
        call.

        ``seg_cache`` shares per-batch compile work *across* ``from_steps``
        calls, keyed on batch object identity: the batched auto-tuner's
        candidate schedules share their level batches between the
        all-to-all/no-all-to-all variants (DESIGN.md §10), so each shared
        segment is compiled once.  Only pass a cache between calls whose
        ``(ring.n, classes, d_ref, validate)`` agree, and only while the
        batches stay alive (the dict is id-keyed).
        """
        self = cls()
        self.n = ring.n
        self.num_steps = len(steps)
        self.classes = tuple(classes)

        seg_of: dict[int, int] = {}
        seg_batches = []
        step_seg = np.empty(len(steps), dtype=np.int64)
        for i, step in enumerate(steps):
            key = id(step.transfers)
            if key not in seg_of:
                seg_of[key] = len(seg_batches)
                seg_batches.append(step.transfers)
            step_seg[i] = seg_of[key]
        self.step_seg = step_seg

        src_parts, dst_parts, hops_parts, cls_parts = [], [], [], []
        seg_ptr = [0]
        cand_cls_parts, cand_hops_parts = [], []
        cand_ptr = [0]
        max_wavelengths = 0
        # match on the structural chain only: builders emit width-32 bits,
        # a class's wire width is evaluation-time (PayloadClass docstring)
        ref_bits = np.array(
            [c.structural_bits(np.float64(d_ref)) for c in self.classes],
            dtype=np.float64
        )
        for batch in seg_batches:
            t = len(batch)
            compiled = seg_cache.get(id(batch)) if seg_cache is not None else None
            if compiled is None:
                if validate and t:
                    validate_no_conflicts(batch, ring.n, ring.w,
                                          max_hops=ring.max_hops)
                hops = batch.arcs(ring.n)[2] if t else np.zeros(0, dtype=np.int64)
                if len(self.classes) == 1:
                    cls_ids = np.zeros(t, dtype=np.int64)
                else:
                    cls_ids = np.full(t, -1, dtype=np.int64)
                    for k, v in enumerate(ref_bits):
                        cls_ids[batch.bits == v] = k
                    if t and (cls_ids < 0).any():
                        raise ValueError(
                            "transfer bits do not match any payload class at "
                            f"d_ref={d_ref!r}"
                        )
                # lockstep candidates: unique (class, hops) pairs per segment
                if not t:
                    keep = np.zeros(0, dtype=np.int64)
                elif len(self.classes) == 1:
                    # one class means one serialization time, and propagation
                    # is monotone in hops, so the step max is exactly the
                    # max-hops candidate — no dedup sort needed
                    keep = np.asarray([hops.argmax()], dtype=np.int64)
                else:
                    pair = cls_ids * (int(hops.max()) + 1) + hops
                    _, keep = np.unique(pair, return_index=True)
                wmax = 1 + int(batch.wavelength.max()) if t else 0
                compiled = (hops, cls_ids, cls_ids[keep], hops[keep], wmax)
                if seg_cache is not None:
                    seg_cache[id(batch)] = compiled
            hops, cls_ids, keep_cls, keep_hops, wmax = compiled
            max_wavelengths = max(max_wavelengths, wmax)
            src_parts.append(batch.src)
            dst_parts.append(batch.dst)
            hops_parts.append(hops)
            cls_parts.append(cls_ids)
            seg_ptr.append(seg_ptr[-1] + t)
            cand_cls_parts.append(keep_cls)
            cand_hops_parts.append(keep_hops)
            cand_ptr.append(cand_ptr[-1] + keep_cls.size)

        def cat(parts, dtype=np.int64):
            return (np.concatenate(parts).astype(dtype, copy=False)
                    if parts else np.zeros(0, dtype=dtype))

        self.src = cat(src_parts)
        self.dst = cat(dst_parts)
        self.hops = cat(hops_parts)
        self.cls = cat(cls_parts)
        self.seg_ptr = np.asarray(seg_ptr, dtype=np.int64)
        self.cand_cls = cat(cand_cls_parts)
        self.cand_hops = cat(cand_hops_parts)
        self.cand_ptr = np.asarray(cand_ptr, dtype=np.int64)
        self.max_wavelengths = max_wavelengths
        # endpoint scatter groupings are only needed by the overlap engine:
        # built lazily (_ensure_scatters) so lockstep-only consumers — the
        # auto-tuner sweep above all — never pay for them
        self.scatter_src = None
        self.scatter_dst = None
        return self

    @classmethod
    def from_composed(
        cls,
        composed,
        ring: Ring,
        classes: "tuple[PayloadClass, ...] | None" = None,
        d_ref: float = 1.0,
        validate: bool = False,
        seg_cache: dict | None = None,
        width_bits: float = 32.0,
    ) -> "ScheduleProfile":
        """Compile a :class:`~repro.core.compose.ComposedSchedule`
        (DESIGN.md §13) through the same machinery as :meth:`from_steps`.

        The fused timeline becomes the step list — so the event engine's
        barrier recurrence and the overlap engine's per-node readiness
        recurrence apply unchanged, and the SWOT-style credit (schedule
        B's reconfiguration hiding under schedule A's communication) falls
        out of the recurrence because both schedules' transfers share each
        fused step.  ``classes`` defaults to the union of the
        constituents' payload classes (deduplicated, order-preserving);
        all constituents must have been built at the same payload
        reference ``d_ref`` so the exact-bits class matching of
        :meth:`from_steps` resolves (the plan cache's d-independent
        ``d=1`` builds satisfy this by construction).

        Single-part slots reuse the constituent's original ``Step``
        objects, so the identity-keyed segment dedup still collapses a
        ring pass's shared batch — and a depth-1 composition compiles to a
        profile bit-identical to the uncomposed schedule's
        (``tests/test_compose.py``).
        """
        if classes is None:
            seen: list[PayloadClass] = []
            for s in composed.schedules:
                c = PayloadClass(
                    wrht.COLLECTIVES[s.collective].payload_divisors(s.n),
                    width_bits)
                if all(c.divisors != o.divisors for o in seen):
                    seen.append(c)
            classes = tuple(seen)
        return cls.from_steps(composed.as_steps(), ring, classes=classes,
                              d_ref=d_ref, validate=validate,
                              seg_cache=seg_cache)

    def _ensure_scatters(self) -> None:
        if self.scatter_src is not None:
            return
        scatter_src, scatter_dst = [], []
        for lo, hi in zip(self.seg_ptr[:-1].tolist(), self.seg_ptr[1:].tolist()):
            scatter_src.append(_scatter(self.src[lo:hi]) if hi > lo else None)
            scatter_dst.append(_scatter(self.dst[lo:hi]) if hi > lo else None)
        self.scatter_src = scatter_src
        self.scatter_dst = scatter_dst

    @property
    def num_segments(self) -> int:
        return len(self.seg_ptr) - 1

    @property
    def num_transfers(self) -> int:
        return int(self.seg_ptr[-1])

    # ------------------------------------------------------------------
    # grid evaluation
    # ------------------------------------------------------------------

    def _class_ser(self, ring: Ring, d: np.ndarray) -> np.ndarray:
        """Per-class serialization times, shape ``[D, n_classes]``."""
        cols = [ring.serialization_time_array(c.bits(d)) for c in self.classes]
        return np.stack(cols, axis=1)

    def _step_maxes(self, ring: Ring, d: np.ndarray) -> np.ndarray:
        """Lock-step per-step durations for the whole grid, shape ``[D, S]``.

        ``max over transfers of ser + prop`` reduced over the compile-time
        ``(class, hops)`` candidates — the max is order-independent, so the
        reduction over deduplicated candidates is bit-identical to the
        per-transfer max of the per-point engine.
        """
        ser_c = self._class_ser(ring, d)
        seg_max = np.zeros((d.size, self.num_segments))
        nonempty = self.cand_ptr[:-1] < self.cand_ptr[1:]
        if nonempty.any():
            cand = (ser_c[:, self.cand_cls]
                    + ring.propagation_time(self.cand_hops)[None, :])
            seg_max[:, nonempty] = np.maximum.reduceat(
                cand, self.cand_ptr[:-1][nonempty], axis=1
            )
        return seg_max[:, self.step_seg]

    def lockstep(self, ring: Ring, d_bits,
                 keep_per_step: bool = True) -> "BatchedTimes":
        """Batched :func:`simulator.simulate_steps` (same accumulation order)."""
        d = np.atleast_1d(np.asarray(d_bits, dtype=np.float64))
        step_max = self._step_maxes(ring, d)
        a = ring.reconfig_delay_s
        ser = np.zeros(d.size)
        for s in range(self.num_steps):   # sequential, like the scalar engine
            ser += step_max[:, s]
        return BatchedTimes(
            n=self.n, steps=self.num_steps,
            max_wavelengths=self.max_wavelengths, timing="lockstep",
            d_bits=d, serialization_s=ser,
            reconfig_s=np.full(d.size, self.num_steps * a),
            per_step_s=step_max + a if keep_per_step else None,
        )

    def _step_empty(self) -> np.ndarray:
        empty_seg = self.seg_ptr[:-1] == self.seg_ptr[1:]
        return empty_seg[self.step_seg]

    def _event_barrier(self, ring: Ring, d: np.ndarray,
                       keep_per_step: bool = True) -> "BatchedTimes":
        """Barrier-mode event engine, derived from the per-step maxes.

        Under a global step barrier every transfer of step ``s`` starts at
        ``t_{s-1} + a`` and the step's makespan delta is its slowest receive
        — the same quantity the lock-step engine maxes over — so the whole
        ``[D, n]`` readiness recurrence collapses to a scalar-per-payload
        recurrence replaying the per-point engine's exact additions
        (``t = (t + a) + max_rx``; ``per_step = t_new - t_old``).
        """
        step_max = self._step_maxes(ring, d)
        a = ring.reconfig_delay_s
        empty = self._step_empty()
        ser = np.zeros(d.size)
        t = np.zeros(d.size)
        per_step = (np.empty((d.size, self.num_steps))
                    if keep_per_step else None)
        for s in range(self.num_steps):
            if empty[s]:
                t = t + a
                if keep_per_step:
                    per_step[:, s] = a
                continue
            nt = (t + a) + step_max[:, s]
            if keep_per_step:
                per_step[:, s] = nt - t
            t = nt
            ser += step_max[:, s]
        return BatchedTimes(
            n=self.n, steps=self.num_steps,
            max_wavelengths=self.max_wavelengths, timing="event",
            d_bits=d, serialization_s=ser,
            reconfig_s=np.full(d.size, self.num_steps * a),
            per_step_s=per_step,
        )

    def event(self, ring: Ring, d_bits, overlap: bool = False,
              keep_per_step: bool = True) -> "BatchedTimes":
        """Batched :func:`simulator.simulate_steps_event`.

        Barrier mode short-circuits through :meth:`_event_barrier` (exact).
        Overlap mode runs the per-node readiness recurrence over ``[D, n]``
        arrays; per-segment serialization/receive grids are computed once
        and reused across the steps sharing a ``TransferBatch``.
        ``keep_per_step=False`` skips the per-step makespan tracking (one
        ``[D, n]`` max per step) when only totals are needed.
        """
        d = np.atleast_1d(np.asarray(d_bits, dtype=np.float64))
        if not overlap:
            return self._event_barrier(ring, d, keep_per_step)
        self._ensure_scatters()
        D = d.size
        a = ring.reconfig_delay_s
        # node-major [n, D] state: all per-step gathers/scatters hit axis 0
        ser_cT = np.ascontiguousarray(self._class_ser(ring, d).T)  # [K, D]
        prop = ring.propagation_time(self.hops)
        ready = np.zeros((self.n, D))
        ser = np.zeros(D)
        per_step = np.empty((D, self.num_steps)) if keep_per_step else None
        t_prev = np.zeros(D)
        seg_cache: dict[int, tuple] = {}
        for s in range(self.num_steps):
            seg = int(self.step_seg[s])
            lo, hi = int(self.seg_ptr[seg]), int(self.seg_ptr[seg + 1])
            if lo == hi:
                # an empty step still retunes every node's MRRs: the clock
                # advances by the reconfiguration delay (see the matching
                # branch in simulate_steps_event)
                ready += a
                t_prev += a
                if keep_per_step:
                    per_step[:, s] = a
                continue
            cached = seg_cache.get(seg)
            if cached is None:
                tx = ser_cT[self.cls[lo:hi]]                # [T_s, D]
                rx = tx + prop[lo:hi][:, None]
                cached = (self.src[lo:hi], self.dst[lo:hi], tx, rx,
                          rx.max(axis=0),
                          np.empty_like(tx), np.empty_like(tx),
                          np.empty_like(tx))
                seg_cache[seg] = cached
            src, dst, tx, rx, rx_max, b_start, b_vals, b_gather = cached
            # allocation-free steady state: start = max(ready@src, ready@dst)+a
            # (mode="clip" for the unbuffered take() path; ids never clip)
            np.take(ready, src, axis=0, out=b_start, mode="clip")
            np.take(ready, dst, axis=0, out=b_vals, mode="clip")
            np.maximum(b_start, b_vals, out=b_start)
            b_start += a
            np.add(b_start, tx, out=b_vals)
            self.scatter_src[seg].apply(ready, b_vals, b_gather)
            np.add(b_start, rx, out=b_vals)
            self.scatter_dst[seg].apply(ready, b_vals, b_gather)
            if keep_per_step:
                t = ready.max(axis=0)
                per_step[:, s] = t - t_prev
                t_prev = t
            ser += rx_max
        reconfig = np.full(D, self.num_steps * a)
        # Clamp audit (DESIGN.md §13): the cap is the lockstep total of
        # THIS step sequence — for a composed schedule that is the fused
        # timeline's barrier execution (Σ fused-step maxes + S·a), which is
        # always an admissible execution of the composition, NOT the sum of
        # the constituents' per-schedule lockstep totals.  Cross-schedule
        # overlap (B's reconfiguration hiding under A's communication)
        # lives inside each fused step and is therefore never clamped
        # away; by induction per-node readiness can only exceed the
        # barrier clock through FP accumulation noise, which is all the
        # min() removes (regression: tests/test_compose.py).
        event_total = np.minimum(ready.max(axis=0), ser + self.num_steps * a)
        return BatchedTimes(
            n=self.n, steps=self.num_steps,
            max_wavelengths=self.max_wavelengths, timing="overlap",
            d_bits=d, serialization_s=ser, reconfig_s=reconfig,
            event_total_s=event_total, per_step_s=per_step,
        )

    def evaluate(self, ring: Ring, d_bits, timing: str = "lockstep",
                 keep_per_step: bool = True) -> "BatchedTimes":
        if timing == "lockstep":
            return self.lockstep(ring, d_bits, keep_per_step)
        if timing in ("event", "overlap"):
            return self.event(ring, d_bits, overlap=timing == "overlap",
                              keep_per_step=keep_per_step)
        raise ValueError(f"unknown timing {timing!r} "
                         "(expected 'lockstep', 'event' or 'overlap')")


@dataclass(frozen=True)
class BatchedTimes:
    """One schedule timed over a payload grid (the batched ``SimResult``)."""

    n: int
    steps: int
    max_wavelengths: int
    timing: str
    d_bits: np.ndarray                 # [D]
    serialization_s: np.ndarray        # [D]
    reconfig_s: np.ndarray             # [D] (constant across D)
    event_total_s: np.ndarray | None = None   # overlap only
    per_step_s: np.ndarray | None = None      # [D, S]; None for analytic paths
    algorithm: str = ""

    @property
    def total_s(self) -> np.ndarray:
        if self.event_total_s is not None:
            return self.event_total_s
        return self.serialization_s + self.reconfig_s

    def sim_result(self, i: int = 0) -> simulator.SimResult:
        """Materialize payload ``i`` as a per-point ``SimResult``."""
        return simulator.SimResult(
            algorithm=self.algorithm,
            n=self.n,
            d_bits=float(self.d_bits[i]),
            steps=self.steps,
            serialization_s=float(self.serialization_s[i]),
            reconfig_s=float(self.reconfig_s[i]),
            max_wavelengths=self.max_wavelengths,
            per_step_s=([] if self.per_step_s is None
                        else [float(x) for x in self.per_step_s[i]]),
            timing=self.timing,
            event_total_s=(None if self.event_total_s is None
                           else float(self.event_total_s[i])),
        )


def _with_meta(times: BatchedTimes, algorithm: str, **overrides) -> BatchedTimes:
    """Attach front-end metadata (algorithm label, timing-string quirks)."""
    return replace(times, algorithm=algorithm, **overrides)


# ---------------------------------------------------------------------------
# Profile (de)serialization — the plan cache's disk tier (DESIGN.md §10).
# ---------------------------------------------------------------------------

_PROFILE_ARRAYS = ("step_seg", "seg_ptr", "src", "dst", "hops", "cls",
                   "cand_ptr", "cand_cls", "cand_hops")


def profile_to_arrays(prof: ScheduleProfile) -> tuple[dict, dict[str, np.ndarray]]:
    """Split a compiled profile into JSON-able metadata + stacked arrays."""
    meta = {
        "n": prof.n,
        "num_steps": prof.num_steps,
        "max_wavelengths": prof.max_wavelengths,
        "classes": [list(c.divisors) for c in prof.classes],
        "class_widths": [c.width_bits for c in prof.classes],
    }
    return meta, {name: getattr(prof, name) for name in _PROFILE_ARRAYS}


def profile_from_arrays(meta: dict, arrays: dict) -> ScheduleProfile:
    """Rebuild a profile from :func:`profile_to_arrays` output.

    The endpoint scatter groupings are recomputed from the stored ``src``/
    ``dst`` columns — they are a pure function of them, so the round-trip
    is exact (pinned by ``tests/test_plan_cache.py``).
    """
    prof = ScheduleProfile()
    prof.n = int(meta["n"])
    prof.num_steps = int(meta["num_steps"])
    prof.max_wavelengths = int(meta["max_wavelengths"])
    widths = meta.get("class_widths") or [32.0] * len(meta["classes"])
    prof.classes = tuple(PayloadClass(tuple(d), float(w))
                         for d, w in zip(meta["classes"], widths))
    for name in _PROFILE_ARRAYS:
        setattr(prof, name, np.asarray(arrays[name]))
    prof.scatter_src = None   # lazy, like from_steps (_ensure_scatters)
    prof.scatter_dst = None
    return prof


# ---------------------------------------------------------------------------
# Profile cache + per-algorithm front-ends (bit-identical to run_optical).
# ---------------------------------------------------------------------------

def _ring_of(n: int, p: step_models.OpticalParams) -> Ring:
    return Ring(n, p.wavelengths, bandwidth_bps=p.bandwidth_bps,
                reconfig_delay_s=p.reconfig_delay_s, physical=p.physical)


def _collective_profile(
    collective: str, n: int, p: step_models.OpticalParams, m: int | None,
    allow_alltoall: bool = True, max_hops: int | None = None,
    failures: FailureMask | None = None, depth: int = 1, bits: int = 32,
) -> ScheduleProfile:
    """Any scheduled collective's profile via the two-tier plan cache
    (DESIGN.md §10, §11).

    The cache key is the d-independent structure ``(collective, n, w, m,
    alltoall, max_hops, rwa, depth, bits)`` — deliberately *not* the whole
    ``OpticalParams``: bandwidth/reconfiguration only enter at evaluation
    time, so every parameter flavour shares one compiled profile.  ``(m,
    alltoall)`` are normalized per collective so keys never fragment on
    axes the collective does not have.  ``depth>1`` yields the composed
    pipeline's profile (DESIGN.md §13); ``bits<32`` a width-scaled
    compressed profile (DESIGN.md §15).
    """
    from . import plan_cache

    collective = wrht.coerce_collective(collective)
    m, allow_alltoall = wrht.collective_plan_fields(collective, m,
                                                    allow_alltoall)
    ring = _ring_of(n, p)
    hops = ring.max_hops if max_hops is None else max_hops
    return plan_cache.get_default().profile(plan_cache.PlanKey(
        n=n, w=p.wavelengths, m=m, alltoall=allow_alltoall, max_hops=hops,
        collective=collective, failures=failures, depth=depth, bits=bits))


def _wrht_profile(
    n: int, p: step_models.OpticalParams, m: int | None,
    allow_alltoall: bool = True, max_hops: int | None = None,
    failures: FailureMask | None = None,
) -> ScheduleProfile:
    """The all-reduce view of :func:`_collective_profile` (historical name)."""
    return _collective_profile("allreduce", n, p, m, allow_alltoall, max_hops,
                               failures)


@functools.lru_cache(maxsize=256)
def _bt_profile(n: int, p: step_models.OpticalParams) -> ScheduleProfile:
    ring = _ring_of(n, p)
    steps = simulator.bt_allreduce_schedule(n, 1.0)
    return ScheduleProfile.from_steps(steps, ring)  # validates (may raise)


@functools.lru_cache(maxsize=256)
def _ring_step_profile(n: int, p: step_models.OpticalParams) -> ScheduleProfile:
    ring = _ring_of(n, p)
    # the one neighbour-pattern template step (run_optical builds the same
    # batch; no need to materialize all 2(N-1) identical Step objects)
    src = np.arange(n)
    step = wrht.Step("ring", 0, TransferBatch.from_arrays(
        src, (src + 1) % n, CW, 1.0 / n, wavelength=0, check=False
    ))
    return ScheduleProfile.from_steps(
        [step], ring, classes=(PayloadClass((n,)),)
    )


@functools.lru_cache(maxsize=256)
def _hring_profile(n: int, g: int, p: step_models.OpticalParams) -> ScheduleProfile:
    ring = _ring_of(n, p)
    steps = simulator.hring_allreduce_schedule(n, g, 1.0)
    n_groups = n // g
    return ScheduleProfile.from_steps(
        steps, ring,
        classes=(PayloadClass((g,)), PayloadClass((g, n_groups))),
    )


@functools.lru_cache(maxsize=256)
def _hring_intra_profile(g: int, p: step_models.OpticalParams) -> ScheduleProfile:
    """The 2g-node intra-step template of run_optical's analytic H-Ring path."""
    template = simulator.hring_allreduce_schedule(2 * g, g, 1.0)[0]
    ring = _ring_of(2 * g, p)
    return ScheduleProfile.from_steps(
        [template], ring, classes=(PayloadClass((g,)),)
    )


def wrht_times(
    n: int, d_bits, p: step_models.OpticalParams, timing: str = "lockstep",
    m: int | None = None, allow_alltoall: bool = True,
    max_hops: int | None = None, keep_per_step: bool = True,
    failures: FailureMask | None = None,
) -> BatchedTimes:
    ring = _ring_of(n, p)
    prof = _wrht_profile(n, p, m, allow_alltoall, max_hops, failures)
    return _with_meta(prof.evaluate(ring, d_bits, timing, keep_per_step),
                      "wrht")


def collective_times(
    collective: str, n: int, d_bits, p: step_models.OpticalParams | None = None,
    timing: str = "lockstep", m: int | None = None,
    allow_alltoall: bool = True, max_hops: int | None = None,
    keep_per_step: bool = True, failures: FailureMask | None = None,
    depth: int = 1, bits: int = 32,
) -> BatchedTimes:
    """Batched timing of any scheduled collective over a payload grid
    (DESIGN.md §11): the profile comes from the plan cache (one compile per
    d-independent structure), the grid evaluates through the same three
    engines as all-reduce, and every number is bit-identical to the
    per-point :func:`repro.core.simulator.run_collective`.

    ``depth>1`` times the composed depth-k pipeline of the collective
    (alternating with its partner phase — RS↔AG — DESIGN.md §13); the
    total then covers all ``depth`` concurrent phases at payload ``d``
    *each*, to be compared against the sum of the constituents' serial
    totals.

    ``bits<32`` times the compressed schedule: ``d_bits`` stays the
    *logical* fp32 payload and the profile's width-scaled classes shrink
    the β-term by exactly ``bits/32`` (DESIGN.md §15 — the quantize compute
    overhead is the planner's, not the wire model's).

    Infeasible collectives raise like the builders do — a single-step
    all-to-all beyond the wavelength or hop budget is an error here, not a
    silently worse schedule.
    """
    collective = wrht.coerce_collective(collective)
    p = p or step_models.OpticalParams()
    ring = _ring_of(n, p)
    prof = _collective_profile(collective, n, p, m, allow_alltoall, max_hops,
                               failures, depth=depth, bits=bits)
    label = collective if depth == 1 else f"{collective}:pipe{depth}"
    return _with_meta(prof.evaluate(ring, d_bits, timing, keep_per_step),
                      label)


def bt_times(n: int, d_bits, p: step_models.OpticalParams,
             timing: str = "lockstep", keep_per_step: bool = True) -> BatchedTimes:
    ring = _ring_of(n, p)
    return _with_meta(
        _bt_profile(n, p).evaluate(ring, d_bits, timing, keep_per_step), "bt")


def ring_times(n: int, d_bits, p: step_models.OpticalParams,
               timing: str = "lockstep") -> BatchedTimes:
    """Flat ring, replicating run_optical's scale-one-step shortcut: all
    2(N-1) steps are the identical neighbour pattern, so every engine times
    one representative step and multiplies (exact — constant d/N payload)."""
    ring = _ring_of(n, p)
    one = _ring_step_profile(n, p).lockstep(ring, d_bits)
    k = 2 * (n - 1)
    return BatchedTimes(
        n=n, steps=k, max_wavelengths=one.max_wavelengths,
        timing=timing, d_bits=one.d_bits,
        serialization_s=one.serialization_s * k,
        reconfig_s=np.full(one.d_bits.size, k * ring.reconfig_delay_s),
        algorithm="ring",
    )


def hring_times(n: int, d_bits, p: step_models.OpticalParams,
                timing: str = "lockstep", g: int = 8,
                keep_per_step: bool = True) -> BatchedTimes:
    ring = _ring_of(n, p)
    g = simulator.hring_group_size(n, g)
    if g < 2:
        # prime (or tiny) N: flat-ring fallback under the hring label
        return _with_meta(ring_times(n, d_bits, p, timing), "hring")
    simulator.check_hring_span(ring, n, g)
    if timing != "lockstep":
        prof = _hring_profile(n, g, p)
        return _with_meta(prof.evaluate(ring, d_bits, timing, keep_per_step),
                          "hring")
    # analytic lock-step decomposition (identical to run_optical): time the
    # 2g-node intra template, close-form the inter-group ring
    d = np.atleast_1d(np.asarray(d_bits, dtype=np.float64))
    intra_ring = _ring_of(2 * g, p)
    intra_ser = _hring_intra_profile(g, p)._step_maxes(intra_ring, d)[:, 0]
    n_groups = n // g
    intra_steps = 2 * (g - 1)
    inter_steps = 2 * (n_groups - 1)
    inter_ser = ring.serialization_time_array((d / g) / n_groups)
    if ring.physical is not None:
        inter_ser = inter_ser + float(ring.propagation_time(np.asarray([g]))[0])
    total_steps = intra_steps + inter_steps
    ser = intra_steps * intra_ser + inter_steps * inter_ser
    return BatchedTimes(
        n=n, steps=total_steps, max_wavelengths=1, timing="lockstep",
        d_bits=d, serialization_s=ser,
        reconfig_s=np.full(d.size, total_steps * ring.reconfig_delay_s),
        algorithm="hring",
    )


_ALGORITHMS = ("wrht", "ring", "bt", "hring")


def algorithm_times(
    algorithm: str, n: int, d_bits, p: step_models.OpticalParams,
    timing: str = "lockstep", g: int = 8, m: int | None = None,
    keep_per_step: bool = True,
) -> BatchedTimes:
    """Batched counterpart of ``run_optical`` for one ``(algorithm, n)``."""
    if algorithm == "wrht":
        return wrht_times(n, d_bits, p, timing, m=m,
                          keep_per_step=keep_per_step)
    if algorithm == "ring":
        return ring_times(n, d_bits, p, timing)
    if algorithm == "bt":
        return bt_times(n, d_bits, p, timing, keep_per_step=keep_per_step)
    if algorithm == "hring":
        return hring_times(n, d_bits, p, timing, g=g,
                           keep_per_step=keep_per_step)
    raise ValueError(f"unknown optical algorithm {algorithm!r}")


# ---------------------------------------------------------------------------
# Grid front-end.
# ---------------------------------------------------------------------------

@dataclass
class GridResult:
    """``algorithms × ns × d_bits × timings`` evaluation of the optical ring.

    ``total_s``/``serialization_s``/``reconfig_s``/``event_total_s`` are
    ``[A, N, T, D]`` float arrays (NaN on infeasible cells);
    ``steps``/``max_wavelengths``/``feasible`` are per-``(A, N)``.
    ``errors`` maps ``(algorithm, n)`` to the infeasibility message (e.g.
    the binary tree's fixed lightpaths exceeding the hop budget).
    """

    algorithms: tuple[str, ...]
    ns: tuple[int, ...]
    d_bits: np.ndarray
    timings: tuple[str, ...]
    total_s: np.ndarray
    serialization_s: np.ndarray
    reconfig_s: np.ndarray
    event_total_s: np.ndarray
    steps: np.ndarray
    max_wavelengths: np.ndarray
    feasible: np.ndarray
    errors: dict = field(default_factory=dict)
    _cells: dict = field(default_factory=dict, repr=False)

    def _index(self, algorithm: str, n: int, timing: str) -> tuple[int, int, int]:
        return (self.algorithms.index(algorithm), self.ns.index(n),
                self.timings.index(timing))

    def cell(self, algorithm: str, n: int, timing: str) -> BatchedTimes | None:
        """The full batched record for one ``(algorithm, n, timing)`` cell
        (None when the cell is infeasible)."""
        return self._cells.get((algorithm, n, timing))

    def total(self, algorithm: str, n: int, timing: str) -> np.ndarray:
        a, i, t = self._index(algorithm, n, timing)
        return self.total_s[a, i, t]

    def is_feasible(self, algorithm: str, n: int) -> bool:
        return bool(self.feasible[self.algorithms.index(algorithm),
                                  self.ns.index(n)])

    def sim_result(self, algorithm: str, n: int, d: float,
                   timing: str) -> simulator.SimResult:
        times = self.cell(algorithm, n, timing)
        if times is None:
            raise InsertionLossError(self.errors[(algorithm, n)])
        matches = np.flatnonzero(self.d_bits == d)
        if matches.size == 0:
            raise KeyError(f"payload {d!r} is not on this grid's d_bits axis")
        return times.sim_result(int(matches[0]))


def evaluate_grid(
    algorithms=_ALGORITHMS,
    ns=(64,),
    d_bits=(1e6,),
    timings=("lockstep",),
    p: step_models.OpticalParams | None = None,
    g: int = 8,
    m: int | None = None,
    keep_per_step: bool = True,
) -> GridResult:
    """Evaluate the whole parameter grid through the batched engine.

    Schedules and compiled profiles are cached across grid points (and
    across calls), so the marginal cost of an extra payload size or timing
    mode is a broadcasted array pass, not a schedule walk.  Per-cell numbers
    are bit-identical to calling :func:`simulator.run_optical` point-wise;
    physically infeasible cells (``InsertionLossError``) are recorded in
    ``feasible``/``errors`` instead of raising.
    """
    p = p or step_models.OpticalParams()
    algorithms = tuple(algorithms)
    ns = tuple(int(n) for n in ns)
    timings = tuple(timings)
    d = np.atleast_1d(np.asarray(list(d_bits), dtype=np.float64))
    A, N, T, D = len(algorithms), len(ns), len(timings), d.size
    shape = (A, N, T, D)
    out = GridResult(
        algorithms=algorithms, ns=ns, d_bits=d, timings=timings,
        total_s=np.full(shape, np.nan),
        serialization_s=np.full(shape, np.nan),
        reconfig_s=np.full(shape, np.nan),
        event_total_s=np.full(shape, np.nan),
        steps=np.zeros((A, N), dtype=np.int64),
        max_wavelengths=np.zeros((A, N), dtype=np.int64),
        feasible=np.ones((A, N), dtype=bool),
    )
    for ai, alg in enumerate(algorithms):
        for ni, n in enumerate(ns):
            try:
                for ti, timing in enumerate(timings):
                    times = algorithm_times(alg, n, d, p, timing, g=g, m=m,
                                            keep_per_step=keep_per_step)
                    out._cells[(alg, n, timing)] = times
                    out.total_s[ai, ni, ti] = times.total_s
                    out.serialization_s[ai, ni, ti] = times.serialization_s
                    out.reconfig_s[ai, ni, ti] = times.reconfig_s
                    if times.event_total_s is not None:
                        out.event_total_s[ai, ni, ti] = times.event_total_s
                    out.steps[ai, ni] = times.steps
                    out.max_wavelengths[ai, ni] = times.max_wavelengths
            except InsertionLossError as e:
                # only the physical power budget marks a cell infeasible;
                # anything else (e.g. a wavelength conflict from a builder
                # regression) propagates loudly, like the per-point path
                out.feasible[ai, ni] = False
                out.errors[(alg, n)] = str(e)
    return out


# ---------------------------------------------------------------------------
# Simulator-backed WRHT auto-tuner.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TuneResult:
    """Outcome of a fan-out sweep through the batched simulator.

    ``total_s[c, i]`` is candidate ``c`` at payload ``i``; ``best_*`` are the
    per-payload argmins (ties broken toward the earlier candidate — smaller
    ``m``, all-to-all first — matching a brute-force first-argmin scan).
    ``analytic_m`` is what the closed-form rule (Lemma 1 capped by the
    insertion-loss fan-out limit) would have picked, for comparison.
    """

    n: int
    w: int
    max_hops: int | None
    timing: str
    d_bits: np.ndarray                       # [D]
    candidates: tuple[tuple[int, bool], ...]  # (m, alltoall) per row
    total_s: np.ndarray                      # [C, D]
    steps: np.ndarray                        # [C]
    best_m: np.ndarray                       # [D]
    best_alltoall: np.ndarray                # [D] bool
    best_total_s: np.ndarray                 # [D]
    analytic_m: int

    def best(self, i: int = 0) -> tuple[int, bool]:
        return int(self.best_m[i]), bool(self.best_alltoall[i])


def _tune_candidates(n, w, d_bits, max_hops, p, m_candidates, failures=None):
    """Shared candidate-sweep preamble of the two tuner implementations."""
    p = p or step_models.OpticalParams(wavelengths=w)
    if p.wavelengths != w:
        p = replace(p, wavelengths=w)
    if max_hops is None:
        max_hops = p.physical.max_hops if p.physical is not None else None
    analytic_m = wrht.feasible_group_size(w, max_hops, failures=failures)
    # every m >= n yields the identical single-group schedule, so cap the
    # sweep at n — smaller m wins argmin ties anyway, and this keeps small
    # rings from building hundreds of duplicate candidates
    m_cap = min(analytic_m, n)
    if m_candidates is None:
        m_candidates = range(2, m_cap + 1)
    ms = sorted({int(m) for m in m_candidates
                 if 2 <= int(m) <= m_cap})
    if not ms:
        raise ValueError("no feasible WRHT fan-out candidates")
    d = np.atleast_1d(np.asarray(d_bits, dtype=np.float64))
    return p, max_hops, analytic_m, ms, d


def _tune_result(n, w, max_hops, timing, d, candidates, totals, steps,
                 analytic_m) -> TuneResult:
    total_s = np.stack(totals, axis=0)              # [C, D]
    best = np.argmin(total_s, axis=0)               # first argmin per payload
    cand_m = np.array([c[0] for c in candidates])
    cand_a2a = np.array([c[1] for c in candidates])
    return TuneResult(
        n=n, w=w, max_hops=max_hops, timing=timing, d_bits=d,
        candidates=tuple(candidates), total_s=total_s,
        steps=np.asarray(steps, dtype=np.int64),
        best_m=cand_m[best], best_alltoall=cand_a2a[best],
        best_total_s=total_s[best, np.arange(d.size)],
        analytic_m=analytic_m,
    )


@functools.lru_cache(maxsize=64)
def _candidate_schedules(n: int, w: int, ms: tuple[int, ...],
                         max_hops: int | None,
                         collective: str = "allreduce",
                         failures: FailureMask | None = None):
    """Memoized batched candidate build — the tuner's repeat calls (one per
    ``plan_buckets`` invocation, one per ``run_optical(m="auto")`` point)
    share one construction per sweep signature.  ``FailureMask`` is frozen
    and hashable, so degraded sweeps memoize per-mask like any other axis."""
    return wrht.build_candidate_schedules(
        n, w, 1.0, ms, allow_alltoall=True, validate=False,
        max_hops=max_hops, collective=collective, failures=failures)


def tune_wrht(
    n: int,
    w: int,
    d_bits,
    max_hops: int | None = None,
    p: step_models.OpticalParams | None = None,
    timing: str = "lockstep",
    m_candidates=None,
    collective: str = "allreduce",
    failures: FailureMask | None = None,
    bits: int = 32,
) -> TuneResult:
    """Sweep every feasible WRHT fan-out ``m`` (and the final all-to-all
    on/off) through the batched simulator; return the simulated argmin.

    The analytic rule picks ``m = 2w + 1`` capped by the insertion-loss
    fan-out limit; the simulator-backed sweep also sees relay sub-steps,
    all-to-all feasibility and (under a physical model) per-hop propagation,
    so its argmin can differ — ``benchmarks/bench_sweep.py`` records the
    comparison.

    All candidate schedules come from one pass of the batched
    multi-candidate builder (``wrht.build_candidate_schedules``,
    DESIGN.md §10) — bit-identical to the per-candidate loop, which is kept
    as :func:`tune_wrht_reference` (the golden oracle;
    ``benchmarks/bench_planner.py`` records the ≥5× speedup).  Compiled
    profiles are published to the plan cache keyed on the d-independent
    structure, so the sweep's winner — and every loser — is a warm plan for
    ``run_optical(m="auto")`` and ``planner.plan_buckets``.  The batched
    construction skips the per-step re-validation (it is conflict-free by
    construction and golden-tested); materializing a schedule through the
    plan cache re-validates it fully.

    ``collective`` widens the sweep beyond all-reduce to the other
    fan-out-swept collective, ``"broadcast"`` (DESIGN.md §11) — its
    candidates have no all-to-all variant, so every row is ``(m, False)``.

    ``failures`` re-tunes under a degraded ring (DESIGN.md §12): the
    candidate pool shrinks to what the degraded builder can route, relay
    sub-steps change every candidate's cost, and the argmin can move —
    which is exactly why a mid-run failure re-plans instead of reusing the
    healthy winner.  Raises ``wrht.DegradedInfeasibleError`` when no
    candidate survives the mask.

    ``bits<32`` tunes the compressed schedule (DESIGN.md §15): candidate
    structure is width-independent (one batched build serves every width),
    but each candidate evaluates with width-scaled payload classes and the
    compiled profiles publish under ``bits``-stamped keys — the argmin can
    move because the α/β balance shifts when the wire shrinks.
    """
    from . import plan_cache

    collective = wrht.coerce_collective(collective)
    if not wrht.COLLECTIVES[collective].tree:
        raise ValueError(
            f"collective {collective!r} has no fan-out axis to tune — "
            "evaluate it directly with collective_times"
        )
    if failures is not None and failures.empty:
        failures = None
    p, max_hops, analytic_m, ms, d = _tune_candidates(
        n, w, d_bits, max_hops, p, m_candidates, failures)
    ring = _ring_of(n, p)
    hops = ring.max_hops if max_hops is None else max_hops
    scheds = _candidate_schedules(n, p.wavelengths, tuple(ms), hops,
                                  collective, failures)
    variants = (True, False) if collective == "allreduce" else (False,)
    cache = plan_cache.get_default()
    seg_cache: dict = {}
    candidates: list[tuple[int, bool]] = []
    totals, steps = [], []
    for m in ms:
        for alltoall in variants:
            sched = scheds.get((m, alltoall))
            if sched is None:
                continue  # the a2a=True build never took the all-to-all:
                          # both schedules are identical, evaluate once
            key = plan_cache.PlanKey(n=n, w=p.wavelengths, m=m,
                                     alltoall=alltoall, max_hops=hops,
                                     collective=collective,
                                     failures=failures, bits=bits)
            prof = cache.peek_profile(key)   # memory, then disk tier
            if prof is None:
                classes = ((FULL_VECTOR,) if bits == 32
                           else (PayloadClass((), float(bits)),))
                prof = ScheduleProfile.from_steps(
                    sched.steps, ring, validate=False, seg_cache=seg_cache,
                    classes=classes)
                cache.put_profile(key, prof)
            times = prof.evaluate(ring, d, timing, keep_per_step=False)
            candidates.append((m, alltoall))
            totals.append(times.total_s)
            steps.append(times.steps)
    return _tune_result(n, w, max_hops, timing, d, candidates, totals, steps,
                        analytic_m)


def tune_wrht_reference(
    n: int,
    w: int,
    d_bits,
    max_hops: int | None = None,
    p: step_models.OpticalParams | None = None,
    timing: str = "lockstep",
    m_candidates=None,
) -> TuneResult:
    """The original per-candidate tuner loop, kept verbatim as the golden
    oracle for :func:`tune_wrht`: one full ``build_schedule`` + compile per
    ``(m, alltoall)`` candidate.  Bit-identical results (argmin and totals)
    are asserted by ``tests/test_amortized_planning.py`` and recorded by
    ``benchmarks/bench_planner.py``."""
    p, max_hops, analytic_m, ms, d = _tune_candidates(
        n, w, d_bits, max_hops, p, m_candidates)
    ring = _ring_of(n, p)
    hops = ring.max_hops if max_hops is None else max_hops
    candidates: list[tuple[int, bool]] = []
    totals, steps = [], []
    for m in ms:
        with_a2a = simulator._cached_wrht_schedule(n, p.wavelengths, m, hops,
                                                   True)
        took_a2a = any(s.kind == "alltoall" for s in with_a2a.steps)
        for alltoall in (True, False):
            if not alltoall and not took_a2a:
                continue
            prof = _wrht_profile(n, p, m, alltoall, max_hops)
            times = prof.evaluate(ring, d, timing, keep_per_step=False)
            candidates.append((m, alltoall))
            totals.append(times.total_s)
            steps.append(times.steps)
    return _tune_result(n, w, max_hops, timing, d, candidates, totals, steps,
                        analytic_m)


def clear_caches() -> None:
    """Drop all compiled profiles and candidate sweeps, and install a fresh
    *memory-only* default plan cache (benchmarks and tests use this for fair
    cold timing — a ``REPRO_PLAN_CACHE_DIR`` disk tier would otherwise turn
    "cold" lookups into disk hits).  Long-lived processes that only want to
    shed memory should call ``plan_cache.get_default().clear()`` instead,
    which keeps their disk tier attached."""
    from . import plan_cache

    for fn in (_bt_profile, _ring_step_profile,
               _hring_profile, _hring_intra_profile, _candidate_schedules):
        fn.cache_clear()
    plan_cache.set_default(plan_cache.PlanCache())
