"""WRHT — Wavelength-Reused Hierarchical Tree all-reduce schedule builder.

This is the paper's primary contribution (Sec. III-C).  Given ``N`` nodes on a
bidirectional WDM ring with ``w`` wavelengths per fiber, build the explicit
per-step transfer schedule:

Reduce stage
    Level 0 partitions the ring into contiguous groups of ``m`` nodes; the
    *middle* node of each group is the representative and receives every
    member's (partially reduced) vector in ONE step — members to its left
    transmit clockwise, members to its right counter-clockwise, so the two
    fibers are loaded symmetrically and ``⌈m/2⌉`` wavelengths suffice.
    Representatives of level ``ℓ`` are regrouped at level ``ℓ+1``.  Recursion
    stops when the surviving representatives can finish with a single
    all-to-all exchange within the wavelength budget (paper Sec. III-C-2:
    ``⌈m*²/8⌉`` wavelengths, citation [16]), or when one root remains.

Broadcast stage
    Exact reverse of the reduce stage (paths reversed, same wavelength
    budget).  Because a reduction is applied at every reduce step, every
    transfer in BOTH stages carries the constant full vector of ``d`` bits.

Total steps: ``2⌈log_m N⌉`` (single root) or ``2⌈log_m N⌉ − 1`` (final
all-to-all) — asserted against the closed forms in ``step_models`` by the
test-suite.  ``m = 2w + 1`` is the Lemma-1 optimum: each fiber then carries
exactly ``w`` concurrent intra-group lightpaths.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .topology import CCW, CW, Ring, Transfer, shortest_direction
from .wavelength import WavelengthConflictError, first_fit_assign, validate_no_conflicts


@dataclass
class Step:
    kind: str                      # "reduce" | "alltoall" | "broadcast"
    level: int                     # tree level (alltoall: top level)
    transfers: list[Transfer]

    @property
    def wavelengths(self) -> int:
        return 0 if not self.transfers else 1 + max(t.wavelength for t in self.transfers)


@dataclass
class WRHTSchedule:
    n: int
    w: int
    m: int
    steps: list[Step] = field(default_factory=list)
    levels: list[list[int]] = field(default_factory=list)  # active nodes per level

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    @property
    def reduce_steps(self) -> int:
        return sum(1 for s in self.steps if s.kind in ("reduce", "alltoall"))

    @property
    def broadcast_steps(self) -> int:
        return sum(1 for s in self.steps if s.kind == "broadcast")


def optimal_group_size(w: int) -> int:
    """Lemma 1: with two fibers and two Tx/Rx sets per node, the largest
    group a representative can drain in one step is ``m = 2w + 1``."""
    return 2 * w + 1


def _chunks(seq: list[int], m: int) -> list[list[int]]:
    return [seq[i : i + m] for i in range(0, len(seq), m)]


def _alltoall_fits(reps: list[int], ring: Ring, d_bits: float) -> list[Transfer] | None:
    """Try to schedule a one-step all-to-all among ``reps``; None if > w."""
    if len(reps) < 2:
        return None
    # Paper Sec. III-C-2 / [16]: all-to-all among m* ring nodes needs
    # ⌈m*²/8⌉ wavelengths.  Cheap necessary condition before running RWA —
    # also keeps the O(r²) enumeration off the N=4096 level-0 case.
    if math.ceil(len(reps) ** 2 / 8) > ring.w:
        return None
    transfers = []
    for src in reps:
        for dst in reps:
            if src == dst:
                continue
            direction = shortest_direction(src, dst, ring.n)
            transfers.append(Transfer(src, dst, direction, d_bits))
    try:
        return first_fit_assign(transfers, ring.n, ring.w)
    except WavelengthConflictError:
        return None


def build_schedule(
    n: int,
    w: int,
    d_bits: float,
    m: int | None = None,
    allow_alltoall: bool = True,
    bandwidth_bps: float = 40e9,
    reconfig_delay_s: float = 25e-6,
    validate: bool = True,
) -> WRHTSchedule:
    """Construct and validate the full WRHT schedule for an N-node ring."""
    if n < 1:
        raise ValueError("need >= 1 node")
    ring = Ring(max(n, 2), w, bandwidth_bps=bandwidth_bps, reconfig_delay_s=reconfig_delay_s)
    if m is None:
        m = optimal_group_size(w)
    if m < 2:
        raise ValueError("group size m must be >= 2")
    # Lemma 1 feasibility: a group of m nodes drains over two fibers with
    # ⌈(m-1)/2⌉ wavelengths per side; beyond m = 2w+1 the step cannot be
    # conflict-free, so clamp (callers probing larger m get the feasible max).
    m = min(m, optimal_group_size(w))

    sched = WRHTSchedule(n=n, w=w, m=m)
    sched.levels.append(list(range(n)))
    if n == 1:
        return sched

    # ---------------- reduce stage ----------------
    reduce_groups: list[list[list[int]]] = []  # per level: list of groups
    level = 0
    while len(sched.levels[-1]) > 1:
        active = sched.levels[-1]
        if allow_alltoall:
            a2a = _alltoall_fits(active, ring, d_bits)
            if a2a is not None:
                sched.steps.append(Step("alltoall", level, a2a))
                break
        groups = _chunks(active, m)
        transfers: list[Transfer] = []
        reps: list[int] = []
        for g in groups:
            mid = len(g) // 2
            rep = g[mid]
            reps.append(rep)
            for i, node in enumerate(g):
                if node == rep:
                    continue
                # left-of-rep members transmit clockwise, right-of-rep
                # counter-clockwise (two Rx sets per node, Sec. III-B).
                direction = CW if i < mid else CCW
                transfers.append(Transfer(node, rep, direction, d_bits))
        assigned = first_fit_assign(transfers, ring.n, ring.w)
        sched.steps.append(Step("reduce", level, assigned))
        reduce_groups.append(groups)
        sched.levels.append(reps)
        level += 1

    # ---------------- broadcast stage ----------------
    # Reverse of the reduce tree (the all-to-all step, if any, already left
    # every surviving representative with the full reduction).
    for level in range(len(reduce_groups) - 1, -1, -1):
        transfers = []
        for g in reduce_groups[level]:
            mid = len(g) // 2
            rep = g[mid]
            for i, node in enumerate(g):
                if node == rep:
                    continue
                direction = CCW if i < mid else CW  # reversed paths
                transfers.append(Transfer(rep, node, direction, d_bits))
        assigned = first_fit_assign(transfers, ring.n, ring.w)
        sched.steps.append(Step("broadcast", level, assigned))

    if validate:
        validate_schedule(sched, ring)
    return sched


# ------------------------------------------------------------------
# Validation: structural (wavelengths) and semantic (all-reduce).
# ------------------------------------------------------------------

def validate_schedule(sched: WRHTSchedule, ring: Ring | None = None) -> None:
    ring = ring or Ring(max(sched.n, 2), sched.w)
    for step in sched.steps:
        validate_no_conflicts(step.transfers, ring.n, ring.w)
    masks = simulate_contribution_masks(sched)
    full = (1 << sched.n) - 1
    bad = [i for i, s in enumerate(masks) if s != full]
    if bad:
        raise AssertionError(
            f"all-reduce semantics violated: nodes {bad[:8]} missing contributions"
        )


def simulate_contribution_masks(sched: WRHTSchedule) -> list[int]:
    """Data-flow simulation: node i starts with bit i; transfers OR bitmasks.

    A correct all-reduce leaves every node with all n bits set (summation is
    a commutative-associative reduction, so bit-union tracks it faithfully).
    Bitmask ints keep this O(n·steps) with tiny constants even at n=4096.
    """
    state: list[int] = [1 << i for i in range(sched.n)]
    for step in sched.steps:
        snapshot = list(state)  # ints are immutable: O(n) snapshot
        incoming: dict[int, int] = {}
        for t in step.transfers:
            incoming[t.dst] = incoming.get(t.dst, 0) | snapshot[t.src]
        for dst, data in incoming.items():
            if step.kind == "broadcast":
                # broadcast overwrites with the rep's full value
                state[dst] = data
            else:
                state[dst] |= data
    return state


def simulate_contributions(sched: WRHTSchedule) -> list[frozenset[int]]:
    """Set view of :func:`simulate_contribution_masks` (test convenience)."""
    return [
        frozenset(i for i in range(sched.n) if mask >> i & 1)
        for mask in simulate_contribution_masks(sched)
    ]


def theoretical_steps(n: int, m: int) -> tuple[int, int]:
    """Closed form of Sec. III-D: (with all-to-all, without) step counts."""
    if n <= 1:
        return (0, 0)
    l = max(1, math.ceil(math.log(n, m)))
    return (2 * l - 1, 2 * l)
