"""WRHT — Wavelength-Reused Hierarchical Tree all-reduce schedule builder.

This is the paper's primary contribution (Sec. III-C).  Given ``N`` nodes on a
bidirectional WDM ring with ``w`` wavelengths per fiber, build the explicit
per-step transfer schedule:

Reduce stage
    Level 0 partitions the ring into contiguous groups of ``m`` nodes; the
    *middle* node of each group is the representative and receives every
    member's (partially reduced) vector in ONE step — members to its left
    transmit clockwise, members to its right counter-clockwise, so the two
    fibers are loaded symmetrically and ``⌈m/2⌉`` wavelengths suffice.
    Representatives of level ``ℓ`` are regrouped at level ``ℓ+1``.  Recursion
    stops when the surviving representatives can finish with a single
    all-to-all exchange within the wavelength budget (paper Sec. III-C-2:
    ``⌈m*²/8⌉`` wavelengths, citation [16]), or when one root remains.

Broadcast stage
    Exact reverse of the reduce stage (paths reversed, same wavelength
    budget).  Because a reduction is applied at every reduce step, every
    transfer in BOTH stages carries the constant full vector of ``d`` bits.

Total steps: ``2⌈log_m N⌉`` (single root) or ``2⌈log_m N⌉ − 1`` (final
all-to-all) — asserted against the closed forms in ``step_models`` by the
test-suite.  ``m = 2w + 1`` is the Lemma-1 optimum: each fiber then carries
exactly ``w`` concurrent intra-group lightpaths.

Steps are represented as :class:`~repro.core.topology.TransferBatch`
structure-of-arrays (see DESIGN.md §1); transfer generation, RWA, conflict
validation and the semantic data-flow check are all array programs, so
building *and fully validating* a schedule is cheap even at N=32768.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .topology import CCW, CW, PhysicalParams, Ring, TransferBatch
from .wavelength import (
    WavelengthConflictError,
    first_fit_assign,
    first_fit_assign_concat,
    first_fit_assign_reference,
    split_overlong_arcs,
    validate_no_conflicts,
)


@dataclass
class Step:
    kind: str                      # "reduce" | "alltoall" | "broadcast"
    level: int                     # tree level (alltoall: top level)
    transfers: TransferBatch

    def __post_init__(self) -> None:
        self.transfers = TransferBatch.coerce(self.transfers)

    @property
    def wavelengths(self) -> int:
        return 1 + self.transfers.max_wavelength if len(self.transfers) else 0


@dataclass
class WRHTSchedule:
    n: int
    w: int
    m: int
    steps: list[Step] = field(default_factory=list)
    levels: list[list[int]] = field(default_factory=list)  # active nodes per level
    max_hops: int | None = None            # insertion-loss hop budget, if any
    level_group_sizes: list[int] = field(default_factory=list)  # m used per level

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    @property
    def reduce_steps(self) -> int:
        return sum(1 for s in self.steps if s.kind in ("reduce", "alltoall"))

    @property
    def broadcast_steps(self) -> int:
        return sum(1 for s in self.steps if s.kind == "broadcast")


def optimal_group_size(w: int) -> int:
    """Lemma 1: with two fibers and two Tx/Rx sets per node, the largest
    group a representative can drain in one step is ``m = 2w + 1``."""
    return 2 * w + 1


def _cap_group_size(m: int, max_hops: int | None, spacing: int) -> int:
    """Insertion-loss fan-out cap: with a hop budget ``H`` and active nodes
    ``spacing`` segments apart, the farthest member a middle representative
    can reach is ``H // spacing`` active positions away, so at most
    ``2·(H // spacing) + 1`` nodes fit in one group (floored at 2 so the
    tree always makes progress)."""
    if max_hops is not None:
        m = min(m, max(2, 2 * (max_hops // max(1, spacing)) + 1))
    return m


def feasible_group_size(w: int, max_hops: int | None = None, spacing: int = 1) -> int:
    """Lemma-1 optimum capped by the insertion-loss fan-out limit.

    A group of 2 whose pair distance still exceeds ``H`` must be relayed —
    ``build_schedule`` does this automatically.
    """
    return _cap_group_size(optimal_group_size(w), max_hops, spacing)


def _assigner(rwa: str):
    if rwa == "fast":
        return first_fit_assign
    if rwa == "reference":
        return lambda batch, n, w: TransferBatch.from_transfers(
            first_fit_assign_reference(TransferBatch.coerce(batch).to_transfers(), n, w)
        )
    raise ValueError(f"unknown rwa {rwa!r} (expected 'fast' or 'reference')")


@dataclass(frozen=True)
class _LevelGrouping:
    """Grouping arrays of one tree level: the shared structure from which
    the reduce batch, the broadcast batch and the closed-form First-Fit
    assignment are all derived (DESIGN.md §10)."""

    reps: np.ndarray       # representative node per group        [G]
    members: np.ndarray    # member nodes, group-major order      [T]
    rep_for: np.ndarray    # each member's representative         [T]
    left: np.ndarray       # member sits left of its rep          [T] bool
    pos: np.ndarray        # member's in-group position           [T]
    gsize_for: np.ndarray  # size of the member's group           [T]


def _level_grouping(active: np.ndarray, m: int) -> _LevelGrouping:
    """Partition ``active`` into runs of ``m`` with middle representatives.

    Row order matches the original per-object builder exactly (group-major,
    member position order, representative skipped) so that stable
    longest-first RWA ties break identically.
    """
    count = active.size
    n_groups = -(-count // m)
    idx = np.arange(count)
    gi = idx // m
    pos = idx - gi * m
    gsize = np.full(n_groups, m, dtype=np.int64)
    gsize[-1] = count - (n_groups - 1) * m
    mid = gsize // 2
    reps = active[np.arange(n_groups) * m + mid]
    member = pos != mid[gi]
    gim = gi[member]
    posm = pos[member]
    return _LevelGrouping(
        reps=reps, members=active[member], rep_for=reps[gim],
        left=posm < mid[gim], pos=posm, gsize_for=gsize[gim],
    )


def _grouping_batch(g: _LevelGrouping, d_bits: float, broadcast: bool,
                    wavelength=None) -> TransferBatch:
    """Materialize one level's transfers from its grouping arrays.

    Left-of-rep members transmit clockwise, right-of-rep counter-clockwise
    (two Rx sets per node, Sec. III-B); broadcast reverses the paths.
    """
    if broadcast:
        return TransferBatch.from_arrays(
            g.rep_for, g.members, np.where(g.left, CCW, CW), d_bits,
            wavelength=wavelength, check=False
        )
    return TransferBatch.from_arrays(
        g.members, g.rep_for, np.where(g.left, CW, CCW), d_bits,
        wavelength=wavelength, check=False
    )


def _level_transfers(
    active: np.ndarray, m: int, d_bits: float, broadcast: bool
) -> tuple[TransferBatch, np.ndarray]:
    """Member↔representative transfers for one tree level, as arrays."""
    g = _level_grouping(active, m)
    return _grouping_batch(g, d_bits, broadcast), g.reps


def _level_wavelengths(g: _LevelGrouping) -> np.ndarray:
    """Closed-form First-Fit assignment for one plain tree level.

    Within a group the two sides load disjoint fiber lanes, and the arcs of
    one side are strictly nested toward the representative (lengths strictly
    decrease as the member approaches it); different groups of the level
    never share a directed segment on the same lane.  Longest-first First
    Fit therefore gives the member at in-group position ``p`` wavelength
    ``p`` (left side) or ``gsize − 1 − p`` (right side), on both stages —
    the broadcast step's arcs are the lane-mirrored image of the reduce
    step's, so the per-row assignment is identical.  Bit-identity to
    :func:`~repro.core.wavelength.first_fit_assign` on the materialized
    batch is pinned by the golden tests of the batched builder
    (DESIGN.md §10).
    """
    return np.where(g.left, g.pos, g.gsize_for - 1 - g.pos)


def _alltoall_fits(
    reps: np.ndarray, ring: Ring, d_bits: float, rwa: str = "fast",
    max_hops: int | None = None,
) -> TransferBatch | None:
    """Try to schedule a one-step all-to-all among ``reps``; None if > w
    or if any pairwise lightpath would exceed the insertion-loss budget."""
    r = reps.size
    if r < 2:
        return None
    # Paper Sec. III-C-2 / [16]: all-to-all among m* ring nodes needs
    # ⌈m*²/8⌉ wavelengths.  Cheap necessary condition before running RWA —
    # also keeps the O(r²) enumeration off the N=4096 level-0 case.
    if math.ceil(r ** 2 / 8) > ring.w:
        return None
    src, dst = np.meshgrid(reps, reps, indexing="ij")
    off = ~np.eye(r, dtype=bool)
    src, dst = src[off], dst[off]
    cw = (dst - src) % ring.n <= (src - dst) % ring.n  # shortest_direction
    batch = TransferBatch.from_arrays(
        src, dst, np.where(cw, CW, CCW), d_bits, check=False
    )
    if max_hops is not None and (batch.arcs(ring.n)[2] > max_hops).any():
        return None  # some pair is out of optical reach — keep climbing the tree
    try:
        return _assigner(rwa)(batch, ring.n, ring.w)
    except WavelengthConflictError:
        return None


def _level_cap(active: np.ndarray, m: int, max_hops: int | None) -> tuple[int, bool]:
    """Group size usable at this level under the hop budget, and whether the
    level's transfers need O/E/O relays.

    Active nodes are grouped by index order, so a member→representative path
    covers the ring gaps between consecutive actives; with worst gap
    ``g_max`` the farthest of ``m`` members is ``⌈(m-1)/2⌉ · g_max`` segments
    out.  Capping ``m`` at ``2·(H // g_max) + 1`` keeps every lightpath
    within the budget.  When even adjacent actives are out of reach
    (``H < g_max``), fall back to pairing (m=2) with relayed transfers.
    """
    if max_hops is None or active.size < 2:
        return m, False
    g_max = int(np.diff(active).max())
    if max_hops < g_max:
        return 2, True
    return _cap_group_size(m, max_hops, g_max), False


def _append_level(
    sched: WRHTSchedule, kind: str, level: int, batch: TransferBatch,
    relay: bool, ring: Ring, assign, max_hops: int | None,
) -> None:
    """Emit one tree level as a Step, splitting into relay sub-steps when the
    hop budget demands it (each sub-step re-runs RWA)."""
    if relay:
        for sub in split_overlong_arcs(batch, ring.n, max_hops):
            sched.steps.append(Step(kind, level, assign(sub, ring.n, ring.w)))
    else:
        sched.steps.append(Step(kind, level, assign(batch, ring.n, ring.w)))


def build_schedule(
    n: int,
    w: int,
    d_bits: float,
    m: int | None = None,
    allow_alltoall: bool = True,
    bandwidth_bps: float = 40e9,
    reconfig_delay_s: float = 25e-6,
    validate: bool = True,
    rwa: str = "fast",
    physical: PhysicalParams | None = None,
    max_hops: int | None = None,
) -> WRHTSchedule:
    """Construct and validate the full WRHT schedule for an N-node ring.

    ``rwa`` selects the wavelength assigner: ``"fast"`` (vectorized bitmask
    First Fit) or ``"reference"`` (original per-object greedy) — the two are
    bit-identical; the knob exists for the equivalence test and the
    schedule-build benchmark.

    ``physical`` (or an explicit ``max_hops``) enables the insertion-loss
    constraint (paper Sec. III): the per-level group size is capped so no
    lightpath exceeds the hop budget, the final all-to-all is only taken
    when every pair is within reach, and levels whose active nodes have
    drifted beyond the budget are relayed through intermediate O/E/O
    regeneration sub-steps.  The resulting schedule never contains a
    transfer longer than the budget (enforced by :func:`validate_schedule`).
    """
    if n < 1:
        raise ValueError("need >= 1 node")
    if max_hops is None and physical is not None:
        max_hops = physical.max_hops
    if max_hops is not None and max_hops < 1:
        raise ValueError("insertion-loss hop budget must allow >= 1 hop")
    ring = Ring(max(n, 2), w, bandwidth_bps=bandwidth_bps,
                reconfig_delay_s=reconfig_delay_s, physical=physical)
    if m is None:
        m = optimal_group_size(w)
    if m < 2:
        raise ValueError("group size m must be >= 2")
    # Lemma 1 feasibility: a group of m nodes drains over two fibers with
    # ⌈(m-1)/2⌉ wavelengths per side; beyond m = 2w+1 the step cannot be
    # conflict-free, so clamp (callers probing larger m get the feasible max).
    m = min(m, optimal_group_size(w))
    # level-0 fan-out cap (unit spacing); deeper levels re-cap per spacing
    # in _level_cap as the active nodes spread out
    m = _cap_group_size(m, max_hops, 1)
    assign = _assigner(rwa)

    sched = WRHTSchedule(n=n, w=w, m=m, max_hops=max_hops)
    active = np.arange(n, dtype=np.int64)
    sched.levels.append(active.tolist())
    if n == 1:
        return sched

    # ---------------- reduce stage ----------------
    reduce_actives: list[np.ndarray] = []  # the grouping input per level
    level_meta: list[tuple[int, bool]] = []  # (group size, relayed) per level
    level = 0
    while active.size > 1:
        if allow_alltoall:
            a2a = _alltoall_fits(active, ring, d_bits, rwa, max_hops=max_hops)
            if a2a is not None:
                sched.steps.append(Step("alltoall", level, a2a))
                break
        m_lvl, relay = _level_cap(active, m, max_hops)
        batch, reps = _level_transfers(active, m_lvl, d_bits, broadcast=False)
        _append_level(sched, "reduce", level, batch, relay, ring, assign, max_hops)
        reduce_actives.append(active)
        level_meta.append((m_lvl, relay))
        sched.level_group_sizes.append(m_lvl)
        active = reps
        sched.levels.append(active.tolist())
        level += 1

    # ---------------- broadcast stage ----------------
    # Reverse of the reduce tree (the all-to-all step, if any, already left
    # every surviving representative with the full reduction).
    for level in range(len(reduce_actives) - 1, -1, -1):
        m_lvl, relay = level_meta[level]
        batch, _ = _level_transfers(reduce_actives[level], m_lvl, d_bits,
                                    broadcast=True)
        _append_level(sched, "broadcast", level, batch, relay, ring, assign,
                      max_hops)

    if validate:
        validate_schedule(sched, ring)
    return sched


# ------------------------------------------------------------------
# Batched multi-candidate builder (DESIGN.md §10).
# ------------------------------------------------------------------

def _concat_batches(batches: list[TransferBatch]) -> tuple[TransferBatch, np.ndarray]:
    """Concatenate step batches into one arc batch with offset pointers."""
    ptr = np.zeros(len(batches) + 1, dtype=np.int64)
    np.cumsum([len(b) for b in batches], out=ptr[1:])
    cat = TransferBatch(
        np.concatenate([b.src for b in batches]),
        np.concatenate([b.dst for b in batches]),
        np.concatenate([b.direction for b in batches]),
        np.concatenate([b.bits for b in batches]),
        np.concatenate([b.wavelength for b in batches]),
    )
    return cat, ptr


def _split_batch(batch: TransferBatch, ptr: np.ndarray) -> list[TransferBatch]:
    """Slice an assigned concatenated batch back into per-step batches."""
    memo = batch._arcs
    out = []
    for lo, hi in zip(ptr[:-1].tolist(), ptr[1:].tolist()):
        sub = TransferBatch(batch.src[lo:hi], batch.dst[lo:hi],
                            batch.direction[lo:hi], batch.bits[lo:hi],
                            batch.wavelength[lo:hi])
        if memo is not None:  # per-row geometry slices with the columns
            sub._arcs = (memo[0], memo[1][lo:hi], memo[2][lo:hi],
                         memo[3][lo:hi])
        out.append(sub)
    return out


def build_candidate_schedules(
    n: int,
    w: int,
    d_bits: float,
    m_candidates=None,
    allow_alltoall: bool = True,
    bandwidth_bps: float = 40e9,
    reconfig_delay_s: float = 25e-6,
    validate: bool = True,
    rwa: str = "fast",
    physical: PhysicalParams | None = None,
    max_hops: int | None = None,
) -> dict[tuple[int, bool], WRHTSchedule]:
    """Build every candidate WRHT schedule of a fan-out sweep in one pass.

    The auto-tuner costs one schedule per ``(m, alltoall)`` candidate;
    rebuilding each from scratch repeats the level walk, the RWA and the
    validation ~2× per fan-out.  This builder amortizes the sweep
    (DESIGN.md §10):

    * the all-to-all and no-all-to-all variants of one ``m`` share their
      per-level active-node/grouping arrays and their ``Step`` objects —
      the full tree is walked once and the variant that took the all-to-all
      at level ``L`` is the slice ``reduce[:L] + [alltoall] +
      broadcast[L-1::-1]`` of it;
    * plain tree levels take the closed-form First-Fit assignment
      (:func:`_level_wavelengths`) instead of running the greedy;
    * relay chains under a hop budget run First-Fit over concatenated
      per-sub-step arc batches with offset pointers
      (:func:`~repro.core.wavelength.first_fit_assign_concat`), sharing one
      translated-component dedup table across every candidate and both
      stages (a broadcast step's components are the lane-mirror of its
      reduce step's, so mirrors are cache hits).

    Returns ``{(m, alltoall): schedule}`` in candidate order, each entry
    **bit-identical** to ``build_schedule(n, w, d_bits, m=m,
    allow_alltoall=alltoall, ...)`` (golden-tested).  The ``(m, False)``
    variant is materialized only when the ``(m, True)`` build actually took
    the all-to-all — otherwise the two are the same schedule.  ``m`` keys
    are the *requested* fan-outs (the per-schedule ``m`` field carries the
    Lemma-1/hop-budget clamp, as in ``build_schedule``).

    ``validate=True`` checks wavelength conflicts and the hop budget once
    per unique step batch plus all-reduce semantics per candidate; the
    tuner passes ``False`` (construction is conflict-free by design and the
    winning schedule is re-validated when materialized through the plan
    cache).
    """
    if n < 1:
        raise ValueError("need >= 1 node")
    if max_hops is None and physical is not None:
        max_hops = physical.max_hops
    if max_hops is not None and max_hops < 1:
        raise ValueError("insertion-loss hop budget must allow >= 1 hop")
    ring = Ring(max(n, 2), w, bandwidth_bps=bandwidth_bps,
                reconfig_delay_s=reconfig_delay_s, physical=physical)
    if m_candidates is None:
        m_candidates = range(2, feasible_group_size(w, max_hops) + 1)
    ms: list[int] = []
    for m in m_candidates:
        m = int(m)
        if m < 2:
            raise ValueError("group size m must be >= 2")
        if m not in ms:
            ms.append(m)
    assign = _assigner(rwa)
    closed_form = rwa == "fast"
    rwa_cache: dict = {}  # translated-component dedup, shared by all candidates

    def emit_level(kind: str, level: int, g: _LevelGrouping,
                   relay: bool, broadcast: bool) -> list[Step]:
        batch = _grouping_batch(g, d_bits, broadcast)
        if relay:
            subs = split_overlong_arcs(batch, ring.n, max_hops)
            if closed_form:
                cat, ptr = _concat_batches(subs)
                assigned = first_fit_assign_concat(cat, ptr, ring.n, ring.w,
                                                   cache=rwa_cache)
                subs = _split_batch(assigned, ptr)
            else:
                subs = [assign(sub, ring.n, ring.w) for sub in subs]
            return [Step(kind, level, sub) for sub in subs]
        if closed_form:
            return [Step(kind, level, batch.with_wavelengths(_level_wavelengths(g)))]
        return [Step(kind, level, assign(batch, ring.n, ring.w))]

    out: dict[tuple[int, bool], WRHTSchedule] = {}
    for m_req in ms:
        # same clamps as build_schedule: Lemma 1 then the level-0 fan-out cap
        m = _cap_group_size(min(m_req, optimal_group_size(w)), max_hops, 1)
        active = np.arange(n, dtype=np.int64)
        levels = [active]
        if n == 1:
            out[(m_req, allow_alltoall)] = WRHTSchedule(
                n=n, w=w, m=m, levels=[active.tolist()], max_hops=max_hops)
            continue

        reduce_steps: list[list[Step]] = []   # Steps per level (relays split)
        groupings: list[_LevelGrouping] = []
        meta: list[tuple[int, bool]] = []     # (m_lvl, relay) per level
        a2a_at: int | None = None
        a2a_step: Step | None = None
        level = 0
        while active.size > 1:
            if allow_alltoall and a2a_at is None:
                fit = _alltoall_fits(active, ring, d_bits, rwa,
                                     max_hops=max_hops)
                if fit is not None:
                    # the all-to-all variant stops here; keep walking the
                    # tree — the no-all-to-all variant needs the rest
                    a2a_at = level
                    a2a_step = Step("alltoall", level, fit)
            m_lvl, relay = _level_cap(active, m, max_hops)
            g = _level_grouping(active, m_lvl)
            reduce_steps.append(emit_level("reduce", level, g, relay, False))
            groupings.append(g)
            meta.append((m_lvl, relay))
            active = g.reps
            levels.append(active)
            level += 1

        bcast_steps = [
            emit_level("broadcast", lvl, g, meta[lvl][1], True)
            for lvl, g in enumerate(groupings)
        ]

        def assemble(depth: int, tail: list[Step]) -> list[Step]:
            steps = [s for lvl in range(depth) for s in reduce_steps[lvl]]
            steps.extend(tail)
            for lvl in range(depth - 1, -1, -1):
                steps.extend(bcast_steps[lvl])
            return steps

        full_tree = WRHTSchedule(
            n=n, w=w, m=m, steps=assemble(len(groupings), []),
            levels=[l.tolist() for l in levels], max_hops=max_hops,
            level_group_sizes=[ml for ml, _ in meta],
        )
        if a2a_at is None:
            out[(m_req, allow_alltoall)] = full_tree
        else:
            out[(m_req, True)] = WRHTSchedule(
                n=n, w=w, m=m, steps=assemble(a2a_at, [a2a_step]),
                levels=[levels[i].tolist() for i in range(a2a_at + 1)],
                max_hops=max_hops,
                level_group_sizes=[meta[i][0] for i in range(a2a_at)],
            )
            out[(m_req, False)] = full_tree

    if validate:
        hops_budget = max_hops if max_hops is not None else ring.max_hops
        seen: set[int] = set()
        for sched in out.values():
            for step in sched.steps:
                if id(step.transfers) not in seen:
                    seen.add(id(step.transfers))
                    validate_no_conflicts(step.transfers, ring.n, ring.w,
                                          max_hops=hops_budget)
            bad = _incomplete_nodes(_contribution_words(sched), sched.n)
            if bad:
                raise AssertionError(
                    f"all-reduce semantics violated: nodes {bad[:8]} missing "
                    "contributions"
                )
    return out


# ------------------------------------------------------------------
# Validation: structural (wavelengths) and semantic (all-reduce).
# ------------------------------------------------------------------

def validate_schedule(sched: WRHTSchedule, ring: Ring | None = None) -> None:
    """Structural validation (wavelengths + insertion loss) then semantic.

    The hop budget comes from the schedule itself or, failing that, from the
    ring's physical model — a schedule built without the constraint validates
    as before.
    """
    ring = ring or Ring(max(sched.n, 2), sched.w)
    max_hops = sched.max_hops if sched.max_hops is not None else ring.max_hops
    for step in sched.steps:
        validate_no_conflicts(step.transfers, ring.n, ring.w, max_hops=max_hops)
    words = _contribution_words(sched)
    bad = _incomplete_nodes(words, sched.n)
    if bad:
        raise AssertionError(
            f"all-reduce semantics violated: nodes {bad[:8]} missing contributions"
        )


def _contribution_words(sched: WRHTSchedule) -> np.ndarray:
    """Data-flow simulation over uint64 bitset rows (one row per node)."""
    n = sched.n
    n_words = (n + 63) // 64
    state = np.zeros((n, n_words), dtype=np.uint64)
    ids = np.arange(n)
    state[ids, ids // 64] = np.left_shift(
        np.uint64(1), (ids % 64).astype(np.uint64)
    )
    for step in sched.steps:
        batch = step.transfers
        if len(batch) == 0:
            continue
        order = np.argsort(batch.dst, kind="stable")
        srcs, dsts = batch.src[order], batch.dst[order]
        gathered = state[srcs]  # all reads precede all writes within a step
        bounds = np.flatnonzero(np.r_[True, dsts[1:] != dsts[:-1]])
        if bounds.size == dsts.size:
            # every receiver gets exactly one transfer (e.g. broadcast):
            # reduceat over singleton groups is pathologically slow, skip it
            merged, receivers = gathered, dsts
        else:
            merged = np.bitwise_or.reduceat(gathered, bounds, axis=0)
            receivers = dsts[bounds]
        if step.kind == "broadcast":
            # broadcast overwrites with the rep's full value
            state[receivers] = merged
        else:
            state[receivers] |= merged
    return state


def _incomplete_nodes(words: np.ndarray, n: int) -> list[int]:
    full = np.full(words.shape[1], np.uint64(0xFFFFFFFFFFFFFFFF))
    tail = n % 64
    if tail:
        full[-1] = np.uint64((1 << tail) - 1)
    return np.flatnonzero((words != full).any(axis=1)).tolist()


def simulate_contribution_masks(sched: WRHTSchedule) -> list[int]:
    """Per-node contribution bitmask: node i starts with bit i; transfers OR.

    A correct all-reduce leaves every node with all n bits set (summation is
    a commutative-associative reduction, so bit-union tracks it faithfully).
    """
    words = _contribution_words(sched)
    return [
        int.from_bytes(words[i].astype("<u8").tobytes(), "little")
        for i in range(sched.n)
    ]


def simulate_contributions(sched: WRHTSchedule) -> list[frozenset[int]]:
    """Set view of :func:`simulate_contribution_masks` (test convenience)."""
    return [
        frozenset(i for i in range(sched.n) if mask >> i & 1)
        for mask in simulate_contribution_masks(sched)
    ]


def theoretical_steps(n: int, m: int) -> tuple[int, int]:
    """Closed form of Sec. III-D: (with all-to-all, without) step counts."""
    if n <= 1:
        return (0, 0)
    l = max(1, math.ceil(math.log(n, m)))
    return (2 * l - 1, 2 * l)
