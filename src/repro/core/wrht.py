"""WRHT — Wavelength-Reused Hierarchical Tree schedule builder + the
scheduled collective algebra (DESIGN.md §11).

The paper derives WRHT only for all-reduce (Sec. III-C), but its two phases
are a wavelength-reused reduce tree followed by a broadcast tree.  This
module exposes those phases — plus the ring reduce-scatter / all-gather pass
and the single-step all-to-all finisher — as first-class plannable
collectives (:class:`Collective`, :func:`build_collective_schedule`), each
with an explicit semantic spec (per-node contribution/ownership masks and
payload-per-step accounting) validated by :func:`validate_schedule`.

For the paper's all-reduce, given ``N`` nodes on a bidirectional WDM ring
with ``w`` wavelengths per fiber, build the explicit per-step transfer
schedule:

Reduce stage
    Level 0 partitions the ring into contiguous groups of ``m`` nodes; the
    *middle* node of each group is the representative and receives every
    member's (partially reduced) vector in ONE step — members to its left
    transmit clockwise, members to its right counter-clockwise, so the two
    fibers are loaded symmetrically and ``⌈m/2⌉`` wavelengths suffice.
    Representatives of level ``ℓ`` are regrouped at level ``ℓ+1``.  Recursion
    stops when the surviving representatives can finish with a single
    all-to-all exchange within the wavelength budget (paper Sec. III-C-2:
    ``⌈m*²/8⌉`` wavelengths, citation [16]), or when one root remains.

Broadcast stage
    Exact reverse of the reduce stage (paths reversed, same wavelength
    budget).  Because a reduction is applied at every reduce step, every
    transfer in BOTH stages carries the constant full vector of ``d`` bits.

Total steps: ``2⌈log_m N⌉`` (single root) or ``2⌈log_m N⌉ − 1`` (final
all-to-all) — asserted against the closed forms in ``step_models`` by the
test-suite.  ``m = 2w + 1`` is the Lemma-1 optimum: each fiber then carries
exactly ``w`` concurrent intra-group lightpaths.

Steps are represented as :class:`~repro.core.topology.TransferBatch`
structure-of-arrays (see DESIGN.md §1); transfer generation, RWA, conflict
validation and the semantic data-flow check are all array programs, so
building *and fully validating* a schedule is cheap even at N=32768.
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field

import numpy as np

from .topology import CCW, CW, FailureMask, PhysicalParams, Ring, TransferBatch
from .wavelength import (
    InsertionLossError,
    WavelengthConflictError,
    _covers_dead_segment,
    _uses_dead_transceiver,
    first_fit_assign,
    first_fit_assign_concat,
    first_fit_assign_reference,
    split_overlong_arcs,
    validate_no_conflicts,
)


class DegradedInfeasibleError(RuntimeError):
    """No feasible schedule exists under the given :class:`FailureMask`.

    The uniform infeasibility signal of degraded-mode building
    (DESIGN.md §12): raised when a transfer's route is cut in *both* ring
    directions, when no live O/E/O relay exists within the hop budget, or
    when the surviving wavelengths cannot carry a required step (the
    original :class:`WavelengthConflictError` is chained as ``__cause__``).
    Healthy-mode builds (no mask) never raise this.
    """


@dataclass
class Step:
    kind: str                      # "reduce" | "alltoall" | "broadcast" | ...
    level: int                     # tree level (alltoall: top level)
    transfers: TransferBatch
    # chunked collectives (reduce_scatter / all_gather / alltoall): shard id
    # carried by each transfer row — TransferBatch stays payload-agnostic,
    # the chunk identity lives on the Step so a shared batch object can back
    # many steps each moving different shards (DESIGN.md §11)
    chunks: np.ndarray | None = None

    def __post_init__(self) -> None:
        self.transfers = TransferBatch.coerce(self.transfers)

    @property
    def wavelengths(self) -> int:
        return 1 + self.transfers.max_wavelength if len(self.transfers) else 0


@dataclass
class WRHTSchedule:
    n: int
    w: int
    m: int
    steps: list[Step] = field(default_factory=list)
    levels: list[list[int]] = field(default_factory=list)  # active nodes per level
    max_hops: int | None = None            # insertion-loss hop budget, if any
    level_group_sizes: list[int] = field(default_factory=list)  # m used per level
    collective: str = "allreduce"          # which Collective this schedule runs
    failures: FailureMask | None = None    # mask the schedule routes around

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    @property
    def reduce_steps(self) -> int:
        return sum(1 for s in self.steps if s.kind in ("reduce", "alltoall"))

    @property
    def broadcast_steps(self) -> int:
        return sum(1 for s in self.steps if s.kind == "broadcast")


# ------------------------------------------------------------------
# The scheduled collective algebra (DESIGN.md §11).
# ------------------------------------------------------------------

class Collective(str, enum.Enum):
    """The collectives the schedule builder can emit on the optical ring.

    Every member reuses phases of the all-reduce machinery (DESIGN.md §11):

    ``ALLREDUCE``       reduce tree [+ all-to-all finisher] + broadcast tree
                        (the paper's WRHT, Sec. III-C); full ``d`` per step.
    ``REDUCE_SCATTER``  ring pass: ``N-1`` neighbour steps of ``d/N`` chunks;
                        node ``i`` ends owning the complete reduction of
                        chunk ``i``.
    ``ALL_GATHER``      ring pass, mirrored: node ``i`` starts owning chunk
                        ``i``; ``N-1`` steps later every node holds every
                        chunk.
    ``BROADCAST``       the WRHT broadcast tree alone: the root (the tree's
                        final surviving representative) propagates the full
                        vector down the levels; full ``d`` per step.
    ``ALLTOALL``        the single-step full-mesh exchange (paper
                        Sec. III-C-2 / [16]): every ordered pair trades a
                        personalized ``d/N`` shard in ONE reconfiguration,
                        needing ``⌈N²/8⌉`` wavelengths — the one-step
                        finisher for reduce-scatter *and* all-gather.
    """

    ALLREDUCE = "allreduce"
    REDUCE_SCATTER = "reduce_scatter"
    ALL_GATHER = "all_gather"
    BROADCAST = "broadcast"
    ALLTOALL = "alltoall"


@dataclass(frozen=True)
class CollectiveSpec:
    """Semantic spec of one scheduled collective (DESIGN.md §11).

    ``tree`` marks the fan-out ``m`` (and, for all-reduce, the all-to-all
    finisher flag) as meaningful plan dimensions; ``chunked`` marks the
    payload accounting: every transfer carries ``d / n`` bits (the division
    chain :meth:`payload_divisors`) instead of the constant full vector.
    Ownership semantics are enforced by :func:`validate_schedule` against
    the data-flow oracles below.
    """

    name: str
    tree: bool
    chunked: bool
    description: str

    def payload_divisors(self, n: int) -> tuple[float, ...]:
        """Division chain from the payload ``d`` to one transfer's bits
        (the ``timing.PayloadClass`` contract: applied left to right)."""
        return (float(n),) if self.chunked else ()


COLLECTIVES: dict[str, CollectiveSpec] = {
    "allreduce": CollectiveSpec(
        "allreduce", tree=True, chunked=False,
        description="reduce tree [+ all-to-all] + broadcast tree, full d"),
    "reduce_scatter": CollectiveSpec(
        "reduce_scatter", tree=False, chunked=True,
        description="ring pass, N-1 steps of d/N; node i owns chunk i"),
    "all_gather": CollectiveSpec(
        "all_gather", tree=False, chunked=True,
        description="mirrored ring pass, N-1 steps of d/N chunks"),
    "broadcast": CollectiveSpec(
        "broadcast", tree=True, chunked=False,
        description="WRHT broadcast tree alone, root down, full d"),
    "alltoall": CollectiveSpec(
        "alltoall", tree=False, chunked=True,
        description="one full-mesh step of personalized d/N shards"),
}


def coerce_collective(collective: "Collective | str") -> str:
    name = (collective.value if isinstance(collective, Collective)
            else str(collective))
    if name not in COLLECTIVES:
        raise ValueError(f"unknown collective {collective!r} "
                         f"(expected one of {sorted(COLLECTIVES)})")
    return name


def collective_plan_fields(
    collective: "Collective | str", m: int | None, allow_alltoall: bool,
) -> tuple[int | None, bool]:
    """Normalize the d-independent plan identity ``(m, alltoall)`` per
    collective, so plan-cache keys never fragment on irrelevant axes:
    the ring passes and the standalone all-to-all have no fan-out and no
    finisher choice, and a pure broadcast never takes the all-to-all."""
    spec = COLLECTIVES[coerce_collective(collective)]
    if not spec.tree:
        return None, True
    if coerce_collective(collective) == "broadcast":
        return m, False
    return m, allow_alltoall


def collective_steps(collective: "Collective | str", n: int,
                     m: int | None = None, with_alltoall: bool = True) -> int:
    """Nominal (relay-free) step count per collective (DESIGN.md §11)."""
    name = coerce_collective(collective)
    if n <= 1:
        return 0
    if name in ("reduce_scatter", "all_gather"):
        return n - 1
    if name == "alltoall":
        return 1
    if m is None or m < 2:
        raise ValueError("tree collectives need a fan-out m >= 2")
    l = max(1, math.ceil(math.log(n, m)))
    if name == "broadcast":
        return l
    return 2 * l - 1 if with_alltoall else 2 * l


def optimal_group_size(w: int) -> int:
    """Lemma 1: with two fibers and two Tx/Rx sets per node, the largest
    group a representative can drain in one step is ``m = 2w + 1``."""
    return 2 * w + 1


def _cap_group_size(m: int, max_hops: int | None, spacing: int) -> int:
    """Insertion-loss fan-out cap: with a hop budget ``H`` and active nodes
    ``spacing`` segments apart, the farthest member a middle representative
    can reach is ``H // spacing`` active positions away, so at most
    ``2·(H // spacing) + 1`` nodes fit in one group (floored at 2 so the
    tree always makes progress)."""
    if max_hops is not None:
        m = min(m, max(2, 2 * (max_hops // max(1, spacing)) + 1))
    return m


def effective_wavelengths(w: int, failures: FailureMask | None = None) -> int:
    """Wavelengths usable at *every* node under the mask (floored at 1).

    A λ dead at node ``v`` only forbids add/drop *at v*, so this is a
    conservative uniform shrink — the group-size and all-to-all budgets are
    worst-case-node bounds, which is exactly what Lemma 1 needs.
    """
    if failures is None or failures.empty:
        return w
    return max(1, w - failures.max_dead_lambda_per_node())


def feasible_group_size(w: int, max_hops: int | None = None, spacing: int = 1,
                        failures: FailureMask | None = None) -> int:
    """Lemma-1 optimum capped by the insertion-loss fan-out limit.

    A group of 2 whose pair distance still exceeds ``H`` must be relayed —
    ``build_schedule`` does this automatically.  A failure mask shrinks the
    Lemma-1 budget to the worst node's surviving wavelength count.
    """
    return _cap_group_size(optimal_group_size(effective_wavelengths(w, failures)),
                           max_hops, spacing)


# ------------------------------------------------------------------
# Degraded-mode routing (DESIGN.md §12).
#
# A single lightpath has exactly two simple routes per ordered pair, so a
# transfer blocked by a cut span first tries the *direction flip*.  When
# both directions are blocked as single lightpaths (e.g. a dead CW span on
# one side plus a dead CCW transceiver at the destination), an O/E/O
# detour can still work: two legs through a live relay node, each leg
# choosing its own fiber direction.  The router therefore plans per row:
# direct → flipped → cheapest feasible two-leg detour → infeasible.  Legs
# longer than the hop budget are further relayed through live nodes,
# reusing the store-and-forward sub-step convention of
# `split_overlong_arcs`.  Only the single-step all-to-all is restricted to
# the direction flip — a detour would need a second reconfiguration.
# ------------------------------------------------------------------

def _route_blocked(batch: TransferBatch, n: int,
                   failures: FailureMask) -> np.ndarray:
    """Bool per row: the route (as currently directed) touches a dead span
    or a dead endpoint transceiver."""
    return (_covers_dead_segment(batch, n, failures)
            | _uses_dead_transceiver(batch, n, failures))


def _reroute_batch(batch: TransferBatch, n: int,
                   failures: FailureMask) -> TransferBatch:
    """Flip the ring direction of every blocked transfer; raise
    :exc:`DegradedInfeasibleError` when a transfer is blocked both ways."""
    if len(batch) == 0:
        return batch
    bad = _route_blocked(batch, n, failures)
    if not bad.any():
        return batch
    flipped = TransferBatch.from_arrays(
        batch.src, batch.dst,
        np.where(bad, -batch.direction, batch.direction), batch.bits,
        check=False,
    )
    still = _route_blocked(flipped, n, failures) & bad
    if still.any():
        i = int(np.flatnonzero(still)[0])
        raise DegradedInfeasibleError(
            f"transfer {int(batch.src[i])}->{int(batch.dst[i])} is blocked "
            "in both ring directions under the failure mask"
        )
    return flipped


class _DegradedRouter:
    """Per-row route planner under a failure mask (plain Python loops:
    degraded operation is rare and schedules build once per cache key)."""

    def __init__(self, n: int, max_hops: int | None,
                 failures: FailureMask) -> None:
        self.n = n
        self.max_hops = max_hops
        self.segd = failures.segment_dead(n)
        self.tdead = failures.transceiver_dead(n)

    def _leg_ok(self, s: int, t: int, d: int) -> bool:
        """Can ``s -> t`` run as ONE lightpath in direction ``d``?"""
        n, lane = self.n, (1 - d) >> 1
        if self.tdead[s, lane] or self.tdead[t, lane]:
            return False
        h = (t - s) * d % n
        start = s if d == CW else t
        row = self.segd[lane]
        if row.any() and row[(start + np.arange(h)) % n].any():
            return False
        return True

    def _hops(self, s: int, t: int, d: int) -> int:
        return (t - s) * d % self.n

    def _split_leg(self, s: int, t: int, d: int) -> list[tuple[int, int, int]]:
        """Cut one feasible leg into hop-budget pieces through live relays:
        each relay is the farthest live node at most ``max_hops`` ahead, so
        dead nodes are skipped at the price of shorter pieces."""
        h = self._hops(s, t, d)
        if self.max_hops is None or h <= self.max_hops:
            return [(s, t, d)]
        n, lane = self.n, (1 - d) >> 1
        parts: list[tuple[int, int, int]] = []
        off = 0
        while h - off > self.max_hops:
            nxt = None
            for k in range(off + self.max_hops, off, -1):
                if not self.tdead[(s + k * d) % n, lane]:
                    nxt = k
                    break
            if nxt is None:
                raise DegradedInfeasibleError(
                    f"no live O/E/O relay within {self.max_hops} hops along "
                    f"{s}->{t} (lane {lane})"
                )
            parts.append(((s + off * d) % n, (s + nxt * d) % n, d))
            off = nxt
        parts.append(((s + off * d) % n, t, d))
        return parts

    def plan_row(self, s: int, t: int, d_pref: int) -> list[tuple[int, int, int]]:
        """Route one transfer: direct → flipped → cheapest two-leg detour.
        Returns the store-and-forward chain as ``(src, dst, dir)`` legs."""
        for d in (d_pref, -d_pref):
            if self._leg_ok(s, t, d):
                return self._split_leg(s, t, d)
        best: tuple[int, int, int, int] | None = None  # (cost, x, d1, d2)
        for x in range(self.n):
            if x in (s, t):
                continue
            for d1 in (CW, CCW):
                if not self._leg_ok(s, x, d1):
                    continue
                for d2 in (CW, CCW):
                    if not self._leg_ok(x, t, d2):
                        continue
                    cost = self._hops(s, x, d1) + self._hops(x, t, d2)
                    if best is None or cost < best[0]:
                        best = (cost, x, d1, d2)
        if best is None:
            raise DegradedInfeasibleError(
                f"transfer {s}->{t} is unroutable under the failure mask "
                "(both directions blocked and no live relay detour exists)"
            )
        _, x, d1, d2 = best
        return self._split_leg(s, x, d1) + self._split_leg(x, t, d2)


def _degraded_substeps(
    batch: TransferBatch, n: int, max_hops: int | None,
    failures: FailureMask,
) -> list[tuple[TransferBatch, np.ndarray]]:
    """Route a step around the failure mask, as relay sub-steps.

    Every row becomes a chain of one or more legs (see :class:`_DegradedRouter`);
    leg ``k`` of every chain lands in sub-step ``k`` (the store-and-forward
    convention of :func:`~repro.core.wavelength.split_overlong_arcs`, so
    single-leg rows sit in sub-step 0).  Returns ``(sub_batch,
    original_rows)`` per sub-step — the row map lets chunked callers slice
    their per-row shard ids.  Rows whose original route is clean skip the
    planner entirely (vectorized precheck), so lightly-degraded steps cost
    barely more than healthy ones.
    """
    if len(batch) == 0:
        return [(batch, np.arange(0, dtype=np.int64))]
    router = _DegradedRouter(n, max_hops, failures)
    hops = batch.arcs(n)[2]
    clean = ~_route_blocked(batch, n, failures)
    if max_hops is not None:
        clean &= hops <= max_hops
    chains: list[list[tuple[int, int, int]]] = []
    for i in range(len(batch)):
        s, t = int(batch.src[i]), int(batch.dst[i])
        if clean[i]:
            chains.append([(s, t, int(batch.direction[i]))])
        else:
            chains.append(router.plan_row(s, t, int(batch.direction[i])))
    out: list[tuple[TransferBatch, np.ndarray]] = []
    for k in range(max(len(c) for c in chains)):
        rows = np.array([i for i, c in enumerate(chains) if len(c) > k],
                        dtype=np.int64)
        legs = [chains[i][k] for i in rows]
        out.append((TransferBatch.from_arrays(
            [l[0] for l in legs], [l[1] for l in legs],
            [l[2] for l in legs], batch.bits[rows], check=False,
        ), rows))
    return out


def _degraded_assign(batch: TransferBatch, ring: Ring,
                     failures: FailureMask) -> TransferBatch:
    """Failure-aware RWA with the uniform degraded error contract: a
    wavelength shortfall under the mask is an infeasibility, not a caller
    bug, so it surfaces as :exc:`DegradedInfeasibleError` (cause chained)."""
    try:
        return first_fit_assign(batch, ring.n, ring.w, failures=failures)
    except WavelengthConflictError as e:
        raise DegradedInfeasibleError(
            "surviving wavelengths cannot carry a required step under the "
            f"failure mask: {e}"
        ) from e


def _assigner(rwa: str):
    if rwa == "fast":
        return first_fit_assign
    if rwa == "reference":
        return lambda batch, n, w: TransferBatch.from_transfers(
            first_fit_assign_reference(TransferBatch.coerce(batch).to_transfers(), n, w)
        )
    raise ValueError(f"unknown rwa {rwa!r} (expected 'fast' or 'reference')")


@dataclass(frozen=True)
class _LevelGrouping:
    """Grouping arrays of one tree level: the shared structure from which
    the reduce batch, the broadcast batch and the closed-form First-Fit
    assignment are all derived (DESIGN.md §10)."""

    reps: np.ndarray       # representative node per group        [G]
    members: np.ndarray    # member nodes, group-major order      [T]
    rep_for: np.ndarray    # each member's representative         [T]
    left: np.ndarray       # member sits left of its rep          [T] bool
    pos: np.ndarray        # member's in-group position           [T]
    gsize_for: np.ndarray  # size of the member's group           [T]


def _level_grouping(active: np.ndarray, m: int) -> _LevelGrouping:
    """Partition ``active`` into runs of ``m`` with middle representatives.

    Row order matches the original per-object builder exactly (group-major,
    member position order, representative skipped) so that stable
    longest-first RWA ties break identically.
    """
    count = active.size
    n_groups = -(-count // m)
    idx = np.arange(count)
    gi = idx // m
    pos = idx - gi * m
    gsize = np.full(n_groups, m, dtype=np.int64)
    gsize[-1] = count - (n_groups - 1) * m
    mid = gsize // 2
    reps = active[np.arange(n_groups) * m + mid]
    member = pos != mid[gi]
    gim = gi[member]
    posm = pos[member]
    return _LevelGrouping(
        reps=reps, members=active[member], rep_for=reps[gim],
        left=posm < mid[gim], pos=posm, gsize_for=gsize[gim],
    )


def _grouping_batch(g: _LevelGrouping, d_bits: float, broadcast: bool,
                    wavelength=None) -> TransferBatch:
    """Materialize one level's transfers from its grouping arrays.

    Left-of-rep members transmit clockwise, right-of-rep counter-clockwise
    (two Rx sets per node, Sec. III-B); broadcast reverses the paths.
    """
    if broadcast:
        return TransferBatch.from_arrays(
            g.rep_for, g.members, np.where(g.left, CCW, CW), d_bits,
            wavelength=wavelength, check=False
        )
    return TransferBatch.from_arrays(
        g.members, g.rep_for, np.where(g.left, CW, CCW), d_bits,
        wavelength=wavelength, check=False
    )


def _level_transfers(
    active: np.ndarray, m: int, d_bits: float, broadcast: bool
) -> tuple[TransferBatch, np.ndarray]:
    """Member↔representative transfers for one tree level, as arrays."""
    g = _level_grouping(active, m)
    return _grouping_batch(g, d_bits, broadcast), g.reps


def _level_wavelengths(g: _LevelGrouping) -> np.ndarray:
    """Closed-form First-Fit assignment for one plain tree level.

    Within a group the two sides load disjoint fiber lanes, and the arcs of
    one side are strictly nested toward the representative (lengths strictly
    decrease as the member approaches it); different groups of the level
    never share a directed segment on the same lane.  Longest-first First
    Fit therefore gives the member at in-group position ``p`` wavelength
    ``p`` (left side) or ``gsize − 1 − p`` (right side), on both stages —
    the broadcast step's arcs are the lane-mirrored image of the reduce
    step's, so the per-row assignment is identical.  Bit-identity to
    :func:`~repro.core.wavelength.first_fit_assign` on the materialized
    batch is pinned by the golden tests of the batched builder
    (DESIGN.md §10).
    """
    return np.where(g.left, g.pos, g.gsize_for - 1 - g.pos)


def _full_mesh_batch(nodes: np.ndarray, n: int, bits: float) -> TransferBatch:
    """One transfer per ordered pair of ``nodes``, shortest direction each."""
    r = nodes.size
    src, dst = np.meshgrid(nodes, nodes, indexing="ij")
    off = ~np.eye(r, dtype=bool)
    src, dst = src[off], dst[off]
    cw = (dst - src) % n <= (src - dst) % n  # shortest_direction
    return TransferBatch.from_arrays(
        src, dst, np.where(cw, CW, CCW), bits, check=False
    )


def _alltoall_fits(
    reps: np.ndarray, ring: Ring, d_bits: float, rwa: str = "fast",
    max_hops: int | None = None, failures: FailureMask | None = None,
) -> TransferBatch | None:
    """Try to schedule a one-step all-to-all among ``reps``; None if > w
    or if any pairwise lightpath would exceed the insertion-loss budget."""
    r = reps.size
    if r < 2:
        return None
    degraded = failures is not None and not failures.empty
    # Paper Sec. III-C-2 / [16]: all-to-all among m* ring nodes needs
    # ⌈m*²/8⌉ wavelengths.  Cheap necessary condition before running RWA —
    # also keeps the O(r²) enumeration off the N=4096 level-0 case.
    if math.ceil(r ** 2 / 8) > effective_wavelengths(ring.w, failures):
        return None
    batch = _full_mesh_batch(reps, ring.n, d_bits)
    if degraded:
        try:
            batch = _reroute_batch(batch, ring.n, failures)
        except DegradedInfeasibleError:
            return None  # the finisher is optional — keep climbing the tree
    if max_hops is not None and (batch.arcs(ring.n)[2] > max_hops).any():
        return None  # some pair is out of optical reach — keep climbing the tree
    try:
        if degraded:
            return first_fit_assign(batch, ring.n, ring.w, failures=failures)
        return _assigner(rwa)(batch, ring.n, ring.w)
    except WavelengthConflictError:
        return None


def _level_cap(active: np.ndarray, m: int, max_hops: int | None) -> tuple[int, bool]:
    """Group size usable at this level under the hop budget, and whether the
    level's transfers need O/E/O relays.

    Active nodes are grouped by index order, so a member→representative path
    covers the ring gaps between consecutive actives; with worst gap
    ``g_max`` the farthest of ``m`` members is ``⌈(m-1)/2⌉ · g_max`` segments
    out.  Capping ``m`` at ``2·(H // g_max) + 1`` keeps every lightpath
    within the budget.  When even adjacent actives are out of reach
    (``H < g_max``), fall back to pairing (m=2) with relayed transfers.
    """
    if max_hops is None or active.size < 2:
        return m, False
    g_max = int(np.diff(active).max())
    if max_hops < g_max:
        return 2, True
    return _cap_group_size(m, max_hops, g_max), False


def _append_level(
    sched: WRHTSchedule, kind: str, level: int, batch: TransferBatch,
    relay: bool, ring: Ring, assign, max_hops: int | None,
    failures: FailureMask | None = None,
) -> None:
    """Emit one tree level as a Step, splitting into relay sub-steps when the
    hop budget demands it (each sub-step re-runs RWA).  Under a failure mask
    the batch is first re-routed around dead spans/transceivers (which may
    push flipped rows over the hop budget, triggering the relay path even
    when the healthy level needed none)."""
    if failures is not None and not failures.empty:
        for sub, _ in _degraded_substeps(batch, ring.n, max_hops, failures):
            sched.steps.append(
                Step(kind, level, _degraded_assign(sub, ring, failures)))
        return
    if relay:
        for sub in split_overlong_arcs(batch, ring.n, max_hops):
            sched.steps.append(Step(kind, level, assign(sub, ring.n, ring.w)))
    else:
        sched.steps.append(Step(kind, level, assign(batch, ring.n, ring.w)))


def build_schedule(
    n: int,
    w: int,
    d_bits: float,
    m: int | None = None,
    allow_alltoall: bool = True,
    bandwidth_bps: float = 40e9,
    reconfig_delay_s: float = 25e-6,
    validate: bool = True,
    rwa: str = "fast",
    physical: PhysicalParams | None = None,
    max_hops: int | None = None,
    failures: FailureMask | None = None,
) -> WRHTSchedule:
    """Construct and validate the full WRHT schedule for an N-node ring.

    ``rwa`` selects the wavelength assigner: ``"fast"`` (vectorized bitmask
    First Fit) or ``"reference"`` (original per-object greedy) — the two are
    bit-identical; the knob exists for the equivalence test and the
    schedule-build benchmark.

    ``physical`` (or an explicit ``max_hops``) enables the insertion-loss
    constraint (paper Sec. III): the per-level group size is capped so no
    lightpath exceeds the hop budget, the final all-to-all is only taken
    when every pair is within reach, and levels whose active nodes have
    drifted beyond the budget are relayed through intermediate O/E/O
    regeneration sub-steps.  The resulting schedule never contains a
    transfer longer than the budget (enforced by :func:`validate_schedule`).

    A non-empty ``failures`` mask puts the build in degraded mode
    (DESIGN.md §12): blocked routes flip direction (relayed through live
    O/E/O nodes when the long way exceeds the hop budget), the Lemma-1
    group size shrinks to the worst node's surviving wavelengths, and any
    remaining infeasibility raises :exc:`DegradedInfeasibleError`.
    """
    if n < 1:
        raise ValueError("need >= 1 node")
    if failures is not None and failures.empty:
        failures = None
    if max_hops is None and physical is not None:
        max_hops = physical.max_hops
    if max_hops is not None and max_hops < 1:
        raise ValueError("insertion-loss hop budget must allow >= 1 hop")
    ring = Ring(max(n, 2), w, bandwidth_bps=bandwidth_bps,
                reconfig_delay_s=reconfig_delay_s, physical=physical,
                failures=failures)
    w_eff = effective_wavelengths(w, failures)
    if m is None:
        m = optimal_group_size(w_eff)
    if m < 2:
        raise ValueError("group size m must be >= 2")
    # Lemma 1 feasibility: a group of m nodes drains over two fibers with
    # ⌈(m-1)/2⌉ wavelengths per side; beyond m = 2w+1 the step cannot be
    # conflict-free, so clamp (callers probing larger m get the feasible
    # max; a failure mask shrinks the budget to the worst surviving node).
    m = min(m, optimal_group_size(w_eff))
    # level-0 fan-out cap (unit spacing); deeper levels re-cap per spacing
    # in _level_cap as the active nodes spread out
    m = _cap_group_size(m, max_hops, 1)
    assign = _assigner(rwa)

    sched = WRHTSchedule(n=n, w=w, m=m, max_hops=max_hops, failures=failures)
    active = np.arange(n, dtype=np.int64)
    sched.levels.append(active.tolist())
    if n == 1:
        return sched

    # ---------------- reduce stage ----------------
    reduce_actives: list[np.ndarray] = []  # the grouping input per level
    level_meta: list[tuple[int, bool]] = []  # (group size, relayed) per level
    level = 0
    while active.size > 1:
        if allow_alltoall:
            a2a = _alltoall_fits(active, ring, d_bits, rwa, max_hops=max_hops,
                                 failures=failures)
            if a2a is not None:
                sched.steps.append(Step("alltoall", level, a2a))
                break
        m_lvl, relay = _level_cap(active, m, max_hops)
        batch, reps = _level_transfers(active, m_lvl, d_bits, broadcast=False)
        _append_level(sched, "reduce", level, batch, relay, ring, assign,
                      max_hops, failures)
        reduce_actives.append(active)
        level_meta.append((m_lvl, relay))
        sched.level_group_sizes.append(m_lvl)
        active = reps
        sched.levels.append(active.tolist())
        level += 1

    # ---------------- broadcast stage ----------------
    # Reverse of the reduce tree (the all-to-all step, if any, already left
    # every surviving representative with the full reduction).
    for level in range(len(reduce_actives) - 1, -1, -1):
        m_lvl, relay = level_meta[level]
        batch, _ = _level_transfers(reduce_actives[level], m_lvl, d_bits,
                                    broadcast=True)
        _append_level(sched, "broadcast", level, batch, relay, ring, assign,
                      max_hops, failures)

    if validate:
        validate_schedule(sched, ring)
    return sched


def build_collective_schedule(
    collective: "Collective | str",
    n: int,
    w: int,
    d_bits: float,
    m: int | None = None,
    allow_alltoall: bool = True,
    bandwidth_bps: float = 40e9,
    reconfig_delay_s: float = 25e-6,
    validate: bool = True,
    rwa: str = "fast",
    physical: PhysicalParams | None = None,
    max_hops: int | None = None,
    failures: FailureMask | None = None,
) -> WRHTSchedule:
    """Generalized schedule builder: one entry point for the whole scheduled
    collective algebra (DESIGN.md §11).

    Reuses the all-reduce machinery unchanged — level grouping, First-Fit
    RWA, hop-budget relays and the insertion-loss caps:

    * ``allreduce`` delegates to :func:`build_schedule`;
    * ``broadcast`` walks the same reduce tree for structure but emits only
      the broadcast stage (root = the final surviving representative; the
      all-to-all finisher never applies — it is a reduce-phase device);
    * ``reduce_scatter`` / ``all_gather`` emit the ``N-1``-step neighbour
      ring pass of ``d/N`` chunks (one shared ``TransferBatch``, per-step
      ``Step.chunks`` shard ids);
    * ``alltoall`` emits the single full-mesh step of personalized ``d/N``
      shards, raising :class:`~repro.core.wavelength.WavelengthConflictError`
      when ``⌈N²/8⌉ > w`` and
      :class:`~repro.core.wavelength.InsertionLossError` when any pair is
      beyond the hop budget (unlike the all-reduce *finisher*, which simply
      keeps climbing the tree).

    A non-empty ``failures`` mask puts every collective in degraded mode
    (DESIGN.md §12): blocked routes flip direction (relayed when the long
    way exceeds the hop budget), budgets shrink to the surviving
    wavelengths, and ALL infeasibilities — including the all-to-all cases
    above — surface uniformly as :exc:`DegradedInfeasibleError`.
    """
    collective = coerce_collective(collective)
    if failures is not None and failures.empty:
        failures = None
    if collective == "allreduce":
        return build_schedule(
            n, w, d_bits, m=m, allow_alltoall=allow_alltoall,
            bandwidth_bps=bandwidth_bps, reconfig_delay_s=reconfig_delay_s,
            validate=validate, rwa=rwa, physical=physical, max_hops=max_hops,
            failures=failures,
        )
    if n < 1:
        raise ValueError("need >= 1 node")
    if max_hops is None and physical is not None:
        max_hops = physical.max_hops
    if max_hops is not None and max_hops < 1:
        raise ValueError("insertion-loss hop budget must allow >= 1 hop")
    ring = Ring(max(n, 2), w, bandwidth_bps=bandwidth_bps,
                reconfig_delay_s=reconfig_delay_s, physical=physical,
                failures=failures)
    w_eff = effective_wavelengths(w, failures)
    if m is None:
        m = optimal_group_size(w_eff)
    if m < 2:
        raise ValueError("group size m must be >= 2")
    m = _cap_group_size(min(m, optimal_group_size(w_eff)), max_hops, 1)
    assign = _assigner(rwa)

    sched = WRHTSchedule(n=n, w=w, m=m, max_hops=max_hops,
                         collective=collective, failures=failures)
    active = np.arange(n, dtype=np.int64)
    sched.levels.append(active.tolist())
    if n > 1:
        if collective == "broadcast":
            _emit_broadcast_tree(sched, active, m, ring, assign, max_hops,
                                 d_bits, failures)
        elif collective in ("reduce_scatter", "all_gather"):
            _emit_ring_pass(sched, collective, n, ring, assign, d_bits,
                            max_hops, failures)
        else:  # alltoall
            _emit_alltoall(sched, active, ring, assign, max_hops, d_bits, w,
                           failures)
    if validate:
        validate_schedule(sched, ring)
    return sched


def _emit_broadcast_tree(
    sched: WRHTSchedule, active: np.ndarray, m: int, ring: Ring, assign,
    max_hops: int | None, d_bits: float,
    failures: FailureMask | None = None,
) -> None:
    """The WRHT broadcast stage alone: walk the reduce tree for its
    grouping structure (no reduce steps emitted, no all-to-all — a pure
    broadcast has a single source), then emit the levels top-down."""
    bcast_actives: list[np.ndarray] = []
    level_meta: list[tuple[int, bool]] = []
    while active.size > 1:
        m_lvl, relay = _level_cap(active, m, max_hops)
        g = _level_grouping(active, m_lvl)
        bcast_actives.append(active)
        level_meta.append((m_lvl, relay))
        sched.level_group_sizes.append(m_lvl)
        active = g.reps
        sched.levels.append(active.tolist())
    for level in range(len(bcast_actives) - 1, -1, -1):
        m_lvl, relay = level_meta[level]
        batch, _ = _level_transfers(bcast_actives[level], m_lvl, d_bits,
                                    broadcast=True)
        _append_level(sched, "broadcast", level, batch, relay, ring, assign,
                      max_hops, failures)


def _emit_ring_pass(
    sched: WRHTSchedule, collective: str, n: int, ring: Ring, assign,
    d_bits: float, max_hops: int | None = None,
    failures: FailureMask | None = None,
) -> None:
    """``N-1`` neighbour steps of ``d/N`` chunks — the bandwidth-optimal
    ring pass.  Every step shares ONE assigned batch (neighbour hops occupy
    disjoint segments, so First Fit lands everything on wavelength 0); the
    per-step shard identity lives in ``Step.chunks``:

    reduce-scatter   step ``t``: node ``i`` forwards its partial of chunk
                     ``(i - t) mod N`` — chunk ``c`` walks ``c+1 → … → c``,
                     accumulating every node's contribution, so node ``i``
                     ends owning the full reduction of chunk ``i``;
    all-gather       step ``t``: node ``i`` forwards chunk ``(i - t + 1)
                     mod N`` — node ``i``'s owned chunk circulates to all.

    Degraded mode keeps the logical neighbour data flow but re-routes
    blocked hops the long way around (relayed through live O/E/O nodes when
    over the hop budget); each logical step then expands into its
    store-and-forward sub-steps, every sub-step carrying the chunk ids of
    the rows it forwards.
    """
    src = np.arange(n, dtype=np.int64)
    batch = TransferBatch.from_arrays(
        src, (src + 1) % n, CW, d_bits / n, check=False
    )
    if failures is not None and not failures.empty:
        # geometry repeats every step — assign each sub-batch once, share it
        subs = [(_degraded_assign(sb, ring, failures), rows)
                for sb, rows in
                _degraded_substeps(batch, ring.n, max_hops, failures)]
        for t in range(1, n):
            if collective == "reduce_scatter":
                chunks = (src - t) % n
            else:
                chunks = (src - t + 1) % n
            for sb, rows in subs:
                sched.steps.append(Step(collective, 0, sb,
                                        chunks=chunks[rows]))
        return
    assigned = assign(batch, ring.n, ring.w)
    for t in range(1, n):
        if collective == "reduce_scatter":
            chunks = (src - t) % n
        else:
            chunks = (src - t + 1) % n
        sched.steps.append(Step(collective, 0, assigned, chunks=chunks))


def _emit_alltoall(
    sched: WRHTSchedule, active: np.ndarray, ring: Ring, assign,
    max_hops: int | None, d_bits: float, w: int,
    failures: FailureMask | None = None,
) -> None:
    """The single-step full-mesh exchange among all ``n`` nodes.

    Degraded mode preserves the single-step invariant — a relayed pair
    would need a second reconfiguration — so a blocked-both-ways pair, a
    flipped path over the hop budget, or a wavelength shortfall all raise
    :exc:`DegradedInfeasibleError` (the healthy errors stay as documented).
    """
    n = active.size
    degraded = failures is not None and not failures.empty
    need = math.ceil(n ** 2 / 8)
    w_eff = effective_wavelengths(w, failures)
    if need > w_eff:
        err = WavelengthConflictError(
            f"single-step all-to-all among {n} nodes needs ⌈n²/8⌉={need} "
            f"wavelengths, but the ring has w={w_eff}"
            + (" surviving the failure mask" if degraded else "")
        )
        if degraded:
            raise DegradedInfeasibleError(str(err)) from err
        raise err
    batch = _full_mesh_batch(active, ring.n, d_bits / n)
    if degraded:
        batch = _reroute_batch(batch, ring.n, failures)
    hops = batch.arcs(ring.n)[2]
    if max_hops is not None and int(hops.max(initial=0)) > max_hops:
        err = InsertionLossError(
            f"all-to-all lightpath spans {int(hops.max())} segments, "
            f"exceeding the insertion-loss hop budget of {max_hops}"
        )
        if degraded:  # a relay would break the single-step invariant
            raise DegradedInfeasibleError(str(err)) from err
        raise err
    if degraded:
        assigned = _degraded_assign(batch, ring, failures)
    else:
        assigned = assign(batch, ring.n, ring.w)
    sched.steps.append(Step("alltoall", 0, assigned,
                            chunks=assigned.dst.copy()))


# ------------------------------------------------------------------
# Batched multi-candidate builder (DESIGN.md §10).
# ------------------------------------------------------------------

def _concat_batches(batches: list[TransferBatch]) -> tuple[TransferBatch, np.ndarray]:
    """Concatenate step batches into one arc batch with offset pointers."""
    ptr = np.zeros(len(batches) + 1, dtype=np.int64)
    np.cumsum([len(b) for b in batches], out=ptr[1:])
    cat = TransferBatch(
        np.concatenate([b.src for b in batches]),
        np.concatenate([b.dst for b in batches]),
        np.concatenate([b.direction for b in batches]),
        np.concatenate([b.bits for b in batches]),
        np.concatenate([b.wavelength for b in batches]),
    )
    return cat, ptr


def _split_batch(batch: TransferBatch, ptr: np.ndarray) -> list[TransferBatch]:
    """Slice an assigned concatenated batch back into per-step batches."""
    memo = batch._arcs
    out = []
    for lo, hi in zip(ptr[:-1].tolist(), ptr[1:].tolist()):
        sub = TransferBatch(batch.src[lo:hi], batch.dst[lo:hi],
                            batch.direction[lo:hi], batch.bits[lo:hi],
                            batch.wavelength[lo:hi])
        if memo is not None:  # per-row geometry slices with the columns
            sub._arcs = (memo[0], memo[1][lo:hi], memo[2][lo:hi],
                         memo[3][lo:hi])
        out.append(sub)
    return out


def build_candidate_schedules(
    n: int,
    w: int,
    d_bits: float,
    m_candidates=None,
    allow_alltoall: bool = True,
    bandwidth_bps: float = 40e9,
    reconfig_delay_s: float = 25e-6,
    validate: bool = True,
    rwa: str = "fast",
    physical: PhysicalParams | None = None,
    max_hops: int | None = None,
    collective: "Collective | str" = "allreduce",
    failures: FailureMask | None = None,
) -> dict[tuple[int, bool], WRHTSchedule]:
    """Build every candidate WRHT schedule of a fan-out sweep in one pass.

    The auto-tuner costs one schedule per ``(m, alltoall)`` candidate;
    rebuilding each from scratch repeats the level walk, the RWA and the
    validation ~2× per fan-out.  This builder amortizes the sweep
    (DESIGN.md §10):

    * the all-to-all and no-all-to-all variants of one ``m`` share their
      per-level active-node/grouping arrays and their ``Step`` objects —
      the full tree is walked once and the variant that took the all-to-all
      at level ``L`` is the slice ``reduce[:L] + [alltoall] +
      broadcast[L-1::-1]`` of it;
    * plain tree levels take the closed-form First-Fit assignment
      (:func:`_level_wavelengths`) instead of running the greedy;
    * relay chains under a hop budget run First-Fit over concatenated
      per-sub-step arc batches with offset pointers
      (:func:`~repro.core.wavelength.first_fit_assign_concat`), sharing one
      translated-component dedup table across every candidate and both
      stages (a broadcast step's components are the lane-mirror of its
      reduce step's, so mirrors are cache hits).

    Returns ``{(m, alltoall): schedule}`` in candidate order, each entry
    **bit-identical** to ``build_schedule(n, w, d_bits, m=m,
    allow_alltoall=alltoall, ...)`` (golden-tested).  The ``(m, False)``
    variant is materialized only when the ``(m, True)`` build actually took
    the all-to-all — otherwise the two are the same schedule.  ``m`` keys
    are the *requested* fan-outs (the per-schedule ``m`` field carries the
    Lemma-1/hop-budget clamp, as in ``build_schedule``).

    ``validate=True`` checks wavelength conflicts and the hop budget once
    per unique step batch plus the collective's semantics per candidate; the
    tuner passes ``False`` (construction is conflict-free by design and the
    winning schedule is re-validated when materialized through the plan
    cache).

    ``collective`` selects the fan-out-swept collective: ``"allreduce"``
    (the default, both all-to-all variants per ``m``) or ``"broadcast"``
    (the WRHT broadcast tree alone, keyed ``(m, False)`` — a pure broadcast
    never takes the all-to-all).  The ring passes and the standalone
    all-to-all have no fan-out axis, so sweeping them is a caller error.

    A non-empty ``failures`` mask disables the amortized one-pass walk —
    per-node deadness breaks the translation symmetries it exploits, and a
    single infeasible candidate must not poison the sweep — and falls back
    to one degraded :func:`build_schedule` per candidate, skipping fan-outs
    that raise :exc:`DegradedInfeasibleError`.  If *no* candidate survives,
    the error propagates.
    """
    collective = coerce_collective(collective)
    if not COLLECTIVES[collective].tree:
        raise ValueError(
            f"collective {collective!r} has no fan-out axis to sweep — "
            "build it directly with build_collective_schedule"
        )
    if n < 1:
        raise ValueError("need >= 1 node")
    if failures is not None and failures.empty:
        failures = None
    if max_hops is None and physical is not None:
        max_hops = physical.max_hops
    if max_hops is not None and max_hops < 1:
        raise ValueError("insertion-loss hop budget must allow >= 1 hop")
    ring = Ring(max(n, 2), w, bandwidth_bps=bandwidth_bps,
                reconfig_delay_s=reconfig_delay_s, physical=physical,
                failures=failures)
    if m_candidates is None:
        m_candidates = range(2, feasible_group_size(w, max_hops,
                                                    failures=failures) + 1)
    ms: list[int] = []
    for m in m_candidates:
        m = int(m)
        if m < 2:
            raise ValueError("group size m must be >= 2")
        if m not in ms:
            ms.append(m)
    if failures is not None:
        return _candidate_schedules_degraded(
            collective, n, w, d_bits, ms, allow_alltoall, bandwidth_bps,
            reconfig_delay_s, validate, rwa, physical, max_hops, failures,
        )
    assign = _assigner(rwa)
    closed_form = rwa == "fast"
    rwa_cache: dict = {}  # translated-component dedup, shared by all candidates

    def emit_level(kind: str, level: int, g: _LevelGrouping,
                   relay: bool, broadcast: bool) -> list[Step]:
        batch = _grouping_batch(g, d_bits, broadcast)
        if relay:
            subs = split_overlong_arcs(batch, ring.n, max_hops)
            if closed_form:
                cat, ptr = _concat_batches(subs)
                assigned = first_fit_assign_concat(cat, ptr, ring.n, ring.w,
                                                   cache=rwa_cache)
                subs = _split_batch(assigned, ptr)
            else:
                subs = [assign(sub, ring.n, ring.w) for sub in subs]
            return [Step(kind, level, sub) for sub in subs]
        if closed_form:
            return [Step(kind, level, batch.with_wavelengths(_level_wavelengths(g)))]
        return [Step(kind, level, assign(batch, ring.n, ring.w))]

    out: dict[tuple[int, bool], WRHTSchedule] = {}
    for m_req in ms:
        # same clamps as build_schedule: Lemma 1 then the level-0 fan-out cap
        m = _cap_group_size(min(m_req, optimal_group_size(w)), max_hops, 1)
        variant_key = allow_alltoall if collective == "allreduce" else False
        active = np.arange(n, dtype=np.int64)
        levels = [active]
        if n == 1:
            out[(m_req, variant_key)] = WRHTSchedule(
                n=n, w=w, m=m, levels=[active.tolist()], max_hops=max_hops,
                collective=collective)
            continue

        reduce_steps: list[list[Step]] = []   # Steps per level (relays split)
        groupings: list[_LevelGrouping] = []
        meta: list[tuple[int, bool]] = []     # (m_lvl, relay) per level
        a2a_at: int | None = None
        a2a_step: Step | None = None
        level = 0
        while active.size > 1:
            if collective == "allreduce" and allow_alltoall and a2a_at is None:
                fit = _alltoall_fits(active, ring, d_bits, rwa,
                                     max_hops=max_hops)
                if fit is not None:
                    # the all-to-all variant stops here; keep walking the
                    # tree — the no-all-to-all variant needs the rest
                    a2a_at = level
                    a2a_step = Step("alltoall", level, fit)
            m_lvl, relay = _level_cap(active, m, max_hops)
            g = _level_grouping(active, m_lvl)
            if collective == "allreduce":
                reduce_steps.append(emit_level("reduce", level, g, relay,
                                               False))
            groupings.append(g)
            meta.append((m_lvl, relay))
            active = g.reps
            levels.append(active)
            level += 1

        bcast_steps = [
            emit_level("broadcast", lvl, g, meta[lvl][1], True)
            for lvl, g in enumerate(groupings)
        ]

        if collective == "broadcast":
            out[(m_req, False)] = WRHTSchedule(
                n=n, w=w, m=m,
                steps=[s for lvl in range(len(groupings) - 1, -1, -1)
                       for s in bcast_steps[lvl]],
                levels=[l.tolist() for l in levels], max_hops=max_hops,
                level_group_sizes=[ml for ml, _ in meta],
                collective="broadcast",
            )
            continue

        def assemble(depth: int, tail: list[Step]) -> list[Step]:
            steps = [s for lvl in range(depth) for s in reduce_steps[lvl]]
            steps.extend(tail)
            for lvl in range(depth - 1, -1, -1):
                steps.extend(bcast_steps[lvl])
            return steps

        full_tree = WRHTSchedule(
            n=n, w=w, m=m, steps=assemble(len(groupings), []),
            levels=[l.tolist() for l in levels], max_hops=max_hops,
            level_group_sizes=[ml for ml, _ in meta],
        )
        if a2a_at is None:
            out[(m_req, allow_alltoall)] = full_tree
        else:
            out[(m_req, True)] = WRHTSchedule(
                n=n, w=w, m=m, steps=assemble(a2a_at, [a2a_step]),
                levels=[levels[i].tolist() for i in range(a2a_at + 1)],
                max_hops=max_hops,
                level_group_sizes=[meta[i][0] for i in range(a2a_at)],
            )
            out[(m_req, False)] = full_tree

    if validate:
        hops_budget = max_hops if max_hops is not None else ring.max_hops
        seen: set[int] = set()
        for sched in out.values():
            for step in sched.steps:
                if id(step.transfers) not in seen:
                    seen.add(id(step.transfers))
                    validate_no_conflicts(step.transfers, ring.n, ring.w,
                                          max_hops=hops_budget)
            _validate_semantics(sched)
    return out


def _candidate_schedules_degraded(
    collective: str, n: int, w: int, d_bits: float, ms: list[int],
    allow_alltoall: bool, bandwidth_bps: float, reconfig_delay_s: float,
    validate: bool, rwa: str, physical: PhysicalParams | None,
    max_hops: int | None, failures: FailureMask,
) -> dict[tuple[int, bool], WRHTSchedule]:
    """Per-candidate degraded sweep (see :func:`build_candidate_schedules`):
    each fan-out builds independently so one infeasible ``m`` cannot poison
    the rest; the all-to-all variant split mirrors the healthy builder."""
    kw = dict(bandwidth_bps=bandwidth_bps, reconfig_delay_s=reconfig_delay_s,
              validate=validate, rwa=rwa, physical=physical,
              max_hops=max_hops, failures=failures)
    out: dict[tuple[int, bool], WRHTSchedule] = {}
    last_err: DegradedInfeasibleError | None = None
    for m_req in ms:
        try:
            if collective == "broadcast":
                out[(m_req, False)] = build_collective_schedule(
                    "broadcast", n, w, d_bits, m=m_req,
                    allow_alltoall=False, **kw)
                continue
            sched = build_schedule(n, w, d_bits, m=m_req,
                                   allow_alltoall=allow_alltoall, **kw)
        except DegradedInfeasibleError as e:
            last_err = e
            continue
        took_a2a = any(s.kind == "alltoall" for s in sched.steps)
        if allow_alltoall and took_a2a:
            out[(m_req, True)] = sched
            try:
                out[(m_req, False)] = build_schedule(
                    n, w, d_bits, m=m_req, allow_alltoall=False, **kw)
            except DegradedInfeasibleError as e:
                last_err = e
        else:
            out[(m_req, allow_alltoall)] = sched
    if not out:
        raise DegradedInfeasibleError(
            f"no feasible fan-out among {ms} for {collective} on n={n} "
            f"w={w} under the failure mask"
        ) from last_err
    return out


# ------------------------------------------------------------------
# Validation: structural (wavelengths) and semantic (per collective).
# ------------------------------------------------------------------

# Chunked-collective semantic validation tracks an [n, n_chunks, n/64] bitset
# cube; beyond this many nodes only the (always-on) structural checks run —
# the ring passes are correct by construction and conformance-tested at
# every size below the cap (DESIGN.md §11).
CHUNKED_SEMANTIC_CAP = 512


def validate_schedule(sched: WRHTSchedule, ring: Ring | None = None) -> None:
    """Structural validation (wavelengths + insertion loss) then semantic —
    the semantic check dispatches on ``sched.collective`` (DESIGN.md §11).

    The hop budget comes from the schedule itself or, failing that, from the
    ring's physical model — a schedule built without the constraint validates
    as before.  Likewise the failure mask: a degraded schedule (or a ring
    with failures) additionally rejects any step touching a dead
    span/transceiver/λ (:exc:`~repro.core.wavelength.FailedResourceError`).
    """
    ring = ring or Ring(max(sched.n, 2), sched.w)
    max_hops = sched.max_hops if sched.max_hops is not None else ring.max_hops
    failures = (sched.failures if sched.failures is not None
                else ring.failures)
    for step in sched.steps:
        validate_no_conflicts(step.transfers, ring.n, ring.w,
                              max_hops=max_hops, failures=failures)
    _validate_semantics(sched)


def broadcast_root(sched: WRHTSchedule) -> int:
    """The source node of a broadcast schedule: the final surviving
    representative of the tree walk (node 0 on a one-node ring)."""
    return int(sched.levels[-1][0]) if sched.levels else 0


def _validate_semantics(sched: WRHTSchedule) -> None:
    """Check the schedule's data-flow against its collective's semantic spec."""
    c = sched.collective
    n = sched.n
    if n <= 1:
        return
    if c == "allreduce":
        bad = _incomplete_nodes(_contribution_words(sched), n)
        if bad:
            raise AssertionError(
                f"all-reduce semantics violated: nodes {bad[:8]} missing "
                "contributions"
            )
    elif c == "broadcast":
        words = _contribution_words(sched)
        root = broadcast_root(sched)
        want = np.zeros(words.shape[1], dtype=np.uint64)
        want[root // 64] = np.uint64(1) << np.uint64(root % 64)
        bad = np.flatnonzero((words != want).any(axis=1)).tolist()
        if bad:
            raise AssertionError(
                f"broadcast semantics violated: nodes {bad[:8]} do not hold "
                f"exactly the root node {root}'s value"
            )
    elif c in ("reduce_scatter", "all_gather"):
        if n > CHUNKED_SEMANTIC_CAP:
            return  # structural checks only beyond the cube cap (see above)
        state = _chunk_contribution_words(sched)
        ids = np.arange(n)
        if c == "reduce_scatter":
            own = state[ids, ids]              # node i's partial of chunk i
            full = np.full(own.shape[1], np.uint64(0xFFFFFFFFFFFFFFFF))
            tail = n % 64
            if tail:
                full[-1] = np.uint64((1 << tail) - 1)
            bad = np.flatnonzero((own != full).any(axis=1)).tolist()
            if bad:
                raise AssertionError(
                    f"reduce-scatter semantics violated: nodes {bad[:8]} do "
                    "not own the complete reduction of their chunk"
                )
        else:
            # every node must hold exactly chunk c's originator, for every c
            want = np.zeros((n, state.shape[2]), dtype=np.uint64)
            want[ids, ids // 64] = np.left_shift(
                np.uint64(1), (ids % 64).astype(np.uint64))
            bad = np.flatnonzero(
                (state != want[None]).any(axis=(1, 2))).tolist()
            if bad:
                raise AssertionError(
                    f"all-gather semantics violated: nodes {bad[:8]} are "
                    "missing (or corrupting) some chunk"
                )
    elif c == "alltoall":
        if len(sched.steps) != 1:
            raise AssertionError(
                f"all-to-all must be a single step, got {len(sched.steps)}"
            )
        b = sched.steps[0].transfers
        codes = np.sort(b.src * n + b.dst)
        pair = np.arange(n)[:, None] * n + np.arange(n)[None, :]
        want = np.sort(pair[~np.eye(n, dtype=bool)])
        if codes.size != want.size or (codes != want).any():
            raise AssertionError(
                "all-to-all semantics violated: transfer rows do not cover "
                "every ordered pair exactly once"
            )


def _contribution_words(sched: WRHTSchedule) -> np.ndarray:
    """Data-flow simulation over uint64 bitset rows (one row per node)."""
    n = sched.n
    n_words = (n + 63) // 64
    state = np.zeros((n, n_words), dtype=np.uint64)
    ids = np.arange(n)
    state[ids, ids // 64] = np.left_shift(
        np.uint64(1), (ids % 64).astype(np.uint64)
    )
    for step in sched.steps:
        batch = step.transfers
        if len(batch) == 0:
            continue
        order = np.argsort(batch.dst, kind="stable")
        srcs, dsts = batch.src[order], batch.dst[order]
        gathered = state[srcs]  # all reads precede all writes within a step
        bounds = np.flatnonzero(np.r_[True, dsts[1:] != dsts[:-1]])
        if bounds.size == dsts.size:
            # every receiver gets exactly one transfer (e.g. broadcast):
            # reduceat over singleton groups is pathologically slow, skip it
            merged, receivers = gathered, dsts
        else:
            merged = np.bitwise_or.reduceat(gathered, bounds, axis=0)
            receivers = dsts[bounds]
        if step.kind == "broadcast":
            # broadcast overwrites with the rep's full value
            state[receivers] = merged
        else:
            state[receivers] |= merged
    return state


def _incomplete_nodes(words: np.ndarray, n: int) -> list[int]:
    full = np.full(words.shape[1], np.uint64(0xFFFFFFFFFFFFFFFF))
    tail = n % 64
    if tail:
        full[-1] = np.uint64((1 << tail) - 1)
    return np.flatnonzero((words != full).any(axis=1)).tolist()


def simulate_contribution_masks(sched: WRHTSchedule) -> list[int]:
    """Per-node contribution bitmask: node i starts with bit i; transfers OR.

    A correct all-reduce leaves every node with all n bits set (summation is
    a commutative-associative reduction, so bit-union tracks it faithfully).
    """
    words = _contribution_words(sched)
    return [
        int.from_bytes(words[i].astype("<u8").tobytes(), "little")
        for i in range(sched.n)
    ]


def simulate_contributions(sched: WRHTSchedule) -> list[frozenset[int]]:
    """Set view of :func:`simulate_contribution_masks` (test convenience)."""
    return [
        frozenset(i for i in range(sched.n) if mask >> i & 1)
        for mask in simulate_contribution_masks(sched)
    ]


def _chunk_contribution_words(sched: WRHTSchedule) -> np.ndarray:
    """Chunk-granular data-flow simulation for the ring passes.

    Returns an ``[n, n_chunks, n_words]`` uint64 cube: ``state[v, c]`` is the
    contribution bitset of node ``v``'s current partial of chunk ``c``.
    Initial state per the collective's spec — reduce-scatter starts every
    node with its own bit on EVERY chunk (it holds its full local vector);
    all-gather starts node ``i`` with its own bit on chunk ``i`` only (it
    contributes exactly its owned shard).  Each transfer ORs the source's
    partial of ``Step.chunks[row]`` into the destination's; reads precede
    writes within a step, like :func:`_contribution_words`.
    """
    n = sched.n
    n_words = (n + 63) // 64
    ids = np.arange(n)
    bit = np.left_shift(np.uint64(1), (ids % 64).astype(np.uint64))
    state = np.zeros((n, n, n_words), dtype=np.uint64)
    if sched.collective == "all_gather":
        state[ids, ids, ids // 64] = bit
    else:
        state[ids[:, None], np.arange(n)[None, :], (ids // 64)[:, None]] = \
            bit[:, None]
    for step in sched.steps:
        b = step.transfers
        if len(b) == 0:
            continue
        if step.chunks is None:
            raise AssertionError(
                f"chunked collective step {step.kind!r} carries no chunk ids"
            )
        key = b.dst * n + step.chunks
        order = np.argsort(key, kind="stable")
        srcs, dsts = b.src[order], b.dst[order]
        cks = step.chunks[order]
        gathered = state[srcs, cks]       # reads precede writes in a step
        ksorted = key[order]
        bounds = np.flatnonzero(np.r_[True, ksorted[1:] != ksorted[:-1]])
        if bounds.size == ksorted.size:
            merged, rd, rc = gathered, dsts, cks
        else:
            merged = np.bitwise_or.reduceat(gathered, bounds, axis=0)
            rd, rc = dsts[bounds], cks[bounds]
        state[rd, rc] |= merged
    return state


def simulate_chunk_contributions(
    sched: WRHTSchedule,
) -> list[list[frozenset[int]]]:
    """Set view of the chunk-granular data-flow: ``result[v][c]`` is the set
    of nodes whose contribution reached node ``v``'s partial of chunk ``c``
    (test convenience for the conformance harness, small ``n`` only)."""
    state = _chunk_contribution_words(sched)
    n = sched.n
    out = []
    for v in range(n):
        row = []
        for c in range(n):
            mask = int.from_bytes(state[v, c].astype("<u8").tobytes(),
                                  "little")
            row.append(frozenset(i for i in range(n) if mask >> i & 1))
        out.append(row)
    return out


def theoretical_steps(n: int, m: int) -> tuple[int, int]:
    """Closed form of Sec. III-D: (with all-to-all, without) step counts."""
    if n <= 1:
        return (0, 0)
    l = max(1, math.ceil(math.log(n, m)))
    return (2 * l - 1, 2 * l)
