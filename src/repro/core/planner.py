"""α–β planner: choose the all-reduce schedule per bucket (Lemma 1 on TPU).

The paper minimizes communication *steps* because each optical step pays a
fixed MRR-reconfiguration delay ``a``.  On TPU the same role is played by the
per-collective launch/hop latency α, against the per-byte term β = 1/BW.
This module is the TPU restatement of Lemma 1/Theorem 1: enumerate candidate
schedules, cost them under the α–β model, return the argmin.

    flat ring (psum)      T = 2(S-1)·α + 2·(S-1)/S·bytes·β
    recursive doubling    T = log2(S)·(α + bytes·β)
    m-ary WRHT tree       T = Σ_levels (α + ⌈(m-1)/links⌉·bytes·β)   [full-d]
                          (+ mirrored broadcast levels; optional final
                           all-to-all replaces the top reduce+broadcast pair)
    hierarchical scatter  T = Σ_i [2(f_i-1)·α + 2·bytes_i·(f_i-1)/f_i·β],
                          bytes_i = bytes / Π_{j<i} f_j   (mesh-factorized)

The crossover the paper exploits appears exactly here: small buckets are
latency-bound (few-step WRHT tree wins), huge buckets are bandwidth-bound
(flat or hierarchical scatter wins).  ``benchmarks/planner_crossover.py``
plots it; the trainer plans all of its gradient buckets in one amortized
:func:`plan_buckets` call at setup (DESIGN.md §10) and dispatches each
bucket from the cached plan.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import numpy as np

# TPU v5e-ish defaults (assignment constants; α calibratable, see DESIGN.md §4)
DEFAULT_ALPHA_S = 1e-6          # per collective step: launch + hop latency
DEFAULT_LINK_GBPS = 50.0        # ICI per link (decimal GB/s, vendor convention)
DEFAULT_LINKS = 4               # links per chip usable concurrently (ring: 2x2 dirs)

BYTES_PER_GB = 1e9              # GB/s -> bytes/s, defined once
BITS_PER_BYTE = 8               # bit/s link specs -> bytes/s


@dataclass(frozen=True)
class CostParams:
    alpha_s: float = DEFAULT_ALPHA_S
    link_bw_Bps: float = DEFAULT_LINK_GBPS * BYTES_PER_GB
    links: int = DEFAULT_LINKS
    # per-bucket compression compute (DESIGN.md §15): one quantize before the
    # wire + one dequantize after, each a fixed kernel launch plus a linear
    # pass over the *logical* fp32 bytes.  ~4e11 B/s is a VPU-bound
    # streaming pass; the planner uses these to decline compression on
    # buckets too small for the β-term savings to cover the overhead.
    quant_alpha_s: float = 2e-6
    quant_Bps: float = 4e11

    @staticmethod
    def tpu_v5e() -> "CostParams":
        return CostParams(alpha_s=DEFAULT_ALPHA_S, link_bw_Bps=50e9, links=DEFAULT_LINKS)

    @staticmethod
    def optical(w: int = 64) -> "CostParams":
        """The paper's regime: huge per-step cost, w parallel channels
        (40 Gb/s per wavelength, so bytes/s = bits/s over 8)."""
        return CostParams(alpha_s=25e-6, link_bw_Bps=40e9 / BITS_PER_BYTE,
                          links=2 * w)


@dataclass(frozen=True)
class Plan:
    """A chosen schedule for one bucket."""

    strategy: str   # "flat" | "rd" | "wrht_tree" | "hier_scatter" | "alltoall"
    cost_s: float
    m: int = 2                       # branching for wrht_tree
    alltoall: bool = False           # finish tree with all-to-all
    factors: tuple[int, ...] = ()    # per-level sizes for hier_scatter
    detail: dict = field(default_factory=dict, compare=False, hash=False)


# Cost closed forms.  The ``_arr`` versions over a bytes *axis* are the
# single implementation (every form is affine in bytes, so the batched
# planner evaluates candidate × bucket matrices in one pass); the scalar
# entry points below are one-element wrappers — float and float64 IEEE
# arithmetic coincide, so the two views are bit-identical.

def _t_flat_ring_arr(s: int, b: np.ndarray, p: CostParams) -> np.ndarray:
    if s == 1:
        return np.zeros(b.size)
    return 2 * (s - 1) * p.alpha_s + 2 * b * (s - 1) / s / p.link_bw_Bps


def _t_rd_arr(s: int, b: np.ndarray, p: CostParams) -> np.ndarray:
    if s == 1:
        return np.zeros(b.size)
    return math.ceil(math.log2(s)) * (p.alpha_s + b / p.link_bw_Bps)


def _t_wrht_tree_arr(s: int, b: np.ndarray, p: CostParams, m: int,
                     alltoall: bool) -> np.ndarray:
    if s == 1:
        return np.zeros(b.size)
    serial = math.ceil((m - 1) / p.links)  # sequential link occupations/level
    levels = max(1, math.ceil(math.log(s, m)))
    steps = 2 * levels - (1 if alltoall else 0)
    return steps * (p.alpha_s + serial * b / p.link_bw_Bps)


def _t_hier_scatter_arr(factors: tuple[int, ...], b: np.ndarray,
                        p: CostParams) -> np.ndarray:
    total = np.zeros(b.size)
    b = b.astype(np.float64)  # private copy: divided level by level
    for f in factors:
        if f == 1:
            continue
        total += 2 * (f - 1) * p.alpha_s + 2 * b * (f - 1) / f / p.link_bw_Bps
        b /= f
    return total


# Closed forms of the non-all-reduce collectives (DESIGN.md §11).  The ring
# pass is one half of the flat ring's RS+AG; the single-step all-to-all
# trades ⌈N²/8⌉ wavelengths for a single α; the broadcast tree is half the
# WRHT tree's step count.

def _t_ring_pass_arr(s: int, b: np.ndarray, p: CostParams) -> np.ndarray:
    """Ring reduce-scatter or all-gather: S-1 steps of b/S chunks."""
    if s == 1:
        return np.zeros(b.size)
    return (s - 1) * p.alpha_s + b * (s - 1) / s / p.link_bw_Bps


def _t_alltoall_arr(s: int, b: np.ndarray, p: CostParams) -> np.ndarray:
    """One full-mesh step of personalized b/S shards: each node serializes
    its S-1 messages over ``links`` concurrent channels."""
    if s == 1:
        return np.zeros(b.size)
    serial = math.ceil((s - 1) / p.links)
    return p.alpha_s + serial * (b / s) / p.link_bw_Bps


def _t_bcast_tree_arr(s: int, b: np.ndarray, p: CostParams,
                      m: int) -> np.ndarray:
    """WRHT broadcast tree alone: ⌈log_m S⌉ full-vector levels."""
    if s == 1:
        return np.zeros(b.size)
    serial = math.ceil((m - 1) / p.links)
    levels = max(1, math.ceil(math.log(s, m)))
    return levels * (p.alpha_s + serial * b / p.link_bw_Bps)


def _t_quant_arr(b: np.ndarray, p: CostParams, bits: int) -> np.ndarray:
    """Per-bucket quantize+dequantize compute overhead (DESIGN.md §15).

    Strategy-independent — a compressed bucket pays it whatever schedule
    moves the wire bits — so it adds *after* the per-width strategy argmin
    without disturbing tie-breaking.  Zero at full width."""
    if bits >= 32:
        return np.zeros(b.size)
    return np.full(b.size, 2 * p.quant_alpha_s) + 2 * b / p.quant_Bps


def _wire_bytes(b: np.ndarray, bits: int) -> np.ndarray:
    """Logical fp32 bytes → wire bytes at ``bits`` per element (exact: the
    supported widths are power-of-two fractions of 32)."""
    return b if bits == 32 else b * (bits / 32.0)


def _alltoall_feasible(s: int, p: CostParams, max_hops: int | None) -> bool:
    """Single-step all-to-all feasibility under the analytic model: the
    wavelength budget is ``links // 2`` (the exact inverse of
    ``CostParams.optical``/``OpticalParams.from_cost``), and the longest
    shortest-direction pair spans ``⌊S/2⌋`` ring segments."""
    if math.ceil(s ** 2 / 8) > max(1, p.links // 2):
        return False
    return max_hops is None or s // 2 <= max_hops


def _b1(bytes_: float) -> np.ndarray:
    return np.asarray([bytes_], dtype=np.float64)


def t_flat_ring(s: int, bytes_: float, p: CostParams) -> float:
    return float(_t_flat_ring_arr(s, _b1(bytes_), p)[0])


def t_rd(s: int, bytes_: float, p: CostParams) -> float:
    return float(_t_rd_arr(s, _b1(bytes_), p)[0])


def t_wrht_tree(s: int, bytes_: float, p: CostParams, m: int,
                alltoall: bool = True) -> float:
    """Full-vector m-ary tree, per the paper's Eq. (1) with the TPU twist
    that a head drains its m-1 members over ``links`` parallel channels."""
    return float(_t_wrht_tree_arr(s, _b1(bytes_), p, m, alltoall)[0])


def t_hier_scatter(factors: tuple[int, ...], bytes_: float, p: CostParams) -> float:
    return float(_t_hier_scatter_arr(factors, _b1(bytes_), p)[0])


def _factorizations(n: int, max_levels: int = 3) -> list[tuple[int, ...]]:
    """All ordered factorizations of n into 1..max_levels factors >= 2."""
    out = [(n,)]
    if max_levels == 1:
        return out
    f = 2
    while f * f <= n:
        if n % f == 0:
            for rest in _factorizations(n // f, max_levels - 1):
                out.append((f,) + rest)
                if n // f != f:
                    out.append(rest + (f,))
        f += 1
    # dedupe preserving order
    seen, uniq = set(), []
    for t in out:
        if t not in seen:
            seen.add(t)
            uniq.append(t)
    return uniq


DEFAULT_STRATEGIES: dict[str, tuple[str, ...]] = {
    "allreduce": ("flat", "rd", "wrht_tree", "hier_scatter"),
    "reduce_scatter": ("flat", "alltoall"),
    "all_gather": ("flat", "alltoall"),
    "broadcast": ("wrht_tree",),
    "alltoall": ("alltoall",),
}


def plan_bucket(
    axis_size: int,
    bytes_: float,
    params: CostParams | None = None,
    m_candidates: tuple[int, ...] = (2, 3, 4, 8, 16),
    allow: tuple[str, ...] | None = None,
    max_hops: int | None = None,
    backend: str = "analytic",
    optical: "object | None" = None,
    collective: str = "allreduce",
    failures: "object | None" = None,
    depth: int = 1,
    bits: int = 32,
    bits_candidates: "tuple[int, ...] | None" = None,
) -> Plan:
    """Return the minimum-cost schedule for one bucket on one device axis.

    ``max_hops`` is the optical insertion-loss hop budget (see
    ``topology.PhysicalParams.max_hops``): a WRHT tree fan-out ``m`` whose
    middle representative would have to reach members more than ``max_hops``
    positions away (``m > 2·max_hops + 1``) is physically infeasible and is
    never enumerated.

    ``backend`` selects the cost model: ``"analytic"`` (the closed-form α–β
    expressions above) or ``"simulated"`` — the same candidate schedules
    costed by the flit-level optical simulator through the batched timing
    engine (``repro.core.timing``), making the two models interchangeable.
    Under ``backend="simulated"``, ``optical`` optionally supplies explicit
    ``step_models.OpticalParams`` (otherwise derived from ``params`` via
    ``OpticalParams.from_cost``); the ``"rd"`` strategy is skipped (it has
    no explicit optical-ring schedule) and ``"hier_scatter"`` is costed via
    the H-Ring schedule, i.e. only its two-level factorizations.

    ``collective`` plans any member of the scheduled collective algebra
    (DESIGN.md §11), with per-collective candidate strategies
    (:data:`DEFAULT_STRATEGIES`): the ring passes choose between the
    bandwidth-optimal ``"flat"`` ring pass and the single-step
    ``"alltoall"`` finisher (when it fits the wavelength/hop budgets); a
    broadcast sweeps the tree fan-out.

    ``failures`` plans against a degraded ring
    (:class:`~repro.core.topology.FailureMask`, DESIGN.md §12).  The
    simulated backend is exact: every candidate is the degraded builder's
    actual relay/detour schedule, and candidates the mask makes unroutable
    are skipped.  The analytic backend only models the λ loss — the
    channel count shrinks by the worst per-node dead-wavelength count —
    because its closed forms have no route notion; use the simulated
    backend when dead arcs/transceivers matter.

    ``depth`` costs the depth-k composed pipeline against the serial
    baseline (DESIGN.md §13) — see :func:`plan_buckets`.

    ``bits``/``bits_candidates`` make the wire width a plan axis
    (DESIGN.md §15) — see :func:`plan_buckets`.

    This is the one-bucket view of :func:`plan_buckets` — a single
    candidate-scan implementation serves both (DESIGN.md §10).
    """
    return plan_buckets(axis_size, [bytes_], params, m_candidates, allow,
                        max_hops, backend, optical, collective, failures,
                        depth, bits, bits_candidates)[0]


def plan_buckets(
    axis_size: int,
    byte_sizes,
    params: CostParams | None = None,
    m_candidates: tuple[int, ...] = (2, 3, 4, 8, 16),
    allow: tuple[str, ...] | None = None,
    max_hops: int | None = None,
    backend: str = "analytic",
    optical: "object | None" = None,
    collective: str = "allreduce",
    failures: "object | None" = None,
    depth: int = 1,
    bits: int = 32,
    bits_candidates: "tuple[int, ...] | None" = None,
) -> list[Plan]:
    """Plan a whole list of gradient-bucket sizes in one batched call.

    The amortized counterpart of :func:`plan_bucket` (DESIGN.md §10):
    returns ``[plan_bucket(axis_size, b, ...) for b in byte_sizes]``,
    *identically* (same strategies, same costs, same tie-breaking — the
    per-bucket argmin scans candidates in the same order with a strict
    ``<``), but with the work amortized across buckets:

    * analytic backend — every closed form is affine in ``bytes``, so the
      whole candidate × bucket cost matrix evaluates in one vectorized pass;
    * simulated backend — schedules are built and compiled once per
      candidate through the plan cache and the batched timing engine
      evaluates the entire payload axis per candidate (one
      :func:`repro.core.timing.tune_wrht` sweep serves every bucket), so
      the marginal cost of a bucket is one column of array arithmetic, not
      a schedule walk.

    The training stack calls this once at setup with every bucket size of
    the gradient partition (``repro.train.train_step.plan_gradient_sync``);
    warm calls hit the plan cache and skip both build and compile.

    ``depth>1`` additionally costs the depth-k *composed pipeline*
    (DESIGN.md §13: ``collective`` alternating with its partner phase —
    RS↔AG — interleaved on one ring with fused RWA) against the serial
    baseline (the sum of the constituents' serial best costs).  Buckets
    where the composition wins get the amortized per-phase composed cost
    and ``detail["pipeline"]["composed"]=True``; buckets where it does not
    keep their serial plan, with the comparison recorded honestly.

    ``bits`` plans at a fixed wire width (DESIGN.md §15): every strategy's
    β-term shrinks by exactly ``bits/32`` and a strategy-independent
    per-bucket quantize+dequantize compute term is added, recorded in
    ``detail["quant_s"]``.  ``bits_candidates`` (e.g. ``(32, 8, 4)``)
    instead *sweeps* the width per bucket: each width plans independently
    and the per-bucket winner is returned with ``detail["bits"]`` (the
    chosen width — 32 means the tuner declined compression for that
    bucket) and ``detail["compression"]`` (every width's best cost, so the
    decline is auditable).
    """
    if collective not in DEFAULT_STRATEGIES:
        raise ValueError(f"unknown collective {collective!r} "
                         f"(expected one of {sorted(DEFAULT_STRATEGIES)})")
    if depth < 1:
        raise ValueError("pipeline depth must be >= 1")
    if bits_candidates is not None:
        widths = tuple(dict.fromkeys(int(w) for w in bits_candidates))
        if not widths:
            raise ValueError("bits_candidates must name at least one width")
        per_width = {
            wd: plan_buckets(axis_size, byte_sizes, params, m_candidates,
                             allow, max_hops, backend, optical, collective,
                             failures, depth, wd)
            for wd in widths
        }
        merged: list[Plan] = []
        for i in range(len(per_width[widths[0]])):
            # first-argmin over widths in candidate order (strict <), like
            # the strategy scan's tie-breaking
            best_wd = widths[0]
            best_pl = per_width[best_wd][i]
            for wd in widths[1:]:
                if per_width[wd][i].cost_s < best_pl.cost_s:
                    best_wd, best_pl = wd, per_width[wd][i]
            detail = dict(best_pl.detail)
            detail["bits"] = best_wd
            detail["compression"] = {
                str(wd): float(per_width[wd][i].cost_s) for wd in widths}
            merged.append(replace(best_pl, detail=detail))
        return merged
    if bits < 1 or bits > 32:
        raise ValueError("wire width must satisfy 1 <= bits <= 32")
    p = params or CostParams.tpu_v5e()
    if failures is not None and failures.empty:
        failures = None
    if failures is not None and backend == "analytic":
        if failures.disconnects(axis_size):
            # a severed ring has no feasible schedule regardless of backend:
            # raise the uniform infeasibility signal HERE so the analytic
            # path agrees with the simulated one at the cliff (DESIGN.md §14)
            from .wrht import DegradedInfeasibleError
            raise DegradedInfeasibleError(
                f"failure mask severs the N={axis_size} ring "
                f"({failures!r}): no strategy can reach every node")
        # the closed forms have no route notion — the mask enters only as a
        # conservative channel shrink (worst per-node λ loss halves `links`
        # symmetrically, matching wrht.effective_wavelengths)
        w_eff = max(1, p.links // 2 - failures.max_dead_lambda_per_node())
        p = replace(p, links=2 * w_eff)
    b = np.asarray(list(byte_sizes), dtype=np.float64)
    if allow is None:
        allow = DEFAULT_STRATEGIES[collective]
    if collective != "allreduce":
        plans = _plan_buckets_collective(axis_size, b, p, m_candidates, allow,
                                         max_hops, backend, optical,
                                         collective, failures, bits)
    elif backend == "simulated":
        plans = _plan_buckets_simulated(axis_size, b, p, m_candidates, allow,
                                        max_hops, optical, failures, bits)
    elif backend != "analytic":
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected 'analytic' or 'simulated')")
    else:
        bw = _wire_bytes(b, bits)
        best, consider = _bucket_argmin(b.size)

        # candidate enumeration order matches plan_bucket exactly, so the
        # strict-< update reproduces its first-argmin tie-breaking
        if "flat" in allow:
            consider(_t_flat_ring_arr(axis_size, bw, p),
                     lambda i, c: Plan("flat", c))
        if "rd" in allow and axis_size & (axis_size - 1) == 0:
            consider(_t_rd_arr(axis_size, bw, p), lambda i, c: Plan("rd", c))
        if "wrht_tree" in allow:
            fan_out_cap = None if max_hops is None else 2 * max_hops + 1
            for m in m_candidates:
                if m < 2 or m > axis_size:
                    continue
                if fan_out_cap is not None and m > fan_out_cap:
                    continue
                for a2a in (True, False):
                    consider(
                        _t_wrht_tree_arr(axis_size, bw, p, m, a2a),
                        lambda i, c, m=m, a2a=a2a: Plan("wrht_tree", c, m=m,
                                                        alltoall=a2a))
        if "hier_scatter" in allow:
            for factors in _factorizations(axis_size):
                consider(_t_hier_scatter_arr(factors, bw, p),
                         lambda i, c, f=factors: Plan("hier_scatter", c,
                                                      factors=f))
        assert all(pl is not None for pl in best)
        plans = best
    if bits != 32:
        # strategy-independent per-bucket compression compute: added after
        # the strategy argmin (cannot disturb it), before the pipeline
        # comparison (each serial phase pays it, see _cost_pipelined)
        over = _t_quant_arr(b, p, bits)
        plans = [
            replace(pl, cost_s=pl.cost_s + float(over[i]),
                    detail={**pl.detail, "bits": bits,
                            "quant_s": float(over[i])})
            for i, pl in enumerate(plans)
        ]
    if depth > 1 and axis_size > 1:
        plans = _cost_pipelined(axis_size, b, p, params, plans, depth,
                                m_candidates, max_hops, backend, optical,
                                collective, failures, bits)
    return plans


def _bucket_argmin(n_buckets: int):
    """Strict-< per-bucket argmin scaffolding shared by the two
    ``plan_buckets`` backends: candidates scanned in ``plan_bucket``'s
    enumeration order keep its exact first-argmin tie-breaking.  Returns
    the result list and ``consider(cost[B], make_plan(i, cost_i))``."""
    best: list[Plan | None] = [None] * n_buckets
    best_cost = np.full(n_buckets, np.inf)

    def consider(cost: np.ndarray, make_plan) -> None:
        mask = cost < best_cost
        if mask.any():
            best_cost[mask] = cost[mask]
            for i in np.flatnonzero(mask):
                best[i] = make_plan(int(i), float(cost[i]))

    return best, consider


def _cost_pipelined(
    axis_size: int,
    b: np.ndarray,
    p: CostParams,
    params: CostParams | None,
    plans: list[Plan],
    depth: int,
    m_candidates: tuple[int, ...],
    max_hops: int | None,
    backend: str,
    optical,
    collective: str,
    failures,
    bits: int = 32,
) -> list[Plan]:
    """Cost the depth-k composed pipeline against the serial baseline
    (DESIGN.md §13) and adopt it per bucket where it wins.

    Serial baseline: the sum of each constituent phase's serial best cost
    (the partner phase is planned through the same backend).  Composed
    cost: the fused timeline's total — exact via the flit-level engine on
    the composed profile for the simulated backend; closed-form for the
    analytic backend (``depth`` concurrent ring passes fuse in groups of
    ``w = links // 2`` — each pass occupies one wavelength per fused slot —
    so the pipeline costs ``⌈depth / w⌉`` serial passes; tree collectives
    have no analytic overlap model and keep their serial plans).  The
    adopted ``cost_s`` is the amortized per-phase share
    ``composed_total / depth``; either way ``detail["pipeline"]`` records
    the comparison.
    """
    from dataclasses import replace as _replace

    from . import compose

    colls = compose.pipeline_collectives(collective, depth)
    serial = np.asarray([pl.cost_s for pl in plans], dtype=np.float64)
    by_coll = {colls[0]: serial}
    for c in dict.fromkeys(colls[1:]):
        if c in by_coll:
            continue
        # the ORIGINAL params go back in — plan_buckets re-applies the
        # analytic mask shrink itself, so passing the shrunk `p` would
        # double-count the λ loss
        by_coll[c] = np.asarray(
            [pl.cost_s for pl in plan_buckets(
                axis_size, b, params, m_candidates, None, max_hops, backend,
                optical, c, failures, 1, bits)], dtype=np.float64)
    serial_sum = np.sum([by_coll[c] for c in colls], axis=0)

    composed_total = None
    reason = None
    ring_pass_only = all(c in ("reduce_scatter", "all_gather")
                         for c in colls)
    if backend == "simulated":
        from . import step_models, timing, wrht
        from .wavelength import InsertionLossError, WavelengthConflictError

        opt = optical or step_models.OpticalParams.from_cost(
            p.alpha_s, p.link_bw_Bps, p.links
        )
        if max_hops is None and opt.physical is not None:
            max_hops = opt.physical.max_hops
        try:
            composed_total = timing.collective_times(
                collective, axis_size, b * 8, opt, opt.timing,
                max_hops=max_hops, keep_per_step=False, failures=failures,
                depth=depth, bits=bits).total_s
        except (InsertionLossError, WavelengthConflictError,
                wrht.DegradedInfeasibleError) as e:
            reason = f"composed pipeline infeasible: {e}"
    elif ring_pass_only:
        w = max(1, p.links // 2)
        composed_total = (math.ceil(depth / w)
                          * _t_ring_pass_arr(axis_size, _wire_bytes(b, bits),
                                             p))
    else:
        reason = ("analytic backend has no overlap model for "
                  f"constituents {sorted(set(colls))}")
    if composed_total is not None and bits != 32:
        # fairness vs the serial baseline: every serial phase's cost already
        # carries the per-bucket quantize/dequantize term, so the composed
        # timeline pays it once per constituent phase too
        composed_total = composed_total + depth * _t_quant_arr(b, p, bits)

    out = []
    for i, pl in enumerate(plans):
        info = {
            "depth": depth,
            "constituents": list(colls),
            "serial_s": float(serial_sum[i]),
            "composed_s": (None if composed_total is None
                           else float(composed_total[i])),
        }
        if reason is not None:
            info["reason"] = reason
        detail = dict(pl.detail)
        if composed_total is not None and composed_total[i] < serial_sum[i]:
            info["composed"] = True
            info["gain"] = 1.0 - float(composed_total[i]) / float(serial_sum[i])
            detail["pipeline"] = info
            out.append(_replace(pl, cost_s=float(composed_total[i]) / depth,
                                detail=detail))
        else:
            info["composed"] = False
            detail["pipeline"] = info
            out.append(_replace(pl, detail=detail))
    return out


def _plan_buckets_simulated(
    axis_size: int,
    b: np.ndarray,
    p: CostParams,
    m_candidates: tuple[int, ...],
    allow: tuple[str, ...],
    max_hops: int | None,
    optical,
    failures=None,
    bits: int = 32,
) -> list[Plan]:
    """The simulated backend: candidate schedules costed by the flit-level
    simulator over the whole ``d_bits`` axis at once, so every bucket shares
    the same compiled profiles (and the plan cache keeps them warm across
    calls).  Candidate mapping: ``flat`` → the 2(N-1)-step optical ring,
    ``wrht_tree`` → the WRHT sweep of :func:`repro.core.timing.tune_wrht`,
    ``hier_scatter`` → the H-Ring schedule per two-level factorization; all
    costed under the optical model's timing engine, like ``run_optical``.
    ``bits<32`` evaluates every candidate at the compressed wire width: the
    tuner compiles width-scaled profiles under ``bits``-stamped keys, and
    the fixed flat/H-Ring profiles evaluate at the width-scaled payload
    (bit-identical — the width factor is a power-of-two exponent shift that
    commutes with every division chain).  Imports the simulator stack
    lazily so the analytic planner keeps zero package dependencies."""
    from . import step_models, timing, wrht
    from .wavelength import InsertionLossError

    opt = optical or step_models.OpticalParams.from_cost(
        p.alpha_s, p.link_bw_Bps, p.links
    )
    # effective hop budget: an explicit max_hops wins, else the optical
    # physical model's — must match what tune_wrht derives, or the candidate
    # pre-filter below would let through fan-outs the tuner then rejects
    if max_hops is None and opt.physical is not None:
        max_hops = opt.physical.max_hops
    detail = {"backend": "simulated"}
    if axis_size == 1:
        return [Plan("flat", 0.0, detail=dict(detail)) for _ in range(b.size)]
    d_bits = b * 8
    d_wire = d_bits if bits == 32 else d_bits * (bits / 32.0)
    best, consider = _bucket_argmin(b.size)

    if "flat" in allow and failures is None:
        # the flat ring is a fixed wavelength-0 neighbour pattern with no
        # route-around — under a mask only the WRHT builder can replan
        cost = timing.ring_times(axis_size, d_wire, opt, opt.timing).total_s
        consider(cost, lambda i, c: Plan("flat", c, detail=dict(detail)))
    if "wrht_tree" in allow:
        cap = wrht.feasible_group_size(opt.wavelengths, max_hops,
                                       failures=failures)
        ms = tuple(m for m in m_candidates if 2 <= m <= min(axis_size, cap))
        if ms:
            try:
                tuned = timing.tune_wrht(axis_size, opt.wavelengths, d_bits,
                                         max_hops, p=opt, timing=opt.timing,
                                         m_candidates=ms, failures=failures,
                                         bits=bits)
            except wrht.DegradedInfeasibleError:
                tuned = None
            if tuned is not None:
                consider(tuned.best_total_s,
                         lambda i, c: Plan("wrht_tree", c,
                                           m=int(tuned.best_m[i]),
                                           alltoall=bool(
                                               tuned.best_alltoall[i]),
                                           detail=dict(detail)))
    if "hier_scatter" in allow and failures is None:
        for factors in _factorizations(axis_size, max_levels=2):
            if len(factors) != 2 or factors[0] < 2 or axis_size % factors[0]:
                continue
            try:
                cost = timing.hring_times(axis_size, d_wire, opt, opt.timing,
                                          g=factors[0]).total_s
            except InsertionLossError:
                continue
            consider(cost, lambda i, c, f=factors:
                     Plan("hier_scatter", c, factors=f, detail=dict(detail)))
    # "rd" has no explicit optical-ring schedule: skipped under this backend
    if any(pl is None for pl in best):
        if failures is not None:
            from .wrht import DegradedInfeasibleError

            raise DegradedInfeasibleError(
                "no strategy survives the failure mask for the simulated "
                f"backend (allow={allow!r}, failures={failures!r})"
            )
        raise ValueError(
            "no strategy in `allow` has an optical-ring schedule for the "
            f"simulated backend (allow={allow!r})"
        )
    return best


def _plan_buckets_collective(
    axis_size: int,
    b: np.ndarray,
    p: CostParams,
    m_candidates: tuple[int, ...],
    allow: tuple[str, ...],
    max_hops: int | None,
    backend: str,
    optical,
    collective: str,
    failures=None,
    bits: int = 32,
) -> list[Plan]:
    """Candidate scan for the non-all-reduce collectives (DESIGN.md §11).

    The analytic and simulated backends share one enumeration order (flat
    ring pass, then the single-step all-to-all, then the broadcast-tree
    fan-out sweep), so tie-breaking matches across backends exactly like
    the all-reduce path.  The simulated backend costs the same schedules
    the optical simulator executes (``timing.collective_times``); an
    all-to-all beyond the wavelength or hop budget is skipped, never
    silently mis-costed.
    """
    if backend not in ("analytic", "simulated"):
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected 'analytic' or 'simulated')")
    detail = {"backend": backend, "collective": collective}
    if axis_size == 1:
        return [Plan("flat", 0.0, detail=dict(detail)) for _ in range(b.size)]
    best, consider = _bucket_argmin(b.size)
    simulated = backend == "simulated"
    if simulated:
        from . import step_models, timing, wrht
        from .wavelength import InsertionLossError, WavelengthConflictError

        opt = optical or step_models.OpticalParams.from_cost(
            p.alpha_s, p.link_bw_Bps, p.links
        )
        if max_hops is None and opt.physical is not None:
            max_hops = opt.physical.max_hops
        d_bits = b * 8

        def simulated_cost(coll, **kw):
            try:
                return timing.collective_times(
                    coll, axis_size, d_bits, opt, opt.timing,
                    max_hops=max_hops, keep_per_step=False,
                    failures=failures, bits=bits, **kw).total_s
            except (InsertionLossError, WavelengthConflictError,
                    wrht.DegradedInfeasibleError):
                return None

    bw = _wire_bytes(b, bits)
    ring_pass = collective if collective in ("reduce_scatter",
                                             "all_gather") else None
    if "flat" in allow and ring_pass is not None:
        cost = (simulated_cost(ring_pass) if simulated
                else _t_ring_pass_arr(axis_size, bw, p))
        if cost is not None:
            consider(cost, lambda i, c: Plan("flat", c, detail=dict(detail)))
    if "alltoall" in allow:
        if simulated:
            cost = simulated_cost("alltoall")
        else:
            cost = (_t_alltoall_arr(axis_size, bw, p)
                    if _alltoall_feasible(axis_size, p, max_hops) else None)
        if cost is not None:
            consider(cost, lambda i, c: Plan("alltoall", c,
                                             detail=dict(detail)))
    if "wrht_tree" in allow and collective == "broadcast":
        fan_out_cap = None if max_hops is None else 2 * max_hops + 1
        ms = tuple(m for m in m_candidates
                   if 2 <= m <= axis_size
                   and (fan_out_cap is None or m <= fan_out_cap))
        if simulated:
            # same Lemma-1/hop-budget pre-filter as the all-reduce simulated
            # path: candidates beyond the tuner's feasible fan-out would make
            # it raise its internal "no feasible candidates" error instead of
            # this planner's uniform one below
            cap = wrht.feasible_group_size(opt.wavelengths, max_hops,
                                           failures=failures)
            ms = tuple(m for m in ms if m <= cap)
            if ms:
                try:
                    tuned = timing.tune_wrht(axis_size, opt.wavelengths,
                                             d_bits, max_hops, p=opt,
                                             timing=opt.timing,
                                             m_candidates=ms,
                                             collective="broadcast",
                                             failures=failures, bits=bits)
                except wrht.DegradedInfeasibleError:
                    tuned = None
                if tuned is not None:
                    consider(tuned.best_total_s,
                             lambda i, c: Plan("wrht_tree", c,
                                               m=int(tuned.best_m[i]),
                                               detail=dict(detail)))
        else:
            for m in ms:
                consider(_t_bcast_tree_arr(axis_size, bw, p, m),
                         lambda i, c, m=m: Plan("wrht_tree", c, m=m,
                                                detail=dict(detail)))
    if any(pl is None for pl in best):
        if failures is not None and simulated:
            from .wrht import DegradedInfeasibleError

            raise DegradedInfeasibleError(
                f"no strategy in allow={allow!r} survives the failure mask "
                f"for collective {collective!r} at axis_size={axis_size}"
            )
        raise ValueError(
            f"no feasible strategy in allow={allow!r} for collective "
            f"{collective!r} at axis_size={axis_size}"
        )
    return best


def crossover_table(
    axis_size: int,
    byte_sizes: tuple[float, ...] = tuple(2.0 ** e for e in range(10, 31, 2)),
    params: CostParams | None = None,
    backend: str = "analytic",
    max_hops: int | None = None,
    optical: "object | None" = None,
    collective: str = "allreduce",
    bits: int = 32,
    bits_candidates: "tuple[int, ...] | None" = None,
) -> list[dict]:
    """Bucket-size sweep: which schedule wins where (benchmark + tests).

    ``backend``/``max_hops``/``optical``/``bits``/``bits_candidates`` pass
    straight through to the planner, so the crossover benchmarks can sweep
    the flit-level simulated backend (and a hop budget, and compressed wire
    widths) next to the analytic closed forms; the whole sweep is one
    :func:`plan_buckets` call.
    """
    plans = plan_buckets(axis_size, byte_sizes, params, backend=backend,
                         max_hops=max_hops, optical=optical,
                         collective=collective, bits=bits,
                         bits_candidates=bits_candidates)
    return [
        {
            "bytes": int(b),
            "strategy": plan.strategy,
            "m": plan.m,
            "factors": plan.factors,
            "cost_us": plan.cost_s * 1e6,
            **({"bits": plan.detail["bits"]} if "bits" in plan.detail else {}),
        }
        for b, plan in zip(byte_sizes, plans)
    ]
