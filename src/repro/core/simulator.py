"""Optical ring interconnect simulator (the paper's in-house simulator, re-built).

Executes explicit per-step transfer schedules on the TeraRack-style ring of
``topology.Ring``.  Two timing engines (DESIGN.md §7):

* **lock-step** (:func:`simulate_steps`): each step pays the MRR
  reconfiguration delay ``a`` plus the duration of its *slowest* concurrent
  transfer (transfers inside one step are wavelength-parallel by
  construction; the RWA validator guarantees conflict-freedom).  This is the
  paper's model and the golden upper bound.
* **event-timed** (:func:`simulate_steps_event`): per-transfer start/finish
  times over the ``TransferBatch`` arrays, tracked per node.  With
  ``overlap=True`` it models SWOT-style reconfiguration–communication
  overlap: a node retunes its MRRs for the next step as soon as *its own*
  transfers finish, hiding the reconfiguration delay behind other nodes'
  tail transfers.  Never slower than lock-step; equal when overlap is off.

Flit alignment and O/E/O conversion follow Table II; when the ring carries a
``PhysicalParams`` model, receivers additionally pay per-hop propagation
delay, and every step is checked against the insertion-loss hop budget.

Besides WRHT (schedule from ``wrht.build_schedule``) this module builds the
explicit optical schedules of the three baselines the paper compares against
(Sec. IV-B): Ring, Binary-Tree and H-Ring — all validated for wavelength
conflicts before timing.  Baseline steps are emitted directly as
``TransferBatch`` arrays (DESIGN.md §1), so even the N-transfer flat-ring
step is built in O(1) NumPy calls.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import numpy as np

from . import step_models, wrht
from .topology import (CCW, CW, FailureMask, FaultTimeline,
                       ResourceObservation, Ring, TransferBatch)
from .wavelength import InsertionLossError, validate_no_conflicts


@dataclass
class SimResult:
    algorithm: str
    n: int
    d_bits: float
    steps: int
    serialization_s: float
    reconfig_s: float
    max_wavelengths: int = 0
    per_step_s: list[float] = field(default_factory=list)
    timing: str = "lockstep"           # engine that produced the result
    event_total_s: float | None = None  # overlap mode: makespan (not additive)

    @property
    def total_s(self) -> float:
        if self.event_total_s is not None:
            return self.event_total_s
        return self.serialization_s + self.reconfig_s


def _step_durations(
    ring: Ring, batch: TransferBatch, bits_override: float | None
) -> tuple[np.ndarray, np.ndarray]:
    """Per-transfer (transmit, receive) durations for one step.

    Transmit ends after flit-aligned serialization + O/E/O; the receiver is
    additionally ``hops`` segments of flight time downstream when the ring
    carries a physical model (zero otherwise, preserving the seed timing).
    """
    if bits_override is not None:
        ser = np.full(len(batch), ring.serialization_time(bits_override))
    else:
        ser = ring.serialization_time_array(batch.bits)
    if ring.physical is None:
        return ser, ser
    return ser, ser + ring.propagation_time(batch.arcs(ring.n)[2])


def simulate_steps(
    name: str, steps: list[wrht.Step], ring: Ring, d_bits: float,
    validate: bool = True, bits_override: float | None = None,
) -> SimResult:
    """Lock-step engine: Σ over steps of (reconfig + slowest transfer)."""
    ser = 0.0
    per_step = []
    maxw = 0
    for step in steps:
        batch = step.transfers
        if validate:
            validate_no_conflicts(batch, ring.n, ring.w, max_hops=ring.max_hops)
        if len(batch) == 0:
            s = 0.0
        else:
            # durations are monotone in bits, so the slowest concurrent
            # transfer bounds the step (with propagation: max over rx ends)
            s = float(_step_durations(ring, batch, bits_override)[1].max())
        ser += s
        per_step.append(s + ring.reconfig_delay_s)
        maxw = max(maxw, step.wavelengths)
    return SimResult(
        algorithm=name,
        n=ring.n,
        d_bits=d_bits,
        steps=len(steps),
        serialization_s=ser,
        reconfig_s=len(steps) * ring.reconfig_delay_s,
        max_wavelengths=maxw,
        per_step_s=per_step,
    )


def simulate_steps_event(
    name: str, steps: list[wrht.Step], ring: Ring, d_bits: float,
    overlap: bool = False, validate: bool = True,
    bits_override: float | None = None,
) -> SimResult:
    """Event-timed engine: per-transfer finish times over the batch arrays.

    Per-node readiness ``ready[v]`` tracks when node ``v`` is data-current
    and free.  A transfer starts once both endpoints are ready and retuned:

    * ``overlap=False`` — global step barrier: every transfer of step ``s``
      starts at ``max(ready) + a``.  Totals equal :func:`simulate_steps`
      bit-for-bit (same accumulation order), which the tests pin down.
    * ``overlap=True`` — SWOT-style: transfer ``i`` starts at
      ``max(ready[src_i], ready[dst_i]) + a``, so nodes whose step-``s-1``
      work finished early pay their MRR reconfiguration *during* the tail
      transfers of step ``s-1``.  Segment-level circuit teardown is modelled
      as endpoint availability (the binding constraint on a WDM ring where
      successive steps reuse disjoint wavelength sets); data dependencies
      are exact: a source transmits only after all its receptions finished.

    The transmitter frees at ``start + serialization``; the receiver at
    ``start + serialization + propagation`` (physical model permitting).
    """
    a = ring.reconfig_delay_s
    ready = np.zeros(ring.n)
    per_step: list[float] = []
    maxw = 0
    ser = 0.0     # lock-step-comparable per-step-max accumulation
    t_prev = 0.0
    for step in steps:
        batch = step.transfers
        if validate:
            validate_no_conflicts(batch, ring.n, ring.w, max_hops=ring.max_hops)
        if len(batch) == 0:
            # an empty step still retunes every node's MRRs — charge the
            # reconfiguration delay here exactly as ``reconfig_s`` (and the
            # lock-step engine's per_step) account for it, so sum(per_step)
            # equals the reported total in every engine
            ready += a
            t_prev += a
            per_step.append(a)
            continue
        tx, rx = _step_durations(ring, batch, bits_override)
        if overlap:
            start = np.maximum(ready[batch.src], ready[batch.dst]) + a
        else:
            start = np.full(len(batch), ready.max() + a)
        np.maximum.at(ready, batch.src, start + tx)
        np.maximum.at(ready, batch.dst, start + rx)
        t = float(ready.max())
        per_step.append(t - t_prev)
        t_prev = t
        ser += float(rx.max())
        maxw = max(maxw, step.wavelengths)
    if overlap:
        # the barrier execution is always admissible, so the makespan is
        # capped by the lock-step total; min() also pins the `event <=
        # lockstep` invariant exactly under FP accumulation-order noise.
        # Clamp audit for composed schedules (DESIGN.md §13): `ser` sums
        # the maxes of the steps as GIVEN — for a ComposedSchedule that is
        # the FUSED timeline (both collectives' transfers inside one
        # step), so the cap is the composition's own barrier execution,
        # not the serial sum of the constituents' lockstep totals.  The
        # cross-schedule credit (B's reconfiguration under A's
        # communication) lives inside each fused step and survives the
        # clamp; tests/test_compose.py pins the three engines' agreement
        # on the serial path and `overlap <= event == lockstep` composed.
        lockstep_total = ser + len(steps) * ring.reconfig_delay_s
        return SimResult(
            algorithm=name, n=ring.n, d_bits=d_bits, steps=len(steps),
            serialization_s=ser, reconfig_s=len(steps) * ring.reconfig_delay_s,
            max_wavelengths=maxw, per_step_s=per_step, timing="overlap",
            event_total_s=min(float(ready.max()), lockstep_total),
        )
    # barrier mode: report the same additive decomposition as lock-step so
    # the two are exactly comparable (event_total_s left unset on purpose)
    return SimResult(
        algorithm=name, n=ring.n, d_bits=d_bits, steps=len(steps),
        serialization_s=ser, reconfig_s=len(steps) * ring.reconfig_delay_s,
        max_wavelengths=maxw, per_step_s=per_step, timing="event",
    )


# ---------------------------------------------------------------------------
# Per-resource health telemetry: the observation source of the closed
# fault-management loop (DESIGN.md §14).
# ---------------------------------------------------------------------------

def _schedule_touches(steps: list[wrht.Step], n: int, kind: str,
                      ident: tuple[int, int]) -> bool:
    """Does any transfer of the schedule exercise the resource?

    ``segment (lane, seg)``: some lightpath covers the directed span.
    ``wavelength (node, λ)``: some transfer adds or drops λ at the node.
    ``transceiver (node, lane)``: some transfer starts or ends at the node
    on that fiber (pass-through traffic exercises neither λ banks nor
    transceivers — the exact semantics the :class:`FailureMask` classes
    enforce).
    """
    a, b = ident
    for step in steps:
        batch = step.transfers
        if len(batch) == 0:
            continue
        lane, start, hops = batch.arcs(n)
        if kind == "segment":
            off = (b - start) % n
            if bool(((lane == a) & (off < hops)).any()):
                return True
        elif kind == "wavelength":
            at_node = (batch.src == a) | (batch.dst == a)
            if bool((at_node & (batch.wavelength == b)).any()):
                return True
        else:  # transceiver
            at_node = (batch.src == a) | (batch.dst == a)
            if bool((at_node & (lane == b)).any()):
                return True
    return False


def observe_faults(
    timeline: FaultTimeline, step: int,
    steps: "list[wrht.Step] | None" = None, n: int | None = None,
) -> list[ResourceObservation]:
    """Per-resource health telemetry for one training step.

    Emits one :class:`~repro.core.topology.ResourceObservation` per
    resource the ``timeline`` tracks — ``ok=False`` while the resource's
    :class:`~repro.core.topology.FlapSchedule` says it is down (a per-λ /
    per-span error or timeout event), ``ok=True`` otherwise.  This is the
    raw signal the :class:`~repro.runtime.fault_tolerance.HealthMonitor`
    smooths with confirm/cooldown hysteresis (DESIGN.md §14); the monitor,
    not this probe, decides what becomes a :class:`FailureMask`.

    With ``steps``/``n`` given, observations are restricted to resources
    the schedule actually exercises — a dead λ nobody adds or drops
    produces no error event, so detection latency genuinely depends on
    traffic, exactly like hardware monitoring.
    """
    if steps is not None and n is None:
        raise ValueError("observe_faults(steps=...) needs the ring size n")
    out = []
    for f in timeline.flaps:
        if steps is not None and not _schedule_touches(steps, n, f.kind,
                                                       f.ident):
            continue
        out.append(ResourceObservation(step, f.kind, f.ident,
                                       ok=not f.is_down(step)))
    return out


# ---------------------------------------------------------------------------
# Baseline schedules on the optical ring.
# ---------------------------------------------------------------------------

def ring_allreduce_schedule(n: int, d_bits: float) -> list[wrht.Step]:
    """Bandwidth-optimal ring all-reduce: reduce-scatter + all-gather,
    2(N-1) steps, every node forwards a d/N chunk to its CW neighbour.
    Neighbour hops occupy disjoint segments -> wavelength 0 everywhere
    (the paper's point: only ONE of w wavelengths is ever used)."""
    src = np.arange(n)
    batch = TransferBatch.from_arrays(
        src, (src + 1) % n, CW, d_bits / n, wavelength=0, check=False
    )
    # every step is the identical neighbour pattern; batches are immutable
    # by convention, so one array set backs all 2(N-1) steps
    return [wrht.Step("ring", 0, batch) for _ in range(2 * (n - 1))]


def bt_allreduce_schedule(n: int, d_bits: float) -> list[wrht.Step]:
    """Binary-tree all-reduce (Sec. III-B, Fig. 2a): ⌈log₂N⌉ reduce steps
    (sender at offset 2^{i-1} inside each 2^i-group sends the FULL vector to
    the group head) + the mirrored broadcast."""
    levels = max(1, math.ceil(math.log2(n)))
    reduce_steps = []
    for i in range(1, levels + 1):
        span, half = 2**i, 2 ** (i - 1)
        heads = np.arange(0, n, span)
        senders = heads + half
        heads, senders = heads[senders < n], senders[senders < n]
        reduce_steps.append(wrht.Step("reduce", i - 1, TransferBatch.from_arrays(
            senders, heads, CCW, d_bits, wavelength=0, check=False
        )))
    bcast_steps = [
        wrht.Step("broadcast", s.level, TransferBatch.from_arrays(
            s.transfers.dst, s.transfers.src, CW, d_bits, wavelength=0, check=False
        ))
        for s in reversed(reduce_steps)
    ]
    return reduce_steps + bcast_steps


def hring_group_size(n: int, g: int) -> int:
    """Largest usable H-Ring group size ``<= g`` dividing ``n`` (1 when none
    exists, e.g. prime N — callers fall back to the flat ring).  Shared by
    ``run_optical`` and the batched ``timing`` front-end so both always time
    the same schedule."""
    g = min(g, n)
    while g > 1 and n % g:
        g -= 1
    return g


def check_hring_span(ring: Ring, n: int, g: int) -> None:
    """Longest H-Ring lightpath vs the insertion-loss hop budget.

    The inter-group hop spans ``g`` segments (when >= 2 groups exist), the
    intra wrap link ``g - 1``; the analytic lock-step shortcut skips
    per-transfer validation, so this single check gates both the per-point
    and the batched H-Ring paths (shared for the same reason as
    :func:`hring_group_size`)."""
    span = g if n // g >= 2 else g - 1
    if ring.max_hops is not None and span > ring.max_hops:
        raise InsertionLossError(
            f"H-Ring lightpath spans {span} segments, exceeding the "
            f"insertion-loss hop budget of {ring.max_hops}"
        )


def hring_allreduce_schedule(n: int, g: int, d_bits: float) -> list[wrht.Step]:
    """Hierarchical ring [13]: intra-group ring reduce-scatter (chunks d/g),
    inter-group ring all-reduce among the g-group heads on each d/g shard,
    intra-group all-gather.  Intra wrap-links ride the CCW fiber; all other
    hops ride CW, so one wavelength per fiber suffices."""
    if g < 2:
        raise ValueError("H-Ring needs group size g >= 2 (g=1 degenerates to "
                         "a self-transfer on the intra wrap link)")
    if n % g:
        raise ValueError("H-Ring needs g | N")
    n_groups = n // g
    steps: list[wrht.Step] = []

    def intra_step(chunk_bits: float) -> wrht.Step:
        heads = np.arange(0, n, g)
        fwd_src = (heads[:, None] + np.arange(g - 1)[None, :]).ravel()
        src = np.concatenate([fwd_src, heads + g - 1])
        dst = np.concatenate([fwd_src + 1, heads])
        direction = np.concatenate([
            np.full(fwd_src.size, CW),
            np.full(heads.size, CCW),  # wrap link of the logical intra ring
        ])
        return wrht.Step("intra", 0, TransferBatch.from_arrays(
            src, dst, direction, chunk_bits, wavelength=0, check=False
        ))

    def inter_step(chunk_bits: float) -> wrht.Step:
        heads = np.arange(n_groups) * g
        # wrap link closes the logical ring CW through the last group's span
        dst = np.roll(heads, -1)
        return wrht.Step("inter", 1, TransferBatch.from_arrays(
            heads, dst, CW, chunk_bits, wavelength=0, check=False
        ))

    intra = intra_step(d_bits / g)
    inter = inter_step((d_bits / g) / n_groups)
    steps.extend([intra] * (g - 1))                 # intra reduce-scatter
    steps.extend([inter] * (2 * (n_groups - 1)))    # inter ring all-reduce
    steps.extend([intra] * (g - 1))                 # intra all-gather
    return steps


# ---------------------------------------------------------------------------
# Front-ends used by the benchmarks.
# ---------------------------------------------------------------------------

def _cached_wrht_schedule(
    n: int, w: int, m: int | None, max_hops: int | None = None,
    allow_alltoall: bool = True, failures: FailureMask | None = None,
) -> wrht.WRHTSchedule:
    """WRHT schedule structure is independent of the payload size — build and
    fully validate (structural + semantic, both vectorized) once per
    (n, w, m, hop budget, all-to-all policy).  Historically an ad-hoc
    ``lru_cache``; now a thin front-end over the two-tier plan cache
    (``repro.core.plan_cache``, DESIGN.md §10), which also holds the
    compiled timing profiles keyed on the same d-independent structure."""
    from . import plan_cache

    return plan_cache.get_default().schedule(plan_cache.PlanKey(
        n=n, w=w, m=m, alltoall=allow_alltoall, max_hops=max_hops,
        failures=failures))


def _simulate(
    name: str, steps: list[wrht.Step], ring: Ring, d_bits: float, timing: str,
    validate: bool = True, bits_override: float | None = None,
) -> SimResult:
    if timing == "lockstep":
        return simulate_steps(name, steps, ring, d_bits, validate=validate,
                              bits_override=bits_override)
    if timing in ("event", "overlap"):
        return simulate_steps_event(name, steps, ring, d_bits,
                                    overlap=timing == "overlap",
                                    validate=validate,
                                    bits_override=bits_override)
    raise ValueError(f"unknown timing {timing!r} "
                     "(expected 'lockstep', 'event' or 'overlap')")


def run_optical(
    algorithm: str,
    n: int,
    d_bits: float,
    p: step_models.OpticalParams | None = None,
    g: int = 8,
    m: int | str | None = None,
    timing: str | None = None,
    failures: FailureMask | None = None,
) -> SimResult:
    """Simulate one all-reduce on the optical ring.

    ``timing`` overrides ``p.timing`` ("lockstep" | "event" | "overlap").
    With ``p.physical`` set, WRHT schedules are built under the insertion-
    loss hop budget and every simulated step is checked against it — a
    baseline whose fixed schedule needs longer lightpaths than the budget
    allows (e.g. binary tree at small budgets) raises ``InsertionLossError``,
    which ``benchmarks/bench_insertion_loss.py`` reports as infeasible.

    ``m="auto"`` hands the WRHT fan-out choice to the simulator-backed
    auto-tuner (:func:`repro.core.timing.tune_wrht`): every feasible group
    size — and the final all-to-all on/off — is swept through the batched
    timing engine and the simulated argmin is used here.

    ``failures`` simulates the degraded ring (DESIGN.md §12) — WRHT only:
    the baselines' schedules are fixed patterns with no route-around, so a
    non-empty mask on them is an error, not a silently wrong number.
    """
    p = p or step_models.OpticalParams()
    timing = timing or p.timing
    if failures is not None and failures.empty:
        failures = None
    ring = Ring(n, p.wavelengths, bandwidth_bps=p.bandwidth_bps,
                reconfig_delay_s=p.reconfig_delay_s, physical=p.physical,
                failures=failures)
    if algorithm == "wrht":
        allow_alltoall = True
        if m == "auto":
            from . import timing as _timing  # import here: timing builds on us
            tuned = _timing.tune_wrht(n, p.wavelengths, d_bits, ring.max_hops,
                                      p=p, timing=timing, failures=failures)
            m, allow_alltoall = tuned.best(0)
        sched = _cached_wrht_schedule(n, p.wavelengths, m, ring.max_hops,
                                      allow_alltoall, failures)
        # every WRHT transfer carries the constant full vector d
        return _simulate("wrht", sched.steps, ring, d_bits, timing,
                         validate=False, bits_override=d_bits)
    if failures is not None:
        raise ValueError(
            f"algorithm {algorithm!r} has a fixed schedule and cannot route "
            "around failures — only 'wrht' supports a failure mask"
        )
    if algorithm == "ring":
        # every one of the 2(N-1) steps is the identical neighbour pattern
        # and every node is both a sender and a receiver, so all three
        # timing engines coincide (uniform per-node finish times): validate/
        # time one representative step and scale (exact, since the per-step
        # payload d/N is constant).
        src = np.arange(n)
        one = [wrht.Step("ring", 0, TransferBatch.from_arrays(
            src, (src + 1) % n, CW, d_bits / n, wavelength=0, check=False
        ))]
        r = simulate_steps("ring", one, ring, d_bits)
        k = 2 * (n - 1)
        return SimResult("ring", n, d_bits, k, r.serialization_s * k,
                         k * ring.reconfig_delay_s, r.max_wavelengths,
                         timing=timing)
    if algorithm == "bt":
        return _simulate("bt", bt_allreduce_schedule(n, d_bits), ring, d_bits,
                         timing)
    if algorithm == "hring":
        g = hring_group_size(n, g)
        if g < 2:
            # prime (or tiny) N admits no proper grouping: H-Ring degenerates
            # to the flat ring; report that schedule under the hring label
            return replace(run_optical("ring", n, d_bits, p, timing=timing),
                           algorithm="hring")
        check_hring_span(ring, n, g)
        if timing != "lockstep":
            # heads and members have genuinely different idle patterns, so
            # the event engines need the explicit full-N schedule
            return _simulate("hring", hring_allreduce_schedule(n, g, d_bits),
                             ring, d_bits, timing)
        sched = hring_allreduce_schedule(2 * g, g, d_bits)  # one intra + inter template
        intra = simulate_steps("hring-intra", [sched[0]], Ring(2 * g, ring.w,
                               bandwidth_bps=ring.bandwidth_bps,
                               reconfig_delay_s=ring.reconfig_delay_s,
                               physical=ring.physical), d_bits)
        n_groups = n // g
        intra_steps = 2 * (g - 1)
        inter_steps = 2 * (n_groups - 1)
        inter_ser = ring.serialization_time((d_bits / g) / n_groups)
        if ring.physical is not None:
            # inter-group heads are g segments apart: receivers pay flight time
            inter_ser += float(ring.propagation_time(np.asarray([g]))[0])
        total_steps = intra_steps + inter_steps
        ser = intra_steps * intra.serialization_s + inter_steps * inter_ser
        return SimResult("hring", n, d_bits, total_steps, ser,
                         total_steps * ring.reconfig_delay_s, 1)
    raise ValueError(f"unknown optical algorithm {algorithm!r}")


def run_collective(
    collective: str,
    n: int,
    d_bits: float,
    p: step_models.OpticalParams | None = None,
    m: int | None = None,
    timing: str | None = None,
    allow_alltoall: bool = True,
    failures: FailureMask | None = None,
) -> SimResult:
    """Simulate one scheduled collective on the optical ring (DESIGN.md §11).

    The per-point counterpart of :func:`repro.core.timing.collective_times`
    (which is golden-tested bit-identical to this path): the schedule's
    d-independent structure comes from the plan cache, and the payload
    accounting follows the collective's spec — the ring passes and the
    all-to-all carry ``d/n`` per transfer, the trees the constant full
    vector.  Infeasible schedules raise exactly like the builders
    (``WavelengthConflictError`` / ``InsertionLossError``).
    """
    from . import plan_cache

    p = p or step_models.OpticalParams()
    timing = timing or p.timing
    name = wrht.coerce_collective(collective)
    spec = wrht.COLLECTIVES[name]
    if failures is not None and failures.empty:
        failures = None
    ring = Ring(max(n, 2), p.wavelengths, bandwidth_bps=p.bandwidth_bps,
                reconfig_delay_s=p.reconfig_delay_s, physical=p.physical,
                failures=failures)
    km, ka = wrht.collective_plan_fields(name, m, allow_alltoall)
    sched = plan_cache.get_default().schedule(plan_cache.PlanKey(
        n=n, w=p.wavelengths, m=km, alltoall=ka, max_hops=ring.max_hops,
        collective=name, failures=failures))
    # the same division chain as the profile's PayloadClass((n,)) — float /
    # int division promotes identically, so the two paths stay bit-identical
    bits = d_bits / n if spec.chunked else d_bits
    return _simulate(name, sched.steps, ring, d_bits, timing,
                     validate=False, bits_override=bits)


def simulate_composed(
    composed,
    d_bits: float,
    p: step_models.OpticalParams | None = None,
    timing: str | None = None,
    validate: bool = False,
) -> SimResult:
    """Per-point timing of a :class:`~repro.core.compose.ComposedSchedule`
    (DESIGN.md §13) — the scalar counterpart of
    :meth:`~repro.core.timing.ScheduleProfile.from_composed`.

    The fused timeline runs through the unchanged engines with
    ``bits_override=None``: composed steps mix payload classes (an RS
    chunk under a broadcast full vector), so every transfer times at its
    own build-time bits — the constituents must therefore have been built
    at this ``d_bits``.  With ``timing="overlap"`` the per-node readiness
    recurrence grants the SWOT-style credit across constituents: one
    schedule's reconfiguration hides under the other's communication
    inside each fused step (see the clamp-audit note in
    :func:`simulate_steps_event`).
    """
    p = p or step_models.OpticalParams()
    timing = timing or p.timing
    ring = Ring(max(composed.n, 2), composed.w,
                bandwidth_bps=p.bandwidth_bps,
                reconfig_delay_s=p.reconfig_delay_s, physical=p.physical,
                failures=composed.failures)
    name = "composed:" + "+".join(s.collective for s in composed.schedules)
    return _simulate(name, composed.as_steps(), ring, d_bits, timing,
                     validate=validate, bits_override=None)
