"""Optical ring interconnect simulator (the paper's in-house simulator, re-built).

Executes explicit per-step transfer schedules on the TeraRack-style ring of
``topology.Ring``: each step pays the MRR reconfiguration delay ``a`` plus the
serialization time of its *slowest* concurrent transfer (transfers inside one
step are wavelength-parallel by construction; the RWA validator guarantees
conflict-freedom).  Flit alignment and O/E/O conversion follow Table II.

Besides WRHT (schedule from ``wrht.build_schedule``) this module builds the
explicit optical schedules of the three baselines the paper compares against
(Sec. IV-B): Ring, Binary-Tree and H-Ring — all validated for wavelength
conflicts before timing.  Baseline steps are emitted directly as
``TransferBatch`` arrays (DESIGN.md §1), so even the N-transfer flat-ring
step is built in O(1) NumPy calls.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import numpy as np

from . import step_models, wrht
from .topology import CCW, CW, Ring, TransferBatch
from .wavelength import validate_no_conflicts


@dataclass
class SimResult:
    algorithm: str
    n: int
    d_bits: float
    steps: int
    serialization_s: float
    reconfig_s: float
    max_wavelengths: int = 0
    per_step_s: list[float] = field(default_factory=list)

    @property
    def total_s(self) -> float:
        return self.serialization_s + self.reconfig_s


def simulate_steps(
    name: str, steps: list[wrht.Step], ring: Ring, d_bits: float,
    validate: bool = True, bits_override: float | None = None,
) -> SimResult:
    ser = 0.0
    per_step = []
    maxw = 0
    for step in steps:
        batch = step.transfers
        if validate:
            validate_no_conflicts(batch, ring.n, ring.w)
        if len(batch) == 0:
            s = 0.0
        elif bits_override is not None:
            s = ring.serialization_time(bits_override)
        else:
            # serialization_time is monotone in bits, so the slowest
            # concurrent transfer is the one with the largest payload
            s = ring.serialization_time(float(batch.bits.max()))
        ser += s
        per_step.append(s + ring.reconfig_delay_s)
        maxw = max(maxw, step.wavelengths)
    return SimResult(
        algorithm=name,
        n=ring.n,
        d_bits=d_bits,
        steps=len(steps),
        serialization_s=ser,
        reconfig_s=len(steps) * ring.reconfig_delay_s,
        max_wavelengths=maxw,
        per_step_s=per_step,
    )


# ---------------------------------------------------------------------------
# Baseline schedules on the optical ring.
# ---------------------------------------------------------------------------

def ring_allreduce_schedule(n: int, d_bits: float) -> list[wrht.Step]:
    """Bandwidth-optimal ring all-reduce: reduce-scatter + all-gather,
    2(N-1) steps, every node forwards a d/N chunk to its CW neighbour.
    Neighbour hops occupy disjoint segments -> wavelength 0 everywhere
    (the paper's point: only ONE of w wavelengths is ever used)."""
    src = np.arange(n)
    batch = TransferBatch.from_arrays(
        src, (src + 1) % n, CW, d_bits / n, wavelength=0, check=False
    )
    # every step is the identical neighbour pattern; batches are immutable
    # by convention, so one array set backs all 2(N-1) steps
    return [wrht.Step("ring", 0, batch) for _ in range(2 * (n - 1))]


def bt_allreduce_schedule(n: int, d_bits: float) -> list[wrht.Step]:
    """Binary-tree all-reduce (Sec. III-B, Fig. 2a): ⌈log₂N⌉ reduce steps
    (sender at offset 2^{i-1} inside each 2^i-group sends the FULL vector to
    the group head) + the mirrored broadcast."""
    levels = max(1, math.ceil(math.log2(n)))
    reduce_steps = []
    for i in range(1, levels + 1):
        span, half = 2**i, 2 ** (i - 1)
        heads = np.arange(0, n, span)
        senders = heads + half
        heads, senders = heads[senders < n], senders[senders < n]
        reduce_steps.append(wrht.Step("reduce", i - 1, TransferBatch.from_arrays(
            senders, heads, CCW, d_bits, wavelength=0, check=False
        )))
    bcast_steps = [
        wrht.Step("broadcast", s.level, TransferBatch.from_arrays(
            s.transfers.dst, s.transfers.src, CW, d_bits, wavelength=0, check=False
        ))
        for s in reversed(reduce_steps)
    ]
    return reduce_steps + bcast_steps


def hring_allreduce_schedule(n: int, g: int, d_bits: float) -> list[wrht.Step]:
    """Hierarchical ring [13]: intra-group ring reduce-scatter (chunks d/g),
    inter-group ring all-reduce among the g-group heads on each d/g shard,
    intra-group all-gather.  Intra wrap-links ride the CCW fiber; all other
    hops ride CW, so one wavelength per fiber suffices."""
    if g < 2:
        raise ValueError("H-Ring needs group size g >= 2 (g=1 degenerates to "
                         "a self-transfer on the intra wrap link)")
    if n % g:
        raise ValueError("H-Ring needs g | N")
    n_groups = n // g
    steps: list[wrht.Step] = []

    def intra_step(chunk_bits: float) -> wrht.Step:
        heads = np.arange(0, n, g)
        fwd_src = (heads[:, None] + np.arange(g - 1)[None, :]).ravel()
        src = np.concatenate([fwd_src, heads + g - 1])
        dst = np.concatenate([fwd_src + 1, heads])
        direction = np.concatenate([
            np.full(fwd_src.size, CW),
            np.full(heads.size, CCW),  # wrap link of the logical intra ring
        ])
        return wrht.Step("intra", 0, TransferBatch.from_arrays(
            src, dst, direction, chunk_bits, wavelength=0, check=False
        ))

    def inter_step(chunk_bits: float) -> wrht.Step:
        heads = np.arange(n_groups) * g
        # wrap link closes the logical ring CW through the last group's span
        dst = np.roll(heads, -1)
        return wrht.Step("inter", 1, TransferBatch.from_arrays(
            heads, dst, CW, chunk_bits, wavelength=0, check=False
        ))

    intra = intra_step(d_bits / g)
    inter = inter_step((d_bits / g) / n_groups)
    steps.extend([intra] * (g - 1))                 # intra reduce-scatter
    steps.extend([inter] * (2 * (n_groups - 1)))    # inter ring all-reduce
    steps.extend([intra] * (g - 1))                 # intra all-gather
    return steps


# ---------------------------------------------------------------------------
# Front-ends used by the benchmarks.
# ---------------------------------------------------------------------------

import functools


@functools.lru_cache(maxsize=256)
def _cached_wrht_schedule(n: int, w: int, m: int | None) -> wrht.WRHTSchedule:
    """WRHT schedule structure is independent of the payload size — build and
    fully validate (structural + semantic, both vectorized) once per
    (n, w, m).  The historical ``n <= 1024`` validation cap is gone: the
    array-based validator handles N=32768 in well under a second."""
    return wrht.build_schedule(n, w, 1.0, m=m, validate=True)


def run_optical(
    algorithm: str,
    n: int,
    d_bits: float,
    p: step_models.OpticalParams | None = None,
    g: int = 8,
    m: int | None = None,
) -> SimResult:
    p = p or step_models.OpticalParams()
    ring = Ring(n, p.wavelengths, bandwidth_bps=p.bandwidth_bps,
                reconfig_delay_s=p.reconfig_delay_s)
    if algorithm == "wrht":
        sched = _cached_wrht_schedule(n, p.wavelengths, m)
        # every WRHT transfer carries the constant full vector d
        return simulate_steps("wrht", sched.steps, ring, d_bits,
                              validate=False, bits_override=d_bits)
    if algorithm == "ring":
        # every one of the 2(N-1) steps is the identical neighbour pattern:
        # validate/time one representative step and scale (exact, since the
        # per-step payload d/N is constant).
        src = np.arange(n)
        one = [wrht.Step("ring", 0, TransferBatch.from_arrays(
            src, (src + 1) % n, CW, d_bits / n, wavelength=0, check=False
        ))]
        r = simulate_steps("ring", one, ring, d_bits)
        k = 2 * (n - 1)
        return SimResult("ring", n, d_bits, k, r.serialization_s * k,
                         k * ring.reconfig_delay_s, r.max_wavelengths)
    if algorithm == "bt":
        return simulate_steps("bt", bt_allreduce_schedule(n, d_bits), ring, d_bits)
    if algorithm == "hring":
        g = min(g, n)
        while g > 1 and n % g:
            g -= 1
        if g < 2:
            # prime (or tiny) N admits no proper grouping: H-Ring degenerates
            # to the flat ring; report that schedule under the hring label
            return replace(run_optical("ring", n, d_bits, p), algorithm="hring")
        sched = hring_allreduce_schedule(2 * g, g, d_bits)  # one intra + inter template
        intra = simulate_steps("hring-intra", [sched[0]], Ring(2 * g, ring.w,
                               bandwidth_bps=ring.bandwidth_bps,
                               reconfig_delay_s=ring.reconfig_delay_s), d_bits)
        n_groups = n // g
        intra_steps = 2 * (g - 1)
        inter_steps = 2 * (n_groups - 1)
        inter_ser = ring.serialization_time((d_bits / g) / n_groups)
        total_steps = intra_steps + inter_steps
        ser = intra_steps * intra.serialization_s + inter_steps * inter_ser
        return SimResult("hring", n, d_bits, total_steps, ser,
                         total_steps * ring.reconfig_delay_s, 1)
    raise ValueError(f"unknown optical algorithm {algorithm!r}")
