"""Device-level all-reduce implementations (shard_map bodies).

The TPU-native port of the paper's algorithm zoo.  Every function here is a
*manual-collective* body: it must be called inside ``jax.shard_map`` with the
named axis in ``axis_names``.  All take the static ``axis_size`` explicitly
(the mesh is known at trace time; passing it avoids relying on
constant-folding of ``psum(1, axis)``).

Implemented algorithms and their optical-paper counterparts:

    allreduce_psum        XLA's native all-reduce (reference / baseline)
    allreduce_ring        Ring (Patarasuk-Yuan): RS + AG via ppermute,
                          2(S-1) steps of 1/S-chunks   <-> paper's O-Ring
    allreduce_rd          recursive doubling, log2 S full-vector steps
                          <-> paper's RD baseline
    allreduce_bt          binary tree reduce + broadcast  <-> paper's BT
    allreduce_wrht_tree   the paper's contribution: m-ary hierarchical tree
                          with optional single-step all-to-all finish among
                          the surviving representatives.  ``m`` plays the
                          2w+1 role; each of the m-1 member transfers per
                          level is an independent ppermute (parallel
                          wavelengths -> parallel ICI channels).
    hierarchical_allreduce WRHT adapted to a *factorized mesh* (production
                          path): per-level reduce-scatter down the axis list
                          then all-gather back up ("scatter" mode — WRHT's
                          step structure with ring's bandwidth optimality),
                          or per-level full psum ("faithful" mode — the
                          paper's constant-d accounting).

Since PR 5 every *scheduled* collective (DESIGN.md §11) also has its
device-level shard_map twin here, with matching ownership semantics:

    reduce_scatter_ring / all_gather_ring      the ring passes (device i
                          owns chunk i, like the scheduled collectives)
    broadcast_wrht_tree   the WRHT broadcast tree alone (root = device 0)
    alltoall_ppermute     single-phase personalized all-to-all, plus the
                          reduce_scatter_alltoall / all_gather_alltoall
                          single-step finisher variants the planner can pick

Correctness of each against ``allreduce_psum`` is enforced by
``tests/test_collectives.py`` on 8 simulated devices, including a hypothesis
sweep; the scheduled-vs-device conformance pairing lives in
``tests/test_collective_conformance.py``.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _shift_perm(size: int, shift: int = 1) -> list[tuple[int, int]]:
    return [(s, (s + shift) % size) for s in range(size)]


def _pad_to(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """Flatten to 1-D and zero-pad so length % multiple == 0."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % multiple
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def _unpad(flat: jax.Array, pad: int, shape: tuple[int, ...]) -> jax.Array:
    if pad:
        flat = flat[: flat.shape[0] - pad]
    return flat.reshape(shape)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


def allreduce_psum(x: jax.Array, axis_name: str, axis_size: int | None = None) -> jax.Array:
    """XLA-native all-reduce — the reference the others are tested against."""
    del axis_size
    return lax.psum(x, axis_name)


def allreduce_ring(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Bandwidth-optimal ring all-reduce: reduce-scatter then all-gather,
    2(S-1) ppermute steps carrying 1/S of the payload each."""
    s = axis_size
    if s == 1:
        return x
    shape = x.shape
    flat, pad = _pad_to(x, s)
    chunks = flat.reshape(s, -1)  # [S, L/S]
    idx = lax.axis_index(axis_name)
    perm = _shift_perm(s)

    def chunk(c):
        return lax.dynamic_index_in_dim(chunks, c % s, axis=0, keepdims=False)

    # reduce-scatter: after S-1 hops node i owns fully-reduced chunk i
    send = chunk(idx + s - 1)
    for t in range(1, s):
        recv = lax.ppermute(send, axis_name, perm)
        send = recv + chunk(idx + s - 1 - t)

    # all-gather: circulate the owned chunk S-1 more hops
    out = jnp.zeros_like(chunks)
    out = lax.dynamic_update_index_in_dim(out, send, idx % s, axis=0)
    cur = send
    for t in range(1, s):
        cur = lax.ppermute(cur, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, cur, (idx - t) % s, axis=0)
    return _unpad(out.reshape(-1), pad, shape)


def reduce_scatter_ring(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Ring reduce-scatter only (returns this device's owned 1/S chunk of the
    padded flat payload; device ``i`` owns chunk ``i``, exactly the scheduled
    ``reduce_scatter`` collective's ownership map, DESIGN.md §11)."""
    s = axis_size
    if s == 1:
        return x.reshape(-1)
    flat, _ = _pad_to(x, s)
    chunks = flat.reshape(s, -1)
    idx = lax.axis_index(axis_name)
    perm = _shift_perm(s)

    def chunk(c):
        return lax.dynamic_index_in_dim(chunks, c % s, axis=0, keepdims=False)

    send = chunk(idx + s - 1)
    for t in range(1, s):
        recv = lax.ppermute(send, axis_name, perm)
        send = recv + chunk(idx + s - 1 - t)
    return send


def all_gather_ring(shard: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Ring all-gather: circulate this device's owned chunk ``S-1`` hops and
    return the concatenation (chunk ``i`` from device ``i``) — the device
    twin of the scheduled ``all_gather`` ring pass (DESIGN.md §11) and the
    inverse of :func:`reduce_scatter_ring`."""
    s = axis_size
    flat = shard.reshape(-1)
    if s == 1:
        return flat
    idx = lax.axis_index(axis_name)
    perm = _shift_perm(s)
    out = jnp.zeros((s, flat.shape[0]), flat.dtype)
    out = lax.dynamic_update_index_in_dim(out, flat, idx % s, axis=0)
    cur = flat
    for t in range(1, s):
        cur = lax.ppermute(cur, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, cur, (idx - t) % s, axis=0)
    return out.reshape(-1)


def broadcast_wrht_tree(x: jax.Array, axis_name: str, axis_size: int,
                        m: int = 2) -> jax.Array:
    """WRHT broadcast tree alone: device 0's value propagated to every
    device down the m-ary levels — the device twin of the scheduled
    ``broadcast`` collective (DESIGN.md §11; the scheduled root is the
    tree's surviving representative, here canonicalized to device 0)."""
    s = axis_size
    if s == 1:
        return x
    if m < 2:
        raise ValueError("m must be >= 2")
    idx = lax.axis_index(axis_name)
    strides = []
    stride = 1
    while stride < s:
        strides.append(stride)
        stride *= m
    for stride in reversed(strides):
        span = stride * m
        for j in range(1, m):
            perm = [
                (h, h + j * stride)
                for h in range(0, s, span)
                if h + j * stride < s
            ]
            if not perm:
                continue
            recv = lax.ppermute(x, axis_name, perm)
            is_member = (idx % span) == (j * stride)
            x = jnp.where(is_member, recv, x)
    return x


def alltoall_ppermute(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Single-phase personalized all-to-all: row ``j`` of the ``[S, ...]``
    input is this device's message for device ``j``; row ``i`` of the output
    is the message received from device ``i`` — the device twin of the
    scheduled one-step ``alltoall`` collective (DESIGN.md §11), expressed as
    S-1 rotation ppermutes (parallel wavelengths → parallel ICI channels).
    """
    s = axis_size
    if x.shape[0] != s:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {s}")
    if s == 1:
        return x
    idx = lax.axis_index(axis_name)
    self_msg = lax.dynamic_index_in_dim(x, idx % s, axis=0, keepdims=False)
    out = jnp.zeros_like(x)
    out = lax.dynamic_update_index_in_dim(out, self_msg, idx % s, axis=0)
    for off in range(1, s):
        msg = lax.dynamic_index_in_dim(x, (idx + off) % s, axis=0,
                                       keepdims=False)
        perm = [(i, (i + off) % s) for i in range(s)]
        recv = lax.ppermute(msg, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, recv, (idx - off) % s,
                                              axis=0)
    return out


def reduce_scatter_alltoall(x: jax.Array, axis_name: str,
                            axis_size: int) -> jax.Array:
    """Reduce-scatter via the single-step all-to-all finisher: every device
    posts its local chunk ``j`` to device ``j`` and locally reduces what it
    received.  Same ownership map as :func:`reduce_scatter_ring` (device
    ``i`` owns chunk ``i``); the optical plan trades ``S-1``
    reconfigurations for ``⌈S²/8⌉`` wavelengths (DESIGN.md §11)."""
    s = axis_size
    if s == 1:
        return x.reshape(-1)
    flat, _ = _pad_to(x, s)
    chunks = flat.reshape(s, -1)
    recv = alltoall_ppermute(chunks, axis_name, s)
    return recv.sum(axis=0)


def all_gather_alltoall(shard: jax.Array, axis_name: str,
                        axis_size: int) -> jax.Array:
    """All-gather via the single-step all-to-all finisher: every device
    posts its owned shard to every peer in one exchange.  Bit-compatible
    output with :func:`all_gather_ring`."""
    s = axis_size
    flat = shard.reshape(-1)
    if s == 1:
        return flat
    msgs = jnp.tile(flat[None], (s, 1))
    recv = alltoall_ppermute(msgs, axis_name, s)
    return recv.reshape(-1)


def allreduce_rd(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Recursive doubling: log2(S) full-vector pairwise exchanges."""
    s = axis_size
    if s & (s - 1):
        raise ValueError("recursive doubling needs a power-of-two axis")
    for k in range(int(math.log2(s))):
        bit = 1 << k
        perm = [(i, i ^ bit) for i in range(s)]
        x = x + lax.ppermute(x, axis_name, perm)
    return x


def allreduce_bt(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Binary-tree: reduce to device 0 then mirrored broadcast (the paper's
    BT baseline, Fig. 2a) — 2⌈log2 S⌉ full-vector steps."""
    return allreduce_wrht_tree(x, axis_name, axis_size, m=2, alltoall_max=1)


# ---------------------------------------------------------------------------
# the paper's contribution, ported
# ---------------------------------------------------------------------------


def allreduce_wrht_tree(
    x: jax.Array,
    axis_name: str,
    axis_size: int,
    m: int,
    alltoall_max: int | None = None,
) -> jax.Array:
    """WRHT on one device axis: hierarchical m-ary tree reduce + broadcast.

    Level ``ℓ`` groups the surviving representatives (indices ≡ 0 mod
    ``m**ℓ``) in runs of ``m``; each member sends its full partial vector to
    the group head (m-1 ppermutes = the paper's ⌈m/2⌉-wavelength parallel
    drain).  When ≤ ``alltoall_max`` representatives survive, they finish
    with a single all-to-all exchange (paper Sec. III-C: saves one broadcast
    level); otherwise recursion reaches a single root.  Broadcast mirrors the
    reduce levels.
    """
    s = axis_size
    if s == 1:
        return x
    if m < 2:
        raise ValueError("m must be >= 2")
    idx = lax.axis_index(axis_name)

    tree_strides: list[int] = []
    stride = 1
    did_alltoall = False
    while True:
        active = list(range(0, s, stride))
        if len(active) == 1:
            break
        if alltoall_max is not None and 1 < len(active) <= alltoall_max:
            # single-step all-to-all among survivors: every rep sends its
            # pre-step partial to every other rep (paper's ⌈m*²/8⌉-wavelength
            # final step).
            x0 = x
            for j in range(1, len(active)):
                perm = [
                    (active[k], active[(k + j) % len(active)])
                    for k in range(len(active))
                ]
                x = x + lax.ppermute(x0, axis_name, perm)
            did_alltoall = True
            break
        # one m-ary reduce level: members j=1..m-1 drain into group heads
        span = stride * m
        for j in range(1, m):
            perm = [
                (h + j * stride, h)
                for h in range(0, s, span)
                if h + j * stride < s
            ]
            if perm:
                x = x + lax.ppermute(x, axis_name, perm)
        tree_strides.append(stride)
        stride = span

    if not did_alltoall and not tree_strides:
        return x  # degenerate (s == 1 handled above)

    # broadcast stage: reverse the tree levels (all-to-all level, if any,
    # already left every survivor with the full reduction)
    for stride in reversed(tree_strides):
        span = stride * m
        for j in range(1, m):
            perm = [
                (h, h + j * stride)
                for h in range(0, s, span)
                if h + j * stride < s
            ]
            if not perm:
                continue
            recv = lax.ppermute(x, axis_name, perm)
            is_member = (idx % span) == (j * stride)
            x = jnp.where(is_member, recv, x)
    return x


def hierarchical_allreduce(
    x: jax.Array,
    axis_names: tuple[str, ...],
    axis_sizes: tuple[int, ...],
    mode: str = "scatter",
) -> jax.Array:
    """WRHT adapted to a factorized device mesh (production gradient sync).

    ``axis_names`` lists the mesh axes innermost-first (e.g. ``("data",
    "pod")``): level ℓ of the paper's tree = axis ℓ.  Two modes:

    - ``"faithful"``: full-vector psum per level — the paper's constant-``d``
      accounting (minimum steps, redundant bytes).
    - ``"scatter"``: reduce-scatter down the hierarchy, all-gather back up —
      WRHT's tree structure with ring's bandwidth optimality (beyond-paper
      optimization; see EXPERIMENTS.md §Perf).
    """
    if mode == "faithful":
        for ax in axis_names:
            x = lax.psum(x, ax)
        return x
    if mode == "flat":
        return lax.psum(x, axis_names)
    if mode != "scatter":
        raise ValueError(f"unknown mode {mode!r}")
    shape = x.shape
    total = math.prod(axis_sizes)
    flat, pad = _pad_to(x, total)
    for ax in axis_names:
        flat = lax.psum_scatter(flat, ax, scatter_dimension=0, tiled=True)
    for ax in reversed(axis_names):
        flat = lax.all_gather(flat, ax, axis=0, tiled=True)
    return _unpad(flat, pad, shape)


ALGORITHMS = {
    "psum": allreduce_psum,
    "ring": allreduce_ring,
    "rd": allreduce_rd,
    "bt": allreduce_bt,
    "wrht": allreduce_wrht_tree,
}


def allreduce(
    x: jax.Array,
    axis_name: str,
    axis_size: int,
    algorithm: str = "psum",
    **kw,
) -> jax.Array:
    if algorithm == "psum":
        kw = {}  # the XLA reference takes no tuning knobs
    return ALGORITHMS[algorithm](x, axis_name, axis_size, **kw)


def make_sharded_allreduce(mesh, axis_name: str, algorithm: str = "psum", **kw):
    """Build a jit-able all-reduce over one mesh axis.

    Takes a stacked input of shape ``[axis_size, ...]`` (row i = device i's
    local contribution) and returns the same shape where every row equals the
    sum — so callers/tests can express *different* per-device operands
    without lying about replication.
    """
    from jax.sharding import PartitionSpec as P

    size = mesh.shape[axis_name]
    fn = ALGORITHMS[algorithm]

    def body(stacked):  # [1, ...] local slice
        local = stacked[0]
        out = fn(local, axis_name, size, **kw)
        return out[None]

    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
        axis_names={axis_name},
    )
