"""Closed-form step counts and communication-time models (Table I, Eq. 1).

Step counts (paper Sec. III-D, Table I):

    Ring    2(N-1)
    H-Ring  2(g²+N)/g + ⌈g/w⌉ - 4          (paper [13]; see note below)
    BT      2⌈log₂N⌉  (or 2(⌈log₂N⌉+1))
    WRHT    2⌈log_m N⌉  or  2⌈log_m N⌉ - 1

NOTE on H-Ring: the paper's Table I prints 411 for (N=1000, g=5, w=64) which
equals ``2(g²+N)/g + ⌈g/w⌉`` — the ``-4`` of their own formula is not applied
in the table.  We implement the formula as printed in the text and expose the
table variant too; the benchmark reports both.

Time model: Eq. (1): ``T = θ·d/B + θ·a`` for algorithms whose every step
carries the full vector ``d`` (WRHT, BT).  Chunked ring-style algorithms carry
``d/N`` (or ``d/g``) per step; the per-algorithm functions below spell out the
byte terms explicitly so each matches its transfer schedule.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .topology import PhysicalParams


def ring_steps(n: int) -> int:
    return 2 * (n - 1)


def hring_steps(n: int, g: int, w: int, table_variant: bool = False) -> int:
    base = 2 * (g * g + n) / g + math.ceil(g / w)
    return math.ceil(base) if table_variant else math.ceil(base) - 4


def bt_steps(n: int, plus_one: bool = False) -> int:
    l = math.ceil(math.log2(n))
    return 2 * (l + 1) if plus_one else 2 * l


def rd_steps(n: int) -> int:
    """Recursive doubling all-reduce: ⌈log₂N⌉ full-vector exchange steps."""
    return math.ceil(math.log2(n))


def wrht_steps(n: int, m: int, with_alltoall: bool = True) -> int:
    if n <= 1:
        return 0
    l = max(1, math.ceil(math.log(n, m)))
    return 2 * l - 1 if with_alltoall else 2 * l


@dataclass(frozen=True)
class OpticalParams:
    """Table II, optical side, plus the physical-layer / timing knobs.

    ``physical`` enables the insertion-loss constraint (Sec. III): schedules
    are built under the hop budget ``physical.max_hops`` and the simulator
    adds per-hop propagation delay.  ``timing`` selects the simulator
    engine: ``"lockstep"`` (per-step max, the golden upper bound),
    ``"event"`` (per-transfer finish times, global step barrier — equals
    lockstep by construction) or ``"overlap"`` (SWOT-style: a node retunes
    its MRRs for the next step while other nodes' tail transfers of the
    current step are still in flight).
    """

    bandwidth_bps: float = 40e9     # per wavelength
    reconfig_delay_s: float = 25e-6  # MRR reconfiguration per step (the α term)
    wavelengths: int = 64
    physical: PhysicalParams | None = None
    timing: str = "lockstep"

    @staticmethod
    def from_cost(alpha_s: float, link_bw_Bps: float, links: int,
                  physical: PhysicalParams | None = None,
                  timing: str = "lockstep") -> "OpticalParams":
        """Map the planner's α–β ``CostParams`` onto the optical simulator.

        The α term is the per-step MRR reconfiguration delay, the per-link
        byte rate becomes the per-wavelength bit rate, and the ``links``
        concurrent channels split across the two fiber directions
        (``CostParams.optical(w)`` uses ``links = 2w``, so this mapping is
        its exact inverse).  Lets ``planner.plan_bucket(backend="simulated")``
        cost the same candidate schedules with the flit-level simulator
        instead of the closed forms.
        """
        return OpticalParams(
            bandwidth_bps=link_bw_Bps * 8,
            reconfig_delay_s=alpha_s,
            wavelengths=max(1, links // 2),
            physical=physical,
            timing=timing,
        )


def max_feasible_m(p: OpticalParams) -> int:
    """Largest WRHT group size under both Lemma 1 and the insertion-loss
    fan-out cap (``2·max_hops + 1``, see ``PhysicalParams.fan_out_cap``)."""
    m = 2 * p.wavelengths + 1
    if p.physical is not None:
        m = min(m, p.physical.fan_out_cap)
    return m


@dataclass(frozen=True)
class ElectricalParams:
    """Table II, electrical side (fat-tree)."""

    bandwidth_bps: float = 25e9
    router_delay_s: float = 50e-6
    radix: int = 32                  # 32-port routers, two-level clos


# ---------------------------------------------------------------------------
# Analytic communication times on the OPTICAL ring (used by fig4 benchmark
# alongside the event simulator; the simulator adds flit/O-E-O effects).
# ---------------------------------------------------------------------------

def t_wrht(n: int, d_bits: float, p: OpticalParams, m: int | None = None,
           with_alltoall: bool = False) -> float:
    """Eq. (1): every step moves the full vector d.  The default group size
    honours the insertion-loss fan-out cap when ``p.physical`` is set."""
    m = m if m is not None else max_feasible_m(p)
    theta = wrht_steps(n, m, with_alltoall)
    return theta * d_bits / p.bandwidth_bps + theta * p.reconfig_delay_s


def t_ring_optical(n: int, d_bits: float, p: OpticalParams) -> float:
    """Bandwidth-optimal ring: 2(N-1) steps of d/N on neighbour segments."""
    theta = ring_steps(n)
    return theta * (d_bits / n) / p.bandwidth_bps + theta * p.reconfig_delay_s


def t_bt_optical(n: int, d_bits: float, p: OpticalParams) -> float:
    """Binary tree: every step moves the full vector d."""
    theta = bt_steps(n)
    return theta * d_bits / p.bandwidth_bps + theta * p.reconfig_delay_s


def t_hring_optical(n: int, d_bits: float, p: OpticalParams, g: int = 5) -> float:
    """Hierarchical ring [13]: intra-group ring (chunks d/g) + inter-group
    ring among N/g representatives (chunks d/(N/g)) + intra all-gather.
    Step count follows the paper's formula; byte term from the decomposition.
    """
    n_groups = max(1, n // g)
    theta = hring_steps(n, g, p.wavelengths)
    intra_steps = 2 * (g - 1)
    inter_steps = 2 * (n_groups - 1)
    bytes_term = (
        intra_steps * (d_bits / g) + inter_steps * (d_bits / max(1, n_groups))
    ) / p.bandwidth_bps
    return bytes_term + theta * p.reconfig_delay_s


# ---------------------------------------------------------------------------
# Electrical fat-tree (fig5): E-Ring and Recursive Doubling, SimGrid-style
# analytic latency = routers-on-path × router_delay + serialization.
# ---------------------------------------------------------------------------

def _fattree_hops(src: int, dst: int, p: ElectricalParams) -> int:
    """Routers traversed in a two-level fat-tree of 32-port edge routers."""
    if src == dst:
        return 0
    return 1 if src // p.radix == dst // p.radix else 3  # edge / edge-core-edge


def t_ring_electrical(n: int, d_bits: float, p: ElectricalParams) -> float:
    """E-Ring: 2(N-1) steps; neighbour (i, i+1) is same-edge except at
    32-node boundaries — per-step latency is the max over concurrent sends,
    which includes one boundary pair (3 router hops) whenever n > radix."""
    theta = ring_steps(n)
    hops = 3 if n > p.radix else 1
    per_step = (d_bits / n) / p.bandwidth_bps + hops * p.router_delay_s
    return theta * per_step


def t_rd_electrical(n: int, d_bits: float, p: ElectricalParams) -> float:
    """Recursive doubling: ⌈log₂N⌉ steps of full-vector pairwise exchange;
    partners at distance 2^i cross the core once 2^i >= radix."""
    total = 0.0
    for i in range(rd_steps(n)):
        hops = 1 if 2**i < p.radix else 3
        total += d_bits / p.bandwidth_bps + hops * p.router_delay_s
    return total


# Convenience: the four DNN models used in the paper's evaluation, gradient
# payload in bits (fp32 parameters, Sec. IV-A).
PAPER_MODELS_BITS: dict[str, float] = {
    "AlexNet": 62.3e6 * 32,
    "VGG16": 138e6 * 32,
    "ResNet50": 25e6 * 32,
    "GoogLeNet": 6.7977e6 * 32,
}
