"""Pipelined multi-collective overlap composer (DESIGN.md §13).

A single WRHT collective is internally serial — every step waits for the
previous one — so ``timing="overlap"`` measures ≈0 gain on homogeneous
schedules (EXPERIMENTS.md §Perf).  The SWOT-style win comes from running
*different* collectives concurrently on one ring: bucket ``k+1``'s
reduce-scatter under bucket ``k``'s all-gather, or a broadcast prefetch
under a reduce-scatter.  This module composes ``k`` independently-built
collective schedules onto one ring:

* **Fused RWA**: at each composed slot the pending steps' transfers are
  concatenated into one union :class:`TransferBatch` and re-assigned by
  :func:`~repro.core.wavelength.first_fit_assign` (same λ budget ``w``,
  same hop budget, same failure mask), so concurrent collectives share the
  wavelength budget without conflicts.
* **Serialization fallback**: a pending step that cannot co-exist with the
  slot's union — :class:`WavelengthConflictError`,
  :class:`InsertionLossError` or :class:`FailedResourceError` from the
  fused assignment — simply waits; its constituent emits in a later slot
  (alone at worst, reusing its original already-assigned batch, so a
  depth-1 composition is bit-identical to the uncomposed schedule).
* **Constituent views**: each input schedule's steps appear in order,
  exactly once, with identical src/dst/direction/bits/chunks (only the
  wavelength assignment may differ on fused slots), so every constituent
  still satisfies its own per-collective semantic oracle
  (``tests/test_collective_conformance.py``) after interleaving.

The composed step list feeds the unchanged timing engines
(``ScheduleProfile.from_composed``, ``simulator.simulate_composed``): the
overlap recurrence then legitimately hides one constituent's
reconfiguration under another's communication — the gain a homogeneous
schedule cannot show.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from . import wrht
from .topology import FailureMask, TransferBatch
from .wavelength import (
    FailedResourceError,
    InsertionLossError,
    WavelengthConflictError,
    first_fit_assign,
    validate_no_conflicts,
)

# the uniform "this step pair cannot co-exist" signal of the fused RWA —
# anything else is a real bug and propagates
_RWA_ERRORS = (WavelengthConflictError, InsertionLossError,
               FailedResourceError)

# pipelined gradient sync alternates the two sharded-sync phases: bucket
# k+1's reduce-scatter runs under bucket k's all-gather.  Collectives with
# no natural partner pipeline against themselves (broadcast prefetch etc.).
PIPELINE_PARTNER = {"reduce_scatter": "all_gather",
                    "all_gather": "reduce_scatter"}


@dataclass(frozen=True)
class ComposedPart:
    """One constituent step's rows inside a composed slot."""

    constituent: int               # index into ComposedSchedule.schedules
    step: int                      # step index within that constituent
    lo: int                        # rows [lo, hi) of the slot's fused batch
    hi: int


@dataclass
class ComposedStep:
    """One slot of the composed timeline: a (possibly fused) batch plus the
    bookkeeping mapping its rows back to constituent steps."""

    transfers: TransferBatch
    parts: tuple[ComposedPart, ...]

    @property
    def fused(self) -> bool:
        return len(self.parts) > 1


@dataclass
class ComposedSchedule:
    """``k`` collective schedules interleaved onto one ring."""

    n: int
    w: int
    schedules: tuple[wrht.WRHTSchedule, ...]
    steps: list[ComposedStep]
    max_hops: int | None = None
    failures: FailureMask | None = None

    @property
    def depth(self) -> int:
        return len(self.schedules)

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    @property
    def fused_steps(self) -> int:
        """Slots carrying ≥ 2 constituents concurrently."""
        return sum(1 for s in self.steps if s.fused)

    @property
    def serial_steps(self) -> int:
        """Slot count of the serial execution (sum of constituent steps)."""
        return sum(len(s.steps) for s in self.schedules)

    @property
    def slots_saved(self) -> int:
        """Reconfigurations the fusion removed vs serial execution."""
        return self.serial_steps - self.num_steps

    @property
    def fusion_efficiency(self) -> float:
        """Fraction of the theoretically removable slots the greedy fusion
        actually removed, in ``[0, 1]``.  A depth-``k`` composition can at
        best shrink ``serial_steps`` down to the longest constituent, so the
        denominator is ``serial_steps - max_j len(schedules[j].steps)``;
        ``1.0`` means perfect interleaving, ``0.0`` full serialization —
        the storm harness (DESIGN.md §14) watches this decay as a shrinking
        λ pool forces the fallback."""
        longest = max(len(s.steps) for s in self.schedules)
        removable = self.serial_steps - longest
        if removable <= 0:
            return 1.0
        return self.slots_saved / removable

    # -- constituent views ------------------------------------------------

    def part_step(self, slot: int, part: ComposedPart) -> wrht.Step:
        """Materialize one part as a :class:`wrht.Step` of its constituent.

        Single-part slots return the constituent's original Step object
        (batch identity preserved — this is what makes depth-1 composition
        bit-identical); fused slots slice the part's rows out of the fused
        batch, keeping the original kind/level/chunks.
        """
        cs = self.steps[slot]
        orig = self.schedules[part.constituent].steps[part.step]
        if not cs.fused:
            return orig
        b = cs.transfers
        lo, hi = part.lo, part.hi
        sub = TransferBatch(b.src[lo:hi], b.dst[lo:hi], b.direction[lo:hi],
                            b.bits[lo:hi], b.wavelength[lo:hi])
        return wrht.Step(orig.kind, orig.level, sub, chunks=orig.chunks)

    def constituent_steps(self, j: int) -> list[wrht.Step]:
        """Constituent ``j``'s steps in composed order (wavelengths as the
        fused assignment left them; src/dst/chunks untouched)."""
        out = []
        for slot, cs in enumerate(self.steps):
            for part in cs.parts:
                if part.constituent == j:
                    out.append(self.part_step(slot, part))
        return out

    def constituent_view(self, j: int) -> wrht.WRHTSchedule:
        """Constituent ``j`` as a standalone :class:`WRHTSchedule` whose
        steps are the composed-order materialization — the object the
        per-collective semantic oracles run against."""
        return replace(self.schedules[j], steps=self.constituent_steps(j))

    def as_steps(self) -> list[wrht.Step]:
        """The fused timeline as plain steps for the timing engines.

        ``kind="composed"`` marks fused slots; single-part slots keep the
        constituent's original Step object so the profile compiler's
        segment dedup (keyed on batch identity) still collapses a ring
        pass's shared batch.
        """
        out = []
        for slot, cs in enumerate(self.steps):
            if not cs.fused:
                out.append(self.part_step(slot, cs.parts[0]))
            else:
                out.append(wrht.Step("composed", 0, cs.transfers))
        return out


def _fuse(batches: list[TransferBatch], n: int, w: int,
          max_hops: int | None, failures: FailureMask | None,
          cache: dict | None) -> TransferBatch:
    """First-Fit RWA over the union of concurrent step batches.

    Raises the usual RWA errors when the union does not fit under ``w``,
    the hop budget or the failure mask — the caller's serialization
    fallback.  Memoized on the batch identities: a pipelined ring pass
    re-fuses the same pair of shared batches every slot, and returning the
    same fused object lets the profile compiler dedup the segment.
    """
    key = tuple(id(b) for b in batches)
    if cache is not None and key in cache:
        return cache[key]
    cat, _ = wrht._concat_batches(batches)
    fused = first_fit_assign(cat, n, w, max_hops=max_hops,
                             failures=failures)
    if cache is not None:
        cache[key] = fused
    return fused


def compose_schedules(
    schedules: "list[wrht.WRHTSchedule] | tuple[wrht.WRHTSchedule, ...]",
    offsets: "tuple[int, ...] | None" = None,
    max_hops: int | None = None,
) -> ComposedSchedule:
    """Interleave ``k`` collective schedules onto one ring.

    Greedy slot fusion with per-constituent cursors: each slot starts from
    the first constituent with a pending step, then tries to add every
    other pending step via the fused RWA over the union batch; a step that
    cannot co-exist waits for a later slot (serialization fallback).  Each
    constituent's steps retain their relative order, so constituent
    semantics are preserved by construction.

    ``offsets`` staggers constituent start slots (default: all start at
    slot 0 — the steady state of a bucket pipeline).  ``max_hops`` bounds
    fused lightpaths; it defaults to the tightest constituent budget.  All
    constituents must share one ring (``n``, ``w``) and one failure mask.
    """
    schedules = tuple(schedules)
    if not schedules:
        raise ValueError("need at least one schedule to compose")
    n, w = schedules[0].n, schedules[0].w
    for s in schedules:
        if (s.n, s.w) != (n, w):
            raise ValueError(
                f"constituents must share one ring: ({s.n}, {s.w}) != "
                f"({n}, {w})")
    masks = {s.failures if (s.failures and not s.failures.empty) else None
             for s in schedules}
    if len(masks) > 1:
        raise ValueError("constituents must share one failure mask")
    failures = masks.pop()
    hop_budgets = [s.max_hops for s in schedules if s.max_hops is not None]
    if max_hops is None and hop_budgets:
        max_hops = min(hop_budgets)

    k = len(schedules)
    if offsets is None:
        offsets = (0,) * k
    if len(offsets) != k or any(o < 0 for o in offsets):
        raise ValueError("offsets must give one slot >= 0 per constituent")
    base = min(offsets)
    offsets = tuple(o - base for o in offsets)

    cursors = [0] * k
    lens = [len(s.steps) for s in schedules]
    fuse_cache: dict = {}
    steps: list[ComposedStep] = []
    slot = 0
    while any(c < L for c, L in zip(cursors, lens)):
        ready = [j for j in range(k)
                 if cursors[j] < lens[j] and slot >= offsets[j]]
        if not ready:
            # every pending constituent is staggered past this slot; the
            # clock advances without emitting (nothing reconfigures)
            slot += 1
            continue
        j0 = ready[0]
        taken = [j0]
        batches = [schedules[j0].steps[cursors[j0]].transfers]
        fused: TransferBatch | None = None
        for j in ready[1:]:
            trial = batches + [schedules[j].steps[cursors[j]].transfers]
            try:
                cand = _fuse(trial, n, w, max_hops, failures, fuse_cache)
            except _RWA_ERRORS:
                continue                    # j waits — serialization fallback
            batches = trial
            fused = cand
            taken.append(j)
        ptr = np.zeros(len(batches) + 1, dtype=np.int64)
        np.cumsum([len(b) for b in batches], out=ptr[1:])
        parts = tuple(
            ComposedPart(j, cursors[j], int(ptr[i]), int(ptr[i + 1]))
            for i, j in enumerate(taken))
        steps.append(ComposedStep(fused if fused is not None else batches[0],
                                  parts))
        for j in taken:
            cursors[j] += 1
        slot += 1
    return ComposedSchedule(n=n, w=w, schedules=schedules, steps=steps,
                            max_hops=max_hops, failures=failures)


def pipeline_collectives(collective: str, depth: int) -> tuple[str, ...]:
    """The constituent sequence of a depth-``k`` pipeline starting with
    ``collective``: alternating with its partner phase (RS↔AG), or ``k``
    copies for partnerless collectives."""
    first = wrht.coerce_collective(collective)
    partner = PIPELINE_PARTNER.get(first, first)
    return tuple(first if j % 2 == 0 else partner for j in range(depth))


def build_pipeline_schedule(
    collective: str,
    n: int,
    w: int,
    d_bits: float,
    depth: int,
    m: int | None = None,
    allow_alltoall: bool = True,
    max_hops: int | None = None,
    rwa: str = "fast",
    failures: FailureMask | None = None,
    validate: bool = False,
    offsets: "tuple[int, ...] | None" = None,
) -> ComposedSchedule:
    """Build and compose the depth-``k`` pipeline of ``collective`` (the
    ``planned_pipelined`` traffic shape — successive buckets' alternating
    RS/AG phases concurrent on one ring).  All constituents are built at
    the same ``d_bits`` (the plan cache uses the d-independent ``d=1``
    structure; heterogeneous bucket payloads time through per-class grids
    downstream)."""
    if depth < 1:
        raise ValueError("pipeline depth must be >= 1")
    scheds = [
        wrht.build_collective_schedule(
            c, n, w, d_bits, m=m, allow_alltoall=allow_alltoall,
            validate=validate, rwa=rwa, max_hops=max_hops, failures=failures)
        for c in pipeline_collectives(collective, depth)
    ]
    return compose_schedules(scheds, offsets=offsets, max_hops=max_hops)


def validate_composed(composed: ComposedSchedule) -> None:
    """Structural validation of a composed schedule.

    Fused slots are checked for wavelength-conflict freedom under the
    composed hop budget and failure mask (:func:`validate_no_conflicts` on
    the fused batch — the negative the differential tests exercise);
    single-part slots are checked under their own constituent's budget
    (a constituent with a laxer hop budget than the composed minimum is
    legal while it runs alone).  Constituent *semantics* are validated via
    :meth:`ComposedSchedule.constituent_view` +
    :func:`wrht.validate_schedule`.
    """
    for cs in composed.steps:
        if cs.fused:
            validate_no_conflicts(cs.transfers, composed.n, composed.w,
                                  max_hops=composed.max_hops,
                                  failures=composed.failures)
        else:
            own = composed.schedules[cs.parts[0].constituent]
            validate_no_conflicts(cs.transfers, composed.n, composed.w,
                                  max_hops=own.max_hops,
                                  failures=composed.failures)
    for j in range(composed.depth):
        # every constituent step must appear exactly once, in order
        seen = [p.step for cs in composed.steps for p in cs.parts
                if p.constituent == j]
        if seen != list(range(len(composed.schedules[j].steps))):
            raise AssertionError(
                f"constituent {j} steps out of order or dropped: {seen}")
