"""Optical ring topology model (TeraRack-style).

The physical substrate of the paper: ``N`` nodes on a bidirectional WDM ring.
Each direction is an independent fiber ring carrying ``w`` wavelengths; a
directed transfer from ``src`` to ``dst`` occupies every unit *segment*
(i, i+1 mod N) (clockwise) or (i, i-1 mod N) (counter-clockwise) along its
path, on one wavelength.  Two transfers conflict iff they share a directed
segment *and* a wavelength.

This module is pure Python/NumPy — it backs the schedule builder, the RWA
(routing and wavelength assignment) pass and the optical simulator.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Iterable, Iterator

import numpy as np

CW = +1   # clockwise
CCW = -1  # counter-clockwise


def _canonical_pairs(pairs) -> tuple[tuple[int, int], ...]:
    """Sorted, deduplicated ``(int, int)`` tuples — one canonical form per
    logical set, so equal masks hash and fingerprint identically."""
    return tuple(sorted({(int(a), int(b)) for a, b in pairs}))


@dataclass(frozen=True)
class FailureMask:
    """Which optical resources of the ring are dead (DESIGN.md §12).

    Three independent failure classes, each a canonical sorted tuple so the
    mask is hashable (plan-cache keys carry it directly) and two masks
    describing the same failures compare — and fingerprint — equal:

    ``dead_segments``      ``(lane, segment)`` pairs: the directed fiber
                           span is cut.  Lane 0 is the CW fiber, lane 1 the
                           CCW fiber (the :meth:`TransferBatch.arcs`
                           convention); segment ids are the ones
                           :func:`path_segments` yields.  No lightpath on
                           that lane may cover the segment.
    ``dead_wavelengths``   ``(node, λ)`` pairs: the node's MRR add/drop bank
                           for wavelength λ is dead, so no transfer may be
                           *added or dropped* at that node on λ (transfers
                           passing through optically are unaffected).
    ``dead_transceivers``  ``(node, lane)`` pairs: the node's Tx/Rx set on
                           that fiber direction is dead — it can neither
                           transmit nor receive on the lane (and cannot act
                           as an O/E/O relay there).

    An empty mask is semantically "healthy" everywhere; builders and
    validators treat ``failures=None`` and an empty mask identically.
    """

    dead_segments: tuple[tuple[int, int], ...] = ()
    dead_wavelengths: tuple[tuple[int, int], ...] = ()
    dead_transceivers: tuple[tuple[int, int], ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "dead_segments",
                           _canonical_pairs(self.dead_segments))
        object.__setattr__(self, "dead_wavelengths",
                           _canonical_pairs(self.dead_wavelengths))
        object.__setattr__(self, "dead_transceivers",
                           _canonical_pairs(self.dead_transceivers))
        for lane, _ in self.dead_segments:
            if lane not in (0, 1):
                raise ValueError(f"dead segment lane must be 0/1, got {lane}")
        for _, lane in self.dead_transceivers:
            if lane not in (0, 1):
                raise ValueError(f"dead transceiver lane must be 0/1, got {lane}")

    # -------------------------------------------------- identity
    @property
    def empty(self) -> bool:
        return not (self.dead_segments or self.dead_wavelengths
                    or self.dead_transceivers)

    def fingerprint(self) -> str:
        """Canonical short hash of the mask — the plan-cache key/filename
        stamp (DESIGN.md §12).  ``"ok"`` for the healthy (empty) mask."""
        if self.empty:
            return "ok"
        payload = repr((self.dead_segments, self.dead_wavelengths,
                        self.dead_transceivers)).encode()
        return hashlib.sha256(payload).hexdigest()[:12]

    def to_lists(self) -> dict:
        """JSON-able view (plan-cache artifact metadata)."""
        return {
            "dead_segments": [list(p) for p in self.dead_segments],
            "dead_wavelengths": [list(p) for p in self.dead_wavelengths],
            "dead_transceivers": [list(p) for p in self.dead_transceivers],
        }

    @classmethod
    def from_lists(cls, d: dict) -> "FailureMask":
        return cls(
            dead_segments=tuple(map(tuple, d.get("dead_segments", ()))),
            dead_wavelengths=tuple(map(tuple, d.get("dead_wavelengths", ()))),
            dead_transceivers=tuple(map(tuple, d.get("dead_transceivers", ()))),
        )

    # -------------------------------------------------- array views
    def segment_dead(self, n: int) -> np.ndarray:
        """Bool ``[2, n]``: ``[lane, seg]`` is True iff the span is cut."""
        out = np.zeros((2, n), dtype=bool)
        for lane, seg in self.dead_segments:
            out[lane, seg % n] = True
        return out

    def transceiver_dead(self, n: int) -> np.ndarray:
        """Bool ``[n, 2]``: ``[node, lane]`` is True iff the Tx/Rx is dead."""
        out = np.zeros((n, 2), dtype=bool)
        for node, lane in self.dead_transceivers:
            out[node % n, lane] = True
        return out

    def forbidden_lambda_bits(self, n: int) -> list[int]:
        """Per-node forbidden-wavelength bitmask (arbitrary-precision Python
        ints, so ``w > 64`` works): a dead λ at a node forbids adding or
        dropping that λ there."""
        out = [0] * n
        for node, lam in self.dead_wavelengths:
            if lam >= 0:
                out[node % n] |= 1 << lam
        return out

    def max_dead_lambda_per_node(self) -> int:
        """Largest count of dead wavelengths at any single node — the
        conservative shrink applied to the Lemma-1 group size
        (:func:`repro.core.wrht.feasible_group_size`)."""
        counts: dict[int, int] = {}
        for node, _ in self.dead_wavelengths:
            counts[node] = counts.get(node, 0) + 1
        return max(counts.values(), default=0)

    def union(self, other: "FailureMask") -> "FailureMask":
        """The mask with every failure of both operands — cumulative
        degradation (DESIGN.md §14).  Canonicalization makes the result
        order-independent: ``a.union(b) == b.union(a)``."""
        return FailureMask(
            dead_segments=self.dead_segments + other.dead_segments,
            dead_wavelengths=self.dead_wavelengths + other.dead_wavelengths,
            dead_transceivers=(self.dead_transceivers
                               + other.dead_transceivers),
        )

    def covers(self, other: "FailureMask") -> bool:
        """True iff every failure of ``other`` is also in this mask — the
        nesting relation the storm harness escalates along."""
        return (set(other.dead_segments) <= set(self.dead_segments)
                and set(other.dead_wavelengths) <= set(self.dead_wavelengths)
                and (set(other.dead_transceivers)
                     <= set(self.dead_transceivers)))

    def disconnects(self, n: int) -> bool:
        """True iff the mask provably severs the ring for all-pairs traffic
        (DESIGN.md §14) — either some node lost its transceivers on *both*
        fibers (it can no longer receive at all), or the segment cuts leave
        the unit-step routing graph not strongly connected.

        Every lightpath — including the degraded builders' O/E/O detours —
        decomposes into unit segments, and a cut span blocks any lightpath
        covering it regardless of wavelengths or transceivers, so failing
        this check is a *sound* infeasibility certificate: the analytic
        planner uses it to raise the uniform
        :class:`~repro.core.wrht.DegradedInfeasibleError` instead of
        costing a fabric no schedule can use.  (Transceiver and λ failures
        other than the total-node case are deliberately NOT folded into the
        graph: pass-through traffic needs neither, so doing so would flag
        feasible rings.)
        """
        tdead = {}
        for node, lane in self.dead_transceivers:
            tdead.setdefault(node % n, set()).add(lane)
        if any(len(lanes) == 2 for lanes in tdead.values()):
            return True
        if not self.dead_segments:
            return False
        dead = self.segment_dead(n)
        cw_ok, ccw_ok = ~dead[0], ~dead[1]
        if cw_ok.all() or ccw_ok.all():
            return False  # one intact fiber ring reaches everyone
        # strong connectivity of the 2n-edge unit-step graph: node u reaches
        # u+1 over CW segment u, and u-1 over CCW segment u-1.  The ring is
        # usable iff node 0 reaches everyone and everyone reaches node 0.
        for forward in (True, False):
            seen = np.zeros(n, dtype=bool)
            seen[0] = True
            frontier = [0]
            while frontier:
                u = frontier.pop()
                cw_next = (u + 1) % n if forward else (u - 1) % n
                cw_seg = u if forward else cw_next
                if cw_ok[cw_seg] and not seen[cw_next]:
                    seen[cw_next] = True
                    frontier.append(cw_next)
                ccw_next = (u - 1) % n if forward else (u + 1) % n
                ccw_seg = ccw_next if forward else u
                if ccw_ok[ccw_seg] and not seen[ccw_next]:
                    seen[ccw_next] = True
                    frontier.append(ccw_next)
            if not seen.all():
                return True
        return False


# ---------------------------------------------------------------------------
# Transient (flapping) faults: per-resource up/down schedules over training
# steps, the ground truth the closed fault-management loop observes
# (DESIGN.md §14).
# ---------------------------------------------------------------------------

FAULT_KINDS = ("segment", "wavelength", "transceiver")


@dataclass(frozen=True)
class ResourceObservation:
    """One per-resource health sample: per-λ/per-span error or ok telemetry
    the simulator emits and the :class:`~repro.runtime.fault_tolerance.
    HealthMonitor` consumes (DESIGN.md §14).  ``ident`` follows the
    :class:`FailureMask` conventions for the kind: ``(lane, segment)`` /
    ``(node, λ)`` / ``(node, lane)``."""

    step: int
    kind: str
    ident: tuple[int, int]
    ok: bool

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown resource kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")
        object.__setattr__(self, "ident",
                           (int(self.ident[0]), int(self.ident[1])))


@dataclass(frozen=True)
class FlapSchedule:
    """Up/down timetable of ONE optical resource.

    Two specification forms, combinable (a step is down if either says so):

    * ``down_intervals`` — explicit half-open ``[lo, hi)`` step intervals
      (a permanent fault is ``(t, FOREVER)``, see :meth:`permanent`);
    * ``up_steps``/``down_steps``/``phase`` — periodic flapping: starting
      at ``phase`` the resource repeats ``up_steps`` healthy steps followed
      by ``down_steps`` dead ones (the flapping-λ model of DESIGN.md §14).

    ``kind``/``ident`` follow the :class:`FailureMask` conventions
    (``segment`` → ``(lane, segment)``, ``wavelength`` → ``(node, λ)``,
    ``transceiver`` → ``(node, lane)``).
    """

    kind: str
    ident: tuple[int, int]
    down_intervals: tuple[tuple[int, int], ...] = ()
    up_steps: int = 0
    down_steps: int = 0
    phase: int = 0

    FOREVER = 1 << 62

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown resource kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")
        object.__setattr__(self, "ident",
                           (int(self.ident[0]), int(self.ident[1])))
        object.__setattr__(
            self, "down_intervals",
            tuple(sorted((int(lo), int(hi))
                         for lo, hi in self.down_intervals)))
        for lo, hi in self.down_intervals:
            if hi <= lo:
                raise ValueError(f"empty down interval [{lo}, {hi})")
        if (self.up_steps > 0) != (self.down_steps > 0):
            raise ValueError("periodic flapping needs both up_steps and "
                             "down_steps > 0 (or neither)")
        if not self.down_intervals and not self.up_steps:
            raise ValueError("flap schedule is never down — specify "
                             "down_intervals or up_steps/down_steps")

    @classmethod
    def permanent(cls, kind: str, ident, at: int = 0) -> "FlapSchedule":
        """A hard fault: down from step ``at`` onwards, never healing."""
        return cls(kind, tuple(ident), down_intervals=((at, cls.FOREVER),))

    @classmethod
    def periodic(cls, kind: str, ident, up_steps: int, down_steps: int,
                 phase: int = 0) -> "FlapSchedule":
        """A flapping fault: ``up_steps`` healthy / ``down_steps`` dead,
        repeating from ``phase``."""
        return cls(kind, tuple(ident), up_steps=up_steps,
                   down_steps=down_steps, phase=phase)

    def is_down(self, step: int) -> bool:
        for lo, hi in self.down_intervals:
            if lo <= step < hi:
                return True
        if self.up_steps:
            period = self.up_steps + self.down_steps
            return (step - self.phase) % period >= self.up_steps
        return False

    def transitions(self, lo: int, hi: int) -> int:
        """Number of up↔down edges of this resource in steps ``(lo, hi]``."""
        return sum(self.is_down(t) != self.is_down(t - 1)
                   for t in range(lo + 1, hi + 1))


@dataclass(frozen=True)
class FaultTimeline:
    """The ground-truth fault state of a ring over training steps: a set of
    per-resource :class:`FlapSchedule` timetables (DESIGN.md §14).

    ``mask_at(step)`` materializes the instantaneous
    :class:`FailureMask`; the closed-loop tests compare the
    :class:`~repro.runtime.fault_tolerance.FaultManager`'s bounded replan
    count against :meth:`transitions` — the replans a naive
    one-per-transition policy would perform.
    """

    flaps: tuple[FlapSchedule, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "flaps", tuple(self.flaps))
        seen = set()
        for f in self.flaps:
            if not isinstance(f, FlapSchedule):
                raise TypeError(f"FaultTimeline entries must be "
                                f"FlapSchedule, got {type(f).__name__}")
            if (f.kind, f.ident) in seen:
                raise ValueError(f"duplicate flap schedule for "
                                 f"{(f.kind, f.ident)}")
            seen.add((f.kind, f.ident))

    def mask_at(self, step: int) -> FailureMask:
        """The instantaneous failure mask at ``step`` (empty = healthy)."""
        segs, lams, txs = [], [], []
        for f in self.flaps:
            if f.is_down(step):
                {"segment": segs, "wavelength": lams,
                 "transceiver": txs}[f.kind].append(f.ident)
        return FailureMask(dead_segments=tuple(segs),
                           dead_wavelengths=tuple(lams),
                           dead_transceivers=tuple(txs))

    def transitions(self, lo: int, hi: int) -> int:
        """Total per-resource up↔down edges in steps ``(lo, hi]`` — the
        replan count of a naive one-replan-per-transition policy."""
        return sum(f.transitions(lo, hi) for f in self.flaps)


@dataclass(frozen=True)
class PhysicalParams:
    """Optical power budget of one lightpath (paper Sec. III, insertion loss;
    DESIGN.md §6 describes the layered enforcement).

    A signal leaves the laser at ``laser_power_dbm``, loses a fixed
    ``coupling_loss_db`` entering/leaving the fiber, and loses
    ``insertion_loss_db_per_hop`` at every node it passes through (each hop
    traverses one node's MRR add/drop bank).  The path is feasible iff the
    power arriving at the receiver stays at or above
    ``receiver_sensitivity_dbm``:

        laser - coupling - hops * per_hop  >=  sensitivity

    which yields the *hop budget* :attr:`max_hops` — the insertion-loss
    constraint the paper's analysis applies to WRHT group sizes (a
    representative can only drain members whose lightpaths fit the budget).
    Wavelength routing treats the budget per directed lightpath; paths longer
    than the budget must be O/E/O-regenerated at a relay node
    (:func:`repro.core.wavelength.split_overlong_arcs`).

    ``propagation_s_per_hop`` is the time of flight across one unit segment
    (~5 ns for a metre of fiber); the event-timed simulator adds it to each
    transfer's receive-side finish time, so distant receivers genuinely
    finish later than near ones.  Defaults give a 32 dB budget and a 64-hop
    reach.
    """

    laser_power_dbm: float = 10.0
    receiver_sensitivity_dbm: float = -26.0
    coupling_loss_db: float = 4.0
    insertion_loss_db_per_hop: float = 0.5
    propagation_s_per_hop: float = 5e-9

    def __post_init__(self) -> None:
        if self.insertion_loss_db_per_hop < 0:
            raise ValueError("insertion loss must be >= 0 dB/hop")
        if self.power_budget_db < self.insertion_loss_db_per_hop:
            raise ValueError(
                f"power budget {self.power_budget_db:.1f} dB cannot cover a "
                "single hop — no lightpath is feasible"
            )

    @property
    def power_budget_db(self) -> float:
        """dB available for per-hop insertion loss."""
        return (self.laser_power_dbm - self.receiver_sensitivity_dbm
                - self.coupling_loss_db)

    @property
    def max_hops(self) -> int:
        """Largest number of unit segments one lightpath may traverse."""
        if self.insertion_loss_db_per_hop == 0:
            return int(1e18)  # lossless: effectively unbounded
        return int(self.power_budget_db // self.insertion_loss_db_per_hop)

    @property
    def fan_out_cap(self) -> int:
        """Largest WRHT group size on a unit-spaced ring: the representative
        sits in the middle, so the farthest member is ``⌈(m-1)/2⌉`` hops away
        and ``m = 2·max_hops + 1`` is the limit (insertion-loss Lemma-1 cap)."""
        return 2 * self.max_hops + 1

    def feasible(self, hops) -> np.ndarray:
        """Vectorized feasibility of per-transfer hop counts."""
        return np.asarray(hops) <= self.max_hops


@dataclass(frozen=True)
class Transfer:
    """One directed optical transmission within a communication step."""

    src: int
    dst: int
    direction: int          # CW or CCW
    bits: float             # payload size in bits
    wavelength: int = -1    # assigned by RWA; -1 = unassigned

    def __post_init__(self) -> None:
        if self.direction not in (CW, CCW):
            raise ValueError(f"direction must be +1/-1, got {self.direction}")
        if self.src == self.dst:
            raise ValueError("transfer src == dst")


def ring_distance(src: int, dst: int, n: int, direction: int) -> int:
    """Number of unit segments traversed from src to dst going `direction`."""
    if direction == CW:
        return (dst - src) % n
    return (src - dst) % n


def shortest_direction(src: int, dst: int, n: int) -> int:
    """Direction with the fewest hops (ties broken clockwise)."""
    return CW if (dst - src) % n <= (src - dst) % n else CCW


def path_segments(src: int, dst: int, n: int, direction: int) -> Iterator[int]:
    """Yield directed segment ids along the path.

    Segment ``i`` on the CW ring is the fiber from node ``i`` to ``i+1``;
    on the CCW ring it is the fiber from node ``i+1`` to ``i``.  The two
    rings are physically distinct so segment ids never collide across
    directions (callers key conflicts on (direction, segment)).
    """
    hops = ring_distance(src, dst, n, direction)
    node = src
    for _ in range(hops):
        if direction == CW:
            yield node
            node = (node + 1) % n
        else:
            node = (node - 1) % n
            yield node


class TransferBatch:
    """Structure-of-arrays schedule step: the batch counterpart of ``Transfer``.

    One row per directed transmission; columns are NumPy arrays so that RWA,
    validation and data-flow simulation run as array programs instead of
    per-object Python loops.  ``wavelength`` is ``-1`` until RWA assigns it.

    The batch is treated as immutable by convention: RWA returns a new batch
    via :meth:`with_wavelengths` rather than mutating in place, so a batch may
    safely be shared between schedule steps (the flat-ring schedule reuses one
    batch for all ``2(N-1)`` identical steps).
    """

    __slots__ = ("src", "dst", "direction", "bits", "wavelength", "_arcs")

    def __init__(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        direction: np.ndarray,
        bits: np.ndarray,
        wavelength: np.ndarray,
    ) -> None:
        self.src = src
        self.dst = dst
        self.direction = direction
        self.bits = bits
        self.wavelength = wavelength
        self._arcs = None  # (n, lane, start, hops) memo — see arcs()
        if not (len(src) == len(dst) == len(direction) == len(bits) == len(wavelength)):
            raise ValueError("TransferBatch columns must have equal length")

    # -------------------------------------------------- constructors
    @classmethod
    def from_arrays(
        cls,
        src,
        dst,
        direction,
        bits,
        wavelength=None,
        check: bool = True,
    ) -> "TransferBatch":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        direction = np.broadcast_to(
            np.asarray(direction, dtype=np.int64), src.shape
        ).copy()
        bits = np.broadcast_to(np.asarray(bits, dtype=np.float64), src.shape).copy()
        if wavelength is None:
            wavelength = np.full(src.shape, -1, dtype=np.int64)
        else:
            wavelength = np.broadcast_to(
                np.asarray(wavelength, dtype=np.int64), src.shape
            ).copy()
        if check and src.size:
            if not np.isin(direction, (CW, CCW)).all():
                raise ValueError("direction must be +1/-1")
            if (src == dst).any():
                raise ValueError("transfer src == dst")
        return cls(src, dst, direction, bits, wavelength)

    @classmethod
    def from_transfers(cls, transfers: Iterable["Transfer"]) -> "TransferBatch":
        ts = list(transfers)
        return cls.from_arrays(
            [t.src for t in ts],
            [t.dst for t in ts],
            [t.direction for t in ts],
            [t.bits for t in ts],
            [t.wavelength for t in ts],
            check=False,  # Transfer.__post_init__ already validated each row
        )

    @classmethod
    def empty(cls) -> "TransferBatch":
        return cls.from_arrays([], [], [], [], check=False)

    @classmethod
    def coerce(cls, transfers) -> "TransferBatch":
        if isinstance(transfers, cls):
            return transfers
        return cls.from_transfers(transfers)

    # -------------------------------------------------- views
    def __len__(self) -> int:
        return int(self.src.size)

    def __getitem__(self, i: int) -> "Transfer":
        return Transfer(
            int(self.src[i]), int(self.dst[i]), int(self.direction[i]),
            float(self.bits[i]), int(self.wavelength[i]),
        )

    def __iter__(self) -> Iterator["Transfer"]:
        for i in range(len(self)):
            yield self[i]

    def to_transfers(self) -> list["Transfer"]:
        return list(self)

    def with_wavelengths(self, wavelength: np.ndarray) -> "TransferBatch":
        batch = TransferBatch(
            self.src, self.dst, self.direction, self.bits,
            np.asarray(wavelength, dtype=np.int64),
        )
        batch._arcs = self._arcs  # geometry is wavelength-independent
        return batch

    # -------------------------------------------------- geometry
    def arcs(self, n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Each directed lightpath as a ring arc.

        Returns ``(lane, start, hops)``: ``lane`` 0 for CW / 1 for CCW (the
        two fibers are independent), and the path covers directed segments
        ``start, start+1, ..., start+hops-1 (mod n)`` — the exact segment ids
        of :func:`path_segments` for either direction.

        The result is memoized per ring size (geometry never changes after
        construction — batches are immutable by convention), so RWA,
        validation and profile compilation share one computation.
        """
        memo = self._arcs
        if memo is not None and memo[0] == n:
            return memo[1], memo[2], memo[3]
        # direction is ±1, so both branches collapse to arithmetic:
        # lane = 0/1 for CW/CCW, hops = (dst-src)%n resp. (src-dst)%n
        lane = (1 - self.direction) >> 1
        hops = ((self.dst - self.src) * self.direction) % n
        start = np.where(self.direction == CW, self.src, self.dst)
        self._arcs = (n, lane, start, hops)
        return lane, start, hops

    @property
    def max_wavelength(self) -> int:
        return -1 if len(self) == 0 else int(self.wavelength.max())

    def __repr__(self) -> str:
        return f"TransferBatch(len={len(self)})"


@dataclass
class Ring:
    """A bidirectional WDM ring with ``n`` nodes and ``w`` wavelengths/fiber."""

    n: int
    w: int
    bandwidth_bps: float = 40e9        # per wavelength (Table II)
    reconfig_delay_s: float = 25e-6    # MRR reconfiguration delay (Table II)
    flit_bits: int = 32 * 8            # flit size (Table II)
    oeo_cycle_s: float = field(default=0.0)  # O/E/O conversion, per flit
    physical: PhysicalParams | None = None   # power budget; None = unconstrained
    failures: FailureMask | None = None      # dead resources; None = healthy

    def __post_init__(self) -> None:
        if self.n < 2:
            raise ValueError("ring needs >= 2 nodes")
        if self.w < 1:
            raise ValueError("need >= 1 wavelength")
        if self.oeo_cycle_s == 0.0:
            # Table II: O/E/O delay is 1 cycle/flit.  At 40 Gb/s a 32 B flit
            # serializes in 256/40e9 s; one extra cycle per flit models the
            # conversion pipeline.
            self.oeo_cycle_s = self.flit_bits / self.bandwidth_bps

    @property
    def max_hops(self) -> int | None:
        """Insertion-loss hop budget, or None when no physical model is set."""
        return None if self.physical is None else self.physical.max_hops

    def serialization_time(self, bits: float) -> float:
        """Wire time for one transfer: flit-aligned serialization + O/E/O."""
        if bits <= 0:
            return 0.0
        flits = -(-int(bits) // self.flit_bits)  # ceil
        return flits * self.flit_bits / self.bandwidth_bps + self.oeo_cycle_s

    def serialization_time_array(self, bits: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`serialization_time` (same flit arithmetic)."""
        b = np.asarray(bits, dtype=np.float64)
        flits = -(-b.astype(np.int64) // self.flit_bits)  # ceil, as the scalar
        out = flits * self.flit_bits / self.bandwidth_bps + self.oeo_cycle_s
        return np.where(b <= 0, 0.0, out)

    def propagation_time(self, hops: np.ndarray) -> np.ndarray:
        """Receive-side time of flight for per-transfer hop counts."""
        if self.physical is None:
            return np.zeros_like(np.asarray(hops, dtype=np.float64))
        return np.asarray(hops, dtype=np.float64) * self.physical.propagation_s_per_hop
