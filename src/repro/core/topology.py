"""Optical ring topology model (TeraRack-style).

The physical substrate of the paper: ``N`` nodes on a bidirectional WDM ring.
Each direction is an independent fiber ring carrying ``w`` wavelengths; a
directed transfer from ``src`` to ``dst`` occupies every unit *segment*
(i, i+1 mod N) (clockwise) or (i, i-1 mod N) (counter-clockwise) along its
path, on one wavelength.  Two transfers conflict iff they share a directed
segment *and* a wavelength.

This module is pure Python/NumPy — it backs the schedule builder, the RWA
(routing and wavelength assignment) pass and the optical simulator.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator

CW = +1   # clockwise
CCW = -1  # counter-clockwise


@dataclass(frozen=True)
class Transfer:
    """One directed optical transmission within a communication step."""

    src: int
    dst: int
    direction: int          # CW or CCW
    bits: float             # payload size in bits
    wavelength: int = -1    # assigned by RWA; -1 = unassigned

    def __post_init__(self) -> None:
        if self.direction not in (CW, CCW):
            raise ValueError(f"direction must be +1/-1, got {self.direction}")
        if self.src == self.dst:
            raise ValueError("transfer src == dst")


def ring_distance(src: int, dst: int, n: int, direction: int) -> int:
    """Number of unit segments traversed from src to dst going `direction`."""
    if direction == CW:
        return (dst - src) % n
    return (src - dst) % n


def shortest_direction(src: int, dst: int, n: int) -> int:
    """Direction with the fewest hops (ties broken clockwise)."""
    return CW if (dst - src) % n <= (src - dst) % n else CCW


def path_segments(src: int, dst: int, n: int, direction: int) -> Iterator[int]:
    """Yield directed segment ids along the path.

    Segment ``i`` on the CW ring is the fiber from node ``i`` to ``i+1``;
    on the CCW ring it is the fiber from node ``i+1`` to ``i``.  The two
    rings are physically distinct so segment ids never collide across
    directions (callers key conflicts on (direction, segment)).
    """
    hops = ring_distance(src, dst, n, direction)
    node = src
    for _ in range(hops):
        if direction == CW:
            yield node
            node = (node + 1) % n
        else:
            node = (node - 1) % n
            yield node


@dataclass
class Ring:
    """A bidirectional WDM ring with ``n`` nodes and ``w`` wavelengths/fiber."""

    n: int
    w: int
    bandwidth_bps: float = 40e9        # per wavelength (Table II)
    reconfig_delay_s: float = 25e-6    # MRR reconfiguration delay (Table II)
    flit_bits: int = 32 * 8            # flit size (Table II)
    oeo_cycle_s: float = field(default=0.0)  # O/E/O conversion, per flit

    def __post_init__(self) -> None:
        if self.n < 2:
            raise ValueError("ring needs >= 2 nodes")
        if self.w < 1:
            raise ValueError("need >= 1 wavelength")
        if self.oeo_cycle_s == 0.0:
            # Table II: O/E/O delay is 1 cycle/flit.  At 40 Gb/s a 32 B flit
            # serializes in 256/40e9 s; one extra cycle per flit models the
            # conversion pipeline.
            self.oeo_cycle_s = self.flit_bits / self.bandwidth_bps

    def serialization_time(self, bits: float) -> float:
        """Wire time for one transfer: flit-aligned serialization + O/E/O."""
        if bits <= 0:
            return 0.0
        flits = -(-int(bits) // self.flit_bits)  # ceil
        return flits * self.flit_bits / self.bandwidth_bps + self.oeo_cycle_s
