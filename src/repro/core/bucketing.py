"""Gradient bucketing: pytree -> size-capped flat buckets -> collective -> pytree.

Why buckets:
  1. overlap — each bucket's collective is an independent HLO op, so XLA can
     overlap bucket k's all-reduce with bucket k+1's backprop compute;
  2. per-size planning — the α–β planner picks a different schedule for a
     4 KB layernorm bucket (latency-bound -> WRHT tree) than for a 256 MB
     embedding bucket (bandwidth-bound -> hierarchical scatter);
  3. padding amortization — scatter-mode collectives need divisibility by the
     axis-size product; padding one bucket beats padding every leaf.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class BucketSpec:
    """Assignment of flat leaf ranges to buckets (static, trace-time)."""

    leaf_shapes: tuple[tuple[int, ...], ...]
    leaf_buckets: tuple[int, ...]       # bucket id per leaf
    bucket_sizes: tuple[int, ...]       # elements per bucket (unpadded)
    treedef: object


def plan_buckets(tree, max_bucket_bytes: int = 32 * 2**20) -> BucketSpec:
    """Greedy sequential packing of leaves into <= max_bucket_bytes buckets.

    Leaves larger than the cap get their own bucket (never split — keeps the
    unflatten cheap and the collective count bounded).
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = [math.prod(s) for s in shapes]
    nbytes = [sz * leaves[i].dtype.itemsize for i, sz in enumerate(sizes)]

    leaf_buckets: list[int] = []
    bucket_sizes: list[int] = []
    cur_bytes = 0
    cur_id = -1
    for i, b in enumerate(nbytes):
        if cur_id < 0 or cur_bytes + b > max_bucket_bytes:
            cur_id += 1
            bucket_sizes.append(0)
            cur_bytes = 0
        leaf_buckets.append(cur_id)
        bucket_sizes[cur_id] += sizes[i]
        cur_bytes += b
    return BucketSpec(shapes, tuple(leaf_buckets), tuple(bucket_sizes), treedef)


def flatten_to_buckets(tree, spec: BucketSpec, dtype=None) -> list[jax.Array]:
    leaves = jax.tree.leaves(tree)
    buckets: list[list[jax.Array]] = [[] for _ in spec.bucket_sizes]
    for leaf, bid in zip(leaves, spec.leaf_buckets):
        flat = leaf.reshape(-1)
        if dtype is not None:
            flat = flat.astype(dtype)
        buckets[bid].append(flat)
    return [jnp.concatenate(b) if len(b) > 1 else b[0] for b in buckets]


def unflatten_buckets(buckets: list[jax.Array], spec: BucketSpec, dtypes=None):
    leaves = []
    offsets = [0] * len(buckets)
    for i, (shape, bid) in enumerate(zip(spec.leaf_shapes, spec.leaf_buckets)):
        n = math.prod(shape)
        seg = jax.lax.dynamic_slice_in_dim(buckets[bid], offsets[bid], n)
        if dtypes is not None:
            seg = seg.astype(dtypes[i])
        leaves.append(seg.reshape(shape))
        offsets[bid] += n
    return jax.tree.unflatten(spec.treedef, leaves)


def bucketed_allreduce(
    tree,
    apply_fn,
    max_bucket_bytes: int = 32 * 2**20,
    sync_dtype=None,
):
    """Apply ``apply_fn(flat_bucket, bucket_bytes) -> flat_bucket`` to every
    bucket of ``tree`` and reassemble.  ``apply_fn`` is where the planner's
    per-size schedule choice plugs in."""
    return bucketed_apply_indexed(
        tree, lambda b, nbytes, i: apply_fn(b, nbytes),
        plan_buckets(tree, max_bucket_bytes), sync_dtype=sync_dtype)


def bucketed_apply_indexed(tree, apply_fn, spec: BucketSpec, sync_dtype=None):
    """Like :func:`bucketed_allreduce`, but against a *precomputed*
    ``spec`` and with the bucket index passed through:
    ``apply_fn(flat_bucket, bucket_bytes, bucket_index)``.

    This is the amortized-planning entry point (DESIGN.md §10): the trainer
    computes the bucket partition and every bucket's schedule once at setup
    (``train_step.plan_gradient_sync``), and each traced step just
    dispatches bucket ``i`` to its precomputed plan.
    """
    leaves = jax.tree.leaves(tree)
    if tuple(tuple(l.shape) for l in leaves) != spec.leaf_shapes:
        raise ValueError("tree leaves do not match the precomputed BucketSpec")
    dtypes = [l.dtype for l in leaves]
    buckets = flatten_to_buckets(tree, spec, dtype=sync_dtype)
    out = [apply_fn(b, b.size * b.dtype.itemsize, i)
           for i, b in enumerate(buckets)]
    return unflatten_buckets(out, spec, dtypes=dtypes)


def bucketed_apply_compressed(tree, ef_tree, apply_fn, spec: BucketSpec, *,
                              bits, block: int = 1024, fused: bool = False,
                              sync_dtype=None):
    """Error-feedback-compressed bucket sync (DESIGN.md §15): per bucket,
    quantize ``grad + residual`` to ``bits[i]`` with per-``block`` scales,
    hand the *dequantized* value to ``apply_fn(flat, bucket_bytes, i)`` (the
    planned collective), and keep the quantization error as the new
    residual.  ``bits[i] >= 32`` is an exact pass-through — the per-bucket
    planner sweep uses it to decline compression on latency-bound buckets.

    ``ef_tree`` must share ``tree``'s structure (the EF residual state the
    trainer carries in the train-state pytree).  ``fused=True`` routes the
    quantize through the pallas ``ef_quantize_bucketize`` kernel.

    Returns ``(new_tree, new_ef_tree)``.
    """
    from . import compression
    leaves = jax.tree.leaves(tree)
    if tuple(tuple(l.shape) for l in leaves) != spec.leaf_shapes:
        raise ValueError("tree leaves do not match the precomputed BucketSpec")
    if len(bits) != len(spec.bucket_sizes):
        raise ValueError(
            f"bits has {len(bits)} entries for {len(spec.bucket_sizes)} buckets")
    dtypes = [l.dtype for l in leaves]
    ef_dtypes = [l.dtype for l in jax.tree.leaves(ef_tree)]
    buckets = flatten_to_buckets(tree, spec, dtype=sync_dtype)
    ef_buckets = flatten_to_buckets(ef_tree, spec)
    out, new_ef = [], []
    for i, (b, e) in enumerate(zip(buckets, ef_buckets)):
        deq, res = compression.ef_compress_blocks(
            b, e.astype(b.dtype), bits=bits[i], block=block, fused=fused)
        out.append(apply_fn(deq, deq.size * deq.dtype.itemsize, i))
        new_ef.append(res)
    return (unflatten_buckets(out, spec, dtypes=dtypes),
            unflatten_buckets(new_ef, spec, dtypes=ef_dtypes))


def bucketed_apply_pipelined(tree, rs_fn, ag_fn, spec: BucketSpec,
                             depth: int = 2, sync_dtype=None):
    """Two-phase bucket sync, software-pipelined over the buckets
    (DESIGN.md §13): bucket ``i``'s first phase (reduce-scatter) is issued
    *before* bucket ``i - depth + 1``'s second phase (all-gather) is
    drained, so up to ``depth`` buckets sit between their phases at any
    point in the issue order.

    ``rs_fn(flat_bucket, bucket_bytes, i) -> (shard, ctx)`` runs the way
    down; ``ag_fn(shard, ctx, bucket_bytes, i) -> flat_bucket`` the way
    back up (``ctx`` is opaque carry, e.g. the pre-scatter lengths).  The
    emitted HLO interleaves RS(k+1) with AG(k) as independent ops — the
    issue order the composed ring schedule (``core.compose``) was costed
    for — while per-bucket numerics are exactly the serial
    ``ag_fn(*rs_fn(...))`` composition.

    ``depth=1`` degenerates to the serial phase order of
    :func:`bucketed_apply_indexed`.
    """
    if depth < 1:
        raise ValueError("pipeline depth must be >= 1")
    leaves = jax.tree.leaves(tree)
    if tuple(tuple(l.shape) for l in leaves) != spec.leaf_shapes:
        raise ValueError("tree leaves do not match the precomputed BucketSpec")
    dtypes = [l.dtype for l in leaves]
    buckets = flatten_to_buckets(tree, spec, dtype=sync_dtype)
    nbytes = [b.size * b.dtype.itemsize for b in buckets]
    out: list = [None] * len(buckets)
    # deque: the sliding window drains from the left every bucket, and
    # list.pop(0) is O(window) per bucket (the StepWatchdog pattern,
    # DESIGN.md §14) — popleft is O(1) at any depth
    window: deque[tuple[int, object, object]] = deque()
    for i, b in enumerate(buckets):
        shard, ctx = rs_fn(b, nbytes[i], i)
        window.append((i, shard, ctx))
        if len(window) >= depth:
            j, shard, ctx = window.popleft()
            out[j] = ag_fn(shard, ctx, nbytes[j], j)
    for j, shard, ctx in window:
        out[j] = ag_fn(shard, ctx, nbytes[j], j)
    return unflatten_buckets(out, spec, dtypes=dtypes)
