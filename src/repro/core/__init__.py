# The paper's primary contribution — WRHT all-reduce — and its substrate:
#   wrht          explicit optical-ring schedule builder (the paper, faithfully)
#   wavelength    routing & wavelength assignment (first-fit RWA)
#   step_models   closed-form step counts / times (Table I, Eq. 1)
#   simulator     optical-ring event simulator (Fig. 4/5 reproduction)
#   timing        payload-vectorized grid timing + WRHT auto-tuner
#   collectives   shard_map all-reduce zoo (ring/BT/RD/WRHT) — the TPU port
#   planner       α–β schedule planner (Lemma 1/Theorem 1 on TPU)
#   bucketing     gradient bucketing for overlap + per-size planning
#   compression   int8 + error-feedback cross-pod sync
#
# NOTE: jax is imported lazily by the submodules that need it; the pure
# Python/NumPy modules (wrht, simulator, ...) stay importable without
# touching jax device state, so `from repro.core import wrht` is always safe
# before XLA_FLAGS are pinned.
from . import (  # noqa: F401
    planner,
    simulator,
    step_models,
    timing,
    topology,
    wavelength,
    wrht,
)
