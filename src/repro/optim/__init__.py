from .adamw import adamw_init, adamw_update, global_norm  # noqa: F401
from .schedule import make_lr_schedule  # noqa: F401
