"""Learning-rate schedules (warmup + cosine / linear decay)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def make_lr_schedule(tc: TrainConfig, kind: str = "cosine"):
    warm = max(tc.warmup_steps, 1)
    total = max(tc.total_steps, warm + 1)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = tc.lr * step / warm
        t = jnp.clip((step - warm) / (total - warm), 0.0, 1.0)
        if kind == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t)) * tc.lr
        else:
            decay = (1.0 - t) * tc.lr
        return jnp.where(step < warm, warm_lr, decay)

    return lr
