"""AdamW, functional, with configurable state dtype and global-norm clipping.

State dtype matters at scale: fp32 m/v doubles optimizer memory vs bf16;
``TrainConfig.opt_state_dtype`` picks (the 236B dry-run uses bf16 m/v to fit
16 GB/chip — a distributed-memory trick recorded in EXPERIMENTS.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_init(params, state_dtype=jnp.float32) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads,
    opt_state: dict,
    params,
    lr: jax.Array,
    tc: TrainConfig,
):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-12))
    count = opt_state["count"] + 1
    b1, b2 = tc.b1, tc.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + 1e-8) + tc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}, {"grad_norm": gnorm}
