"""Batched serving engine: prefill + step-synchronized decode.

Slot-based continuous batching (lite): a fixed number of batch slots; a
round admits up to ``batch_slots`` queued requests, right-pads them to a
common prefill length, runs one jit'd prefill, then step-synchronized greedy
decode until every sequence hits EOS or its token budget; finished slots are
refilled next round.  (True per-step slot refill needs paged attention —
out of scope; the cache layout supports it later.)

Both phases are jit'd once per (batch, seq) bucket; the decode loop runs one
token per call with a shared scalar position — the same ``serve_step`` the
decode_32k / long_500k dry-run cells lower.  The batch bucket is sized to
the *admitted* count, not ``batch_slots``: a half-empty round neither pays
prefill/decode compute for dead slots nor skews per-round latency, and the
jit bucket cache stays bounded by the ``batch_slots`` distinct sizes.

``submit`` validates the prompt against the KV-cache geometry up front: a
prompt whose prefill footprint (``len(prompt)`` plus any frontend stub
positions) reaches ``max_seq`` would overflow the cache at prefill and
silently decode garbage, so it is rejected with an actionable ``ValueError``
instead.  Every request records *why* it finished (``finish_reason``:
``"eos"`` | ``"budget"`` | ``"seq_limit"``), and every round appends a
:class:`RoundStats` to ``round_log`` — the hook the multi-tenant traffic
simulator (``repro.core.traffic``, DESIGN.md §16) uses to size inference
collectives from real serving behaviour.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api as mapi


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = field(default_factory=list)
    done: bool = False
    # why the request finished: "eos" (hit eos_id), "budget" (max_new_tokens
    # emitted) or "seq_limit" (the shared decode position hit max_seq before
    # the budget was met) — None while in flight
    finish_reason: str | None = None


@dataclass(frozen=True)
class RoundStats:
    """One serve round's shape, recorded in ``Engine.round_log``."""

    admitted: int        # requests actually served this round
    batch: int           # jit bucket used (== admitted, not batch_slots)
    prefill_len: int     # KV positions written at prefill (incl. frontend)
    decode_steps: int    # decode calls issued after prefill


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_seq: int = 256, compute_dtype=jnp.bfloat16,
                 pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.api = mapi.get_api(cfg, compute_dtype=compute_dtype, remat="none")
        self._queue: list[Request] = []
        self._rid = itertools.count()
        self.round_log: list[RoundStats] = []
        # retrace counters: the wrapped bodies run once per jit bucket, so
        # these count compilations, not calls (the bucket-cache-bounded test)
        self.prefill_traces = 0
        self.decode_traces = 0

        def _prefill(params, batch, cache):
            self.prefill_traces += 1
            return self.api.prefill(params, batch, cache)

        def _decode(params, tok, pos, cache):
            self.decode_traces += 1
            return self.api.decode(params, tok, pos, cache)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    @property
    def _frontend_extra(self) -> int:
        return (self.cfg.frontend_seq
                if self.cfg.frontend == "patch_embed" else 0)

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> Request:
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt: prefill needs at least one token")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        extra = self._frontend_extra
        if len(prompt) + extra >= self.max_seq:
            frontend = (f" plus {extra} frontend positions" if extra else "")
            raise ValueError(
                f"prompt of {len(prompt)} tokens{frontend} does not fit the "
                f"KV cache: prefill would fill {len(prompt) + extra} of "
                f"max_seq={self.max_seq} positions, leaving no room to "
                f"decode — shorten the prompt or raise max_seq")
        r = Request(next(self._rid), prompt, max_new_tokens, eos_id)
        self._queue.append(r)
        return r

    def _admit(self) -> list[Request]:
        batch, self._queue = (self._queue[: self.batch_slots],
                              self._queue[self.batch_slots:])
        return batch

    def run(self) -> list[Request]:
        """Serve everything queued; returns completed requests."""
        done: list[Request] = []
        while self._queue:
            batch = self._admit()
            done.extend(self._serve_round(batch))
        return done

    def _serve_round(self, reqs: list[Request]) -> list[Request]:
        # size the jit bucket to the admitted count: fewer requests than
        # batch_slots must not pay full-width prefill/decode, and the
        # distinct bucket count is bounded by batch_slots
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad to align ends
        cache = self.api.init_cache(b, self.max_seq)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "patch_embed":
            batch["patch_embeds"] = jnp.zeros(
                (b, self.cfg.frontend_seq, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, self.cfg.encoder_seq, self.cfg.d_model), jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch, cache)
        pos = plen + self._frontend_extra
        prefill_len = pos
        budget = max(r.max_new_tokens for r in reqs)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        decode_steps = 0
        for step in range(budget):
            tok_host = np.asarray(jax.device_get(next_tok))
            for i, r in enumerate(reqs):
                if r.done:
                    continue
                t = int(tok_host[i])
                r.output.append(t)
                if r.eos_id is not None and t == r.eos_id:
                    r.done = True
                    r.finish_reason = "eos"
                elif len(r.output) >= r.max_new_tokens:
                    r.done = True
                    r.finish_reason = "budget"
            if all(r.done for r in reqs):
                break
            if pos >= self.max_seq:
                break
            logits, cache = self._decode(self.params, next_tok,
                                         jnp.asarray(pos, jnp.int32), cache)
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
            pos += 1
            decode_steps += 1
        for r in reqs:
            if not r.done:
                # the shared decode position hit max_seq before this
                # request's budget — a truncation, not a completion
                r.done = True
                r.finish_reason = "seq_limit"
        self.round_log.append(RoundStats(admitted=len(reqs), batch=b,
                                         prefill_len=prefill_len,
                                         decode_steps=decode_steps))
        return reqs
