"""Batched serving engine: prefill + step-synchronized decode.

Slot-based continuous batching (lite): a fixed number of batch slots; a
round admits up to ``batch_slots`` queued requests, right-pads them to a
common prefill length, runs one jit'd prefill, then step-synchronized greedy
decode until every sequence hits EOS or its token budget; finished slots are
refilled next round.  (True per-step slot refill needs paged attention —
out of scope; the cache layout supports it later.)

Both phases are jit'd once per (batch, seq) bucket; the decode loop runs one
token per call with a shared scalar position — the same ``serve_step`` the
decode_32k / long_500k dry-run cells lower.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api as mapi


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_seq: int = 256, compute_dtype=jnp.bfloat16,
                 pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.api = mapi.get_api(cfg, compute_dtype=compute_dtype, remat="none")
        self._queue: list[Request] = []
        self._rid = itertools.count()

        self._prefill = jax.jit(
            lambda params, batch, cache: self.api.prefill(params, batch, cache))
        self._decode = jax.jit(
            lambda params, tok, pos, cache: self.api.decode(params, tok, pos, cache))

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> Request:
        r = Request(next(self._rid), list(prompt), max_new_tokens, eos_id)
        self._queue.append(r)
        return r

    def _admit(self) -> list[Request]:
        batch, self._queue = (self._queue[: self.batch_slots],
                              self._queue[self.batch_slots:])
        return batch

    def run(self) -> list[Request]:
        """Serve everything queued; returns completed requests."""
        done: list[Request] = []
        while self._queue:
            batch = self._admit()
            done.extend(self._serve_round(batch))
        return done

    def _serve_round(self, reqs: list[Request]) -> list[Request]:
        b = self.batch_slots
        plen = max(len(r.prompt) for r in reqs)
        plen = max(plen, 1)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad to align ends
        cache = self.api.init_cache(b, self.max_seq)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "patch_embed":
            batch["patch_embeds"] = jnp.zeros(
                (b, self.cfg.frontend_seq, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, self.cfg.encoder_seq, self.cfg.d_model), jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch, cache)
        pos = plen
        if self.cfg.frontend == "patch_embed":
            pos += self.cfg.frontend_seq
        budget = max(r.max_new_tokens for r in reqs)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for step in range(budget):
            tok_host = np.asarray(jax.device_get(next_tok))
            for i, r in enumerate(reqs):
                if r.done or len(r.output) >= r.max_new_tokens:
                    r.done = True
                    continue
                t = int(tok_host[i])
                r.output.append(t)
                if r.eos_id is not None and t == r.eos_id:
                    r.done = True
            if all(r.done or len(r.output) >= r.max_new_tokens for r in reqs):
                break
            if pos >= self.max_seq:
                break
            logits, cache = self._decode(self.params, next_tok,
                                         jnp.asarray(pos, jnp.int32), cache)
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
            pos += 1
        for r in reqs:
            r.done = True
        return reqs
