from .engine import Engine, Request, RoundStats  # noqa: F401
