"""Paper Table I: communication-step comparison, N=1000, w=64 (+ scaling)."""

from __future__ import annotations

import time

from repro.core import step_models as sm, wrht


def rows() -> list[dict]:
    out = []
    n, w = 1000, 64
    m = 2 * w + 1
    t0 = time.perf_counter()
    sched = wrht.build_schedule(n, w, 1.0)
    build_us = (time.perf_counter() - t0) * 1e6
    out.append({"name": "table1/ring_steps", "us_per_call": 0.0,
                "derived": sm.ring_steps(n), "paper": 1998})
    out.append({"name": "table1/hring_steps(g=5)", "us_per_call": 0.0,
                "derived": sm.hring_steps(n, 5, w, table_variant=True),
                "paper": 411})
    out.append({"name": "table1/bt_steps", "us_per_call": 0.0,
                "derived": sm.bt_steps(n), "paper": 20})
    out.append({"name": "table1/wrht_steps(closed_form)", "us_per_call": 0.0,
                "derived": sm.wrht_steps(n, m, with_alltoall=False), "paper": 4})
    out.append({"name": "table1/wrht_steps(built_schedule)",
                "us_per_call": build_us, "derived": sched.num_steps,
                "paper": "4 (3 with all-to-all)"})
    # scaling check across the paper's cluster sizes
    for nn in (1024, 2048, 3072, 4096):
        s = wrht.build_schedule(nn, w, 1.0, validate=False)
        out.append({"name": f"table1/wrht_steps(N={nn})", "us_per_call": 0.0,
                    "derived": s.num_steps, "paper": "≤4"})
    return out
