"""Amortized planning wall-clock: batched tuner + two-tier plan cache.

Two measurements, written to ``BENCH_planner.json`` by
``python -m benchmarks.bench_planner`` (DESIGN.md §10, EXPERIMENTS.md §Perf):

* ``tuner`` — ``timing.tune_wrht`` through the batched multi-candidate
  builder vs ``timing.tune_wrht_reference`` (the per-candidate loop kept as
  the golden oracle), cold caches, on the PR-3 sweep's tuner cells.  The
  acceptance bar is a ≥5× speedup with **bit-identical** candidates, totals
  and argmin — both are asserted here at measurement time and recorded in
  the artifact.
* ``plan_buckets`` — cold vs warm throughput (plans/second) of
  ``planner.plan_buckets`` over a realistic gradient-bucket size list,
  simulated backend: the cold call pays one batched candidate build; the
  warm call hits the plan cache and skips both build and compile.  The
  per-bucket ``plan_bucket`` loop is timed alongside to show what the batch
  API amortizes.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the cells for CI smoke runs (the workflow uploads the
JSON as an artifact).
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import plan_cache, planner, step_models as sm, timing

# the PR-3 sweep's tuner portion (benchmarks/bench_sweep.measure_tuner)
TUNER_CELLS = ((1024, 64, None), (1024, 16, 16), (4096, 64, None))
QUICK_TUNER_CELLS = ((256, 16, None), (256, 16, 8))


def measure_tuner(cells=TUNER_CELLS) -> dict:
    """Cold batched vs cold per-candidate tuner, with bit-identity checks."""
    d = sm.PAPER_MODELS_BITS["ResNet50"]
    rows = []
    total_ref = total_batched = 0.0
    all_identical = True
    for n, w, max_hops in cells:
        timing.clear_caches()
        t0 = time.perf_counter()
        ref = timing.tune_wrht_reference(n, w, d, max_hops)
        ref_s = time.perf_counter() - t0

        timing.clear_caches()
        t0 = time.perf_counter()
        bat = timing.tune_wrht(n, w, d, max_hops)
        batched_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        timing.tune_wrht(n, w, d, max_hops)
        warm_s = time.perf_counter() - t0

        identical = (
            ref.candidates == bat.candidates
            and np.array_equal(ref.total_s, bat.total_s)
            and np.array_equal(ref.steps, bat.steps)
            and np.array_equal(ref.best_m, bat.best_m)
            and np.array_equal(ref.best_alltoall, bat.best_alltoall)
        )
        all_identical &= identical
        total_ref += ref_s
        total_batched += batched_s
        rows.append({
            "n": n, "w": w, "max_hops": max_hops,
            "candidates": len(bat.candidates),
            "tuned_m": int(bat.best_m[0]),
            "reference_s": round(ref_s, 4),
            "batched_s": round(batched_s, 4),
            "warm_s": round(warm_s, 4),
            "speedup": round(ref_s / batched_s, 1),
            "bit_identical": identical,
        })
    return {
        "cells": rows,
        "reference_s": round(total_ref, 4),
        "batched_s": round(total_batched, 4),
        "speedup": round(total_ref / total_batched, 1),
        "bit_identical": all_identical,
    }


def bucket_sizes(n_buckets: int = 24) -> list[float]:
    """Log-spaced gradient-bucket byte sizes, 4 KB .. 256 MB (what a
    size-capped partition of a transformer's parameters produces)."""
    return np.geomspace(4 * 2**10, 256 * 2**20, n_buckets).tolist()


def measure_plan_buckets(axis_size: int = 1024, w: int = 64,
                         n_buckets: int = 24) -> dict:
    """Cold vs warm ``plan_buckets`` throughput, simulated backend."""
    sizes = bucket_sizes(n_buckets)
    p = planner.CostParams.optical(w)

    timing.clear_caches()
    t0 = time.perf_counter()
    cold_plans = planner.plan_buckets(axis_size, sizes, p, backend="simulated")
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    warm_plans = planner.plan_buckets(axis_size, sizes, p, backend="simulated")
    warm_s = time.perf_counter() - t0
    assert warm_plans == cold_plans

    # what the batch API amortizes: one plan_bucket call per bucket (warm
    # caches — the historical per-step-call pattern of the training loop)
    t0 = time.perf_counter()
    loop_plans = [planner.plan_bucket(axis_size, b, p, backend="simulated")
                  for b in sizes]
    loop_warm_s = time.perf_counter() - t0
    assert loop_plans == cold_plans

    t0 = time.perf_counter()
    analytic = planner.plan_buckets(axis_size, sizes, p)
    analytic_s = time.perf_counter() - t0
    stats = plan_cache.get_default().stats
    return {
        "axis_size": axis_size,
        "wavelengths": w,
        "buckets": n_buckets,
        "cold_s": round(cold_s, 4),
        "warm_s": round(warm_s, 5),
        "cold_plans_per_s": round(n_buckets / cold_s, 1),
        "warm_plans_per_s": round(n_buckets / warm_s, 1),
        "warm_speedup": round(cold_s / warm_s, 1),
        "loop_warm_s": round(loop_warm_s, 4),
        "batch_vs_loop_warm": round(loop_warm_s / warm_s, 1),
        "analytic_s": round(analytic_s, 5),
        "strategies": sorted({pl.strategy for pl in cold_plans}),
        "cache": {"memory_hits": stats.memory_hits,
                  "disk_hits": stats.disk_hits,
                  "misses": stats.misses},
    }


def bench(quick: bool = False) -> dict:
    if quick:
        tuner = measure_tuner(QUICK_TUNER_CELLS)
        buckets = measure_plan_buckets(axis_size=256, w=16, n_buckets=12)
    else:
        tuner = measure_tuner()
        buckets = measure_plan_buckets()
    return {
        "benchmark": "planner_amortized",
        "quick": quick,
        "tuner": tuner,
        "plan_buckets": buckets,
    }


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` harness (CI smoke)."""
    t = measure_tuner(QUICK_TUNER_CELLS)
    b = measure_plan_buckets(axis_size=256, w=16, n_buckets=8)
    return [
        {
            "name": "planner/tuner_batched_vs_percandidate",
            "us_per_call": t["batched_s"] * 1e6 / max(1, len(t["cells"])),
            "derived": {k: t[k] for k in
                        ("reference_s", "batched_s", "speedup",
                         "bit_identical")},
        },
        {
            "name": "planner/plan_buckets/N=256/w=16",
            "us_per_call": b["cold_s"] * 1e6 / b["buckets"],
            "derived": {k: b[k] for k in
                        ("cold_plans_per_s", "warm_plans_per_s",
                         "warm_speedup", "batch_vs_loop_warm")},
        },
    ]


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    result = bench(quick=quick)
    path = Path(__file__).resolve().parents[1] / "BENCH_planner.json"
    path.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {path}")
    t = result["tuner"]
    print(f"tuner: reference={t['reference_s']}s batched={t['batched_s']}s "
          f"speedup={t['speedup']}x bit_identical={t['bit_identical']}")
    for c in t["cells"]:
        print(f"  n={c['n']} w={c['w']} H={c['max_hops']}: "
              f"{c['reference_s']}s -> {c['batched_s']}s "
              f"({c['speedup']}x, warm {c['warm_s']}s)")
    b = result["plan_buckets"]
    print(f"plan_buckets N={b['axis_size']}: cold {b['cold_plans_per_s']} "
          f"plans/s, warm {b['warm_plans_per_s']} plans/s "
          f"({b['warm_speedup']}x), batch vs per-bucket loop (warm) "
          f"{b['batch_vs_loop_warm']}x")


if __name__ == "__main__":
    main()
