"""Compression-aware crossovers: how bits-per-element moves the planning
frontiers (DESIGN.md §15, EXPERIMENTS.md §Compression).

Four measurements, written to ``BENCH_compression.json`` by
``python -m benchmarks.bench_compression``:

* ``rs_ag_vs_ar`` — the RS+AG-vs-AR crossover re-measured at equal wire
  width on both sides (fp32 / int8 / int4).  Compression does NOT move this
  frontier down: shrinking the β-term by ``bits/32`` on both curves leaves
  the step-bound region in charge up to ~``32/bits``× larger *logical*
  payloads, so the same-width int8 crossover sits ≈4× above the fp32 one.
  The honest table (``compressed_vs_ar``) includes the cells where int8
  *loses* outright — small buckets where the quantize overhead exceeds the
  β saving.
* ``compressed_frontier`` — the frontier the trainer actually rides:
  int8/int4 RS+AG *plus the quantize/dequant overhead* against the fp32
  monolithic all-reduce.  This crossover moves down (≈25 MB vs ≈63 MB at
  N=256), which is what ``sync_algorithm="planned_sharded_compressed"``
  exploits per bucket.
* ``electrical_vs_optical`` — paper Fig. 5 re-measured at int8/int4 with
  both link technologies compressing equally: shrinking the β-term leaves
  the latency terms in charge, and the (N-1)-hop electrical ring carries
  far more per-hop latency than WRHT's ~2·log_m(N) reconfigurations — so
  WRHT's relative reduction *grows* as the width shrinks (0.57 → 0.84 →
  0.91 vs E-Ring on ResNet50 at N=256).
* ``tuner_decline`` — the per-bucket width sweep itself
  (``planner.plan_buckets(bits_candidates=...)``) across bucket sizes: the
  smallest buckets decline compression (detail["bits"] == 32) and the
  decline→compress boundary is bisected to the byte.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the grid for the CI smoke run (the workflow asserts the
frontier moved below the fp32 crossover at N=256 and uploads the JSON).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

import numpy as np

from repro.core import planner, step_models as sm, timing

NS = (64, 256, 1024)
QUICK_NS = (64, 256)
BITS_GRID = (32, 8, 4)
D_GRID = tuple(float(2 ** e) for e in range(13, 34))   # 8 Kb .. 8 Gb
RESNET50 = sm.PAPER_MODELS_BITS["ResNet50"]


def _quant_overhead_s(d_bits, cp: planner.CostParams):
    """The planner's quantize/dequant compute term on a *logical* fp32
    payload of ``d_bits`` bits (2 passes: quantize out, dequantize in)."""
    b = np.atleast_1d(np.asarray(d_bits, dtype=np.float64)) / 8.0
    return 2.0 * cp.quant_alpha_s + 2.0 * b / cp.quant_Bps


def _rs_ag(n, d, p, bits):
    d = np.atleast_1d(np.asarray(d, dtype=np.float64))
    rs = timing.collective_times("reduce_scatter", n, d, p,
                                 keep_per_step=False, bits=bits).total_s
    ag = timing.collective_times("all_gather", n, d, p,
                                 keep_per_step=False, bits=bits).total_s
    return rs + ag


def _ar(n, d, p, bits):
    d = np.atleast_1d(np.asarray(d, dtype=np.float64))
    return timing.collective_times("allreduce", n, d, p,
                                   keep_per_step=False, bits=bits).total_s


def _bisect_crossover(f_lhs, f_rhs, d_grid):
    """Smallest d where f_lhs(d) <= f_rhs(d), refined by bisection; None if
    one side wins everywhere on the grid."""
    d = np.asarray(d_grid)
    wins = f_lhs(d) <= f_rhs(d)
    if wins.all() or not wins.any():
        return None, bool(wins.all())
    i = int(np.argmax(wins))
    lo, hi = float(d[i - 1]), float(d[i])
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if f_lhs(np.array([mid]))[0] <= f_rhs(np.array([mid]))[0]:
            hi = mid
        else:
            lo = mid
    return hi, None


def measure_rs_ag_vs_ar(ns=NS, p: sm.OpticalParams | None = None,
                        cp: planner.CostParams | None = None) -> list[dict]:
    """Same-width RS+AG-vs-AR crossovers plus the honest compressed-vs-fp32
    cells (including where int8 loses)."""
    p = p or sm.OpticalParams()
    cp = cp or planner.CostParams.optical()
    rows = []
    for n in ns:
        for bits in BITS_GRID:
            cx, always = _bisect_crossover(
                lambda d: _rs_ag(n, d, p, bits),
                lambda d: _ar(n, d, p, bits), D_GRID)
            rows.append({
                "n": n, "bits": bits, "kind": "same_width",
                "crossover_d_bits": cx,
                "crossover_mbytes": None if cx is None else cx / 8 / 1e6,
                "rs_ag_always_wins": always,
            })
        # honest head-to-head at fixed logical payloads: compressed AR with
        # its overhead vs fp32 AR — int8 must LOSE on small buckets
        for d in (2.0 ** 16, 2.0 ** 23, 2.0 ** 30):
            t32 = float(_ar(n, d, p, 32)[0])
            for bits in (8, 4):
                tb = float(_ar(n, d, p, bits)[0]
                           + _quant_overhead_s(d, cp)[0])
                rows.append({
                    "n": n, "bits": bits, "kind": "compressed_vs_ar",
                    "d_bits": d, "fp32_s": t32, "compressed_s": tb,
                    "compressed_wins": tb < t32,
                })
    return rows


def measure_compressed_frontier(ns=NS, p: sm.OpticalParams | None = None,
                                cp: planner.CostParams | None = None
                                ) -> list[dict]:
    """Per (n, width): where compressed RS+AG (overhead included) crosses
    below the *fp32* monolithic all-reduce — the deployable frontier."""
    p = p or sm.OpticalParams()
    cp = cp or planner.CostParams.optical()
    rows = []
    for n in ns:
        fp32_cx, fp32_always = _bisect_crossover(
            lambda d: _rs_ag(n, d, p, 32), lambda d: _ar(n, d, p, 32),
            D_GRID)
        row = {"n": n, "fp32_crossover_d_bits": fp32_cx,
               "fp32_rs_ag_always_wins": fp32_always, "widths": {}}
        for bits in (8, 4):
            cx, always = _bisect_crossover(
                lambda d: _rs_ag(n, d, p, bits) + _quant_overhead_s(d, cp),
                lambda d: _ar(n, d, p, 32), D_GRID)
            row["widths"][str(bits)] = {
                "crossover_d_bits": cx,
                "crossover_mbytes": None if cx is None else cx / 8 / 1e6,
                "rs_ag_always_wins": always,
                "moved_below_fp32": (cx is not None and fp32_cx is not None
                                     and cx < fp32_cx),
            }
        rows.append(row)
    return rows


def measure_electrical_vs_optical(ns=NS, p: sm.OpticalParams | None = None
                                  ) -> list[dict]:
    """Fig. 5 at compressed wire widths: both technologies quantize, so the
    electrical side's wire bits shrink by the same bits/32 factor."""
    p = p or sm.OpticalParams()
    e = sm.ElectricalParams()
    rows = []
    for n in ns:
        for bits in BITS_GRID:
            factor = bits / 32.0
            for model, d in sm.PAPER_MODELS_BITS.items():
                wrht_t = float(_ar(n, d, p, bits)[0])
                ering_t = sm.t_ring_electrical(n, d * factor, e)
                rd_t = sm.t_rd_electrical(n, d * factor, e)
                rows.append({
                    "n": n, "bits": bits, "model": model,
                    "wrht_s": wrht_t, "e_ring_s": ering_t, "rd_s": rd_t,
                    "wrht_vs_ering_reduction": 1 - wrht_t / ering_t,
                    "wrht_vs_rd_reduction": 1 - wrht_t / rd_t,
                })
    return rows


def measure_tuner_decline(ns=NS, cp: planner.CostParams | None = None
                          ) -> list[dict]:
    """The per-bucket sweep across bucket sizes: which width each bucket
    picks, plus the bisected decline→compress boundary in bytes."""
    cp = cp or planner.CostParams.optical()
    sizes = [float(2 ** e) for e in range(12, 27, 2)]     # 4 KB .. 64 MB
    rows = []
    for n in ns:
        plans = planner.plan_buckets(n, sizes, cp,
                                     bits_candidates=BITS_GRID)
        per_bucket = [{"bytes": int(b), "bits": pl.detail["bits"],
                       "strategy": pl.strategy,
                       "cost_us": pl.cost_s * 1e6,
                       "quant_us": pl.detail.get("quant_s", 0.0) * 1e6}
                      for b, pl in zip(sizes, plans)]
        declined = [r for r in per_bucket if r["bits"] == 32]
        compressed = [r for r in per_bucket if r["bits"] < 32]
        boundary = None
        if declined and compressed:
            lo = float(max(r["bytes"] for r in declined))
            hi = float(min(r["bytes"] for r in compressed))
            if lo < hi:
                for _ in range(40):
                    mid = 0.5 * (lo + hi)
                    pl = planner.plan_buckets(n, [mid], cp,
                                              bits_candidates=BITS_GRID)[0]
                    if pl.detail["bits"] < 32:
                        hi = mid
                    else:
                        lo = mid
                boundary = hi
        rows.append({"n": n, "buckets": per_bucket,
                     "decline_boundary_bytes": boundary,
                     "any_declined": bool(declined),
                     "any_compressed": bool(compressed)})
    return rows


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` CSV harness."""
    p = sm.OpticalParams()
    cp = planner.CostParams.optical()
    out = []
    for row in measure_compressed_frontier(ns=QUICK_NS, p=p, cp=cp):
        for bits, cell in row["widths"].items():
            out.append({
                "name": f"compressed_frontier_n{row['n']}_b{bits}",
                "us_per_call": 0.0,
                "derived": {"crossover_d_bits": cell["crossover_d_bits"],
                            "fp32_d_bits": row["fp32_crossover_d_bits"],
                            "moved_below_fp32": cell["moved_below_fp32"]},
            })
    for row in measure_tuner_decline(ns=(QUICK_NS[-1],), cp=cp):
        out.append({
            "name": f"tuner_decline_n{row['n']}",
            "us_per_call": 0.0,
            "derived": {"boundary_bytes": row["decline_boundary_bytes"],
                        "bits": [b["bits"] for b in row["buckets"]]},
        })
    return out


def main() -> None:
    quick = "--quick" in sys.argv
    ns = QUICK_NS if quick else NS
    p = sm.OpticalParams()
    cp = planner.CostParams.optical()
    payload = {
        "config": {
            "wavelengths": p.wavelengths,
            "bandwidth_bps": p.bandwidth_bps,
            "bits_grid": list(BITS_GRID),
            "quant_alpha_s": cp.quant_alpha_s,
            "quant_Bps": cp.quant_Bps,
            "quick": quick,
            "note": "d_bits are LOGICAL fp32 payload bits throughout; "
                    "compressed wire bytes shrink by bits/32 and the "
                    "quantize overhead is added where marked "
                    "(DESIGN.md §15)",
        },
        "rs_ag_vs_ar": measure_rs_ag_vs_ar(ns=ns, p=p, cp=cp),
        "compressed_frontier": measure_compressed_frontier(ns=ns, p=p,
                                                           cp=cp),
        "electrical_vs_optical": measure_electrical_vs_optical(ns=ns, p=p),
        "tuner_decline": measure_tuner_decline(ns=ns, cp=cp),
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_compression.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    for row in payload["compressed_frontier"]:
        fp32 = row["fp32_crossover_d_bits"]
        print(f"  N={row['n']:5d}: fp32 RS+AG-vs-AR crossover at "
              + (f"{fp32 / 8 / 1e6:.2f} MB" if fp32 else "none"))
        for bits, cell in row["widths"].items():
            cx = cell["crossover_d_bits"]
            print(f"           int{bits} frontier at "
                  + (f"{cx / 8 / 1e6:.2f} MB" if cx else "none")
                  + f" (moved_below_fp32={cell['moved_below_fp32']})")
    for row in payload["tuner_decline"]:
        b = row["decline_boundary_bytes"]
        print(f"  N={row['n']:5d}: tuner decline boundary at "
              + (f"{b / 1024:.1f} KB" if b else "none"))


if __name__ == "__main__":
    main()
