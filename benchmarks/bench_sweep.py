"""Sweep wall-clock: per-point ``run_optical`` vs the batched grid engine.

The paper's evaluation is a parameter sweep (four DNN payloads × four ring
sizes × four algorithms — now × three timing modes and an insertion-loss
frontier).  Before this engine existed every sweep point paid a full Python
walk over the step list; ``timing.evaluate_grid`` compiles each schedule to
a ``ScheduleProfile`` once and evaluates the whole payload axis per timing
mode in broadcasted NumPy (DESIGN.md §9).

``python -m benchmarks.bench_sweep`` runs the full measurement and writes
``BENCH_sweep.json`` at the repo root:

  * ``sweep``      — wall-clock of the two paths over an extended Fig.-4
    grid (payload axis densified to ``N_PAYLOADS`` sizes) plus the
    insertion-loss frontier, the speedup, and a cell-by-cell bit-identity
    check (``evaluate_grid`` must reproduce the per-point numbers exactly,
    not approximately).
  * ``tuner``      — ``timing.tune_wrht`` vs the analytic fan-out rule
    (Lemma 1 capped by the hop budget): chosen m, simulated times, and the
    win of the simulated argmin per cell.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the grid for CI smoke runs.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import simulator, step_models as sm, timing
from repro.core.topology import PhysicalParams
from repro.core.wavelength import InsertionLossError

ALGOS = ("wrht", "ring", "bt", "hring")
TIMINGS = ("lockstep", "event", "overlap")
N_PAYLOADS = 40


def payload_grid(n_payloads: int = N_PAYLOADS) -> list[float]:
    """Log-spaced payload axis bracketing the paper's four DNN gradients."""
    d = set(np.geomspace(1e6, 1e10, n_payloads - 4).tolist())
    d.update(sm.PAPER_MODELS_BITS.values())
    return sorted(d)


def _legacy_sweep(ns, payloads, timings, p) -> dict:
    """The pre-batching path: one ``run_optical`` call per grid point."""
    cells = {}
    for alg in ALGOS:
        for n in ns:
            try:
                for t in timings:
                    for d in payloads:
                        cells[(alg, n, t, d)] = simulator.run_optical(
                            alg, n, d, p, timing=t)
            except InsertionLossError:
                cells[(alg, n)] = None  # infeasible under the hop budget
    return cells


def _compare(legacy: dict, grid: timing.GridResult, ns, payloads, timings) -> int:
    """Count cells whose batched numbers are NOT bit-identical to legacy."""
    mismatches = 0
    for ai, alg in enumerate(ALGOS):
        for ni, n in enumerate(ns):
            if legacy.get((alg, n), "feasible") is None:
                if grid.feasible[ai, ni]:
                    mismatches += 1
                continue
            for t in timings:
                times = grid.cell(alg, n, t)
                if times is None:  # grid infeasible where legacy was not
                    mismatches += len(payloads)
                    continue
                for di, d in enumerate(payloads):
                    ref = legacy[(alg, n, t, d)]
                    got = times.sim_result(di)
                    if (got.total_s != ref.total_s
                            or got.serialization_s != ref.serialization_s
                            or got.reconfig_s != ref.reconfig_s
                            or got.steps != ref.steps
                            or got.max_wavelengths != ref.max_wavelengths):
                        mismatches += 1
    return mismatches


def measure_sweep(ns=(1024, 2048, 3072, 4096), n_payloads=N_PAYLOADS) -> dict:
    p = sm.OpticalParams()
    phys = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=2.0))
    payloads = payload_grid(n_payloads)

    # warm the schedule caches for BOTH paths (the per-point path had the
    # same lru-cached builders pre-PR), then drop the compiled profiles so
    # the batched measurement pays its own compile cost
    _legacy_sweep(ns, payloads[:1], ("lockstep",), p)
    _legacy_sweep(ns[:2], payloads[:1], ("lockstep",), phys)
    timing.clear_caches()

    t0 = time.perf_counter()
    legacy = _legacy_sweep(ns, payloads, TIMINGS, p)
    legacy_phys = _legacy_sweep(ns[:2], payloads, ("lockstep", "overlap"), phys)
    legacy_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    grid = timing.evaluate_grid(ALGOS, ns, payloads, TIMINGS, p,
                                keep_per_step=False)
    grid_phys = timing.evaluate_grid(ALGOS, ns[:2], payloads,
                                     ("lockstep", "overlap"), phys,
                                     keep_per_step=False)
    batched_s = time.perf_counter() - t0

    mismatches = _compare(legacy, grid, ns, payloads, TIMINGS)
    mismatches += _compare(legacy_phys, grid_phys, ns[:2], payloads,
                           ("lockstep", "overlap"))
    cells = (len(ALGOS) * len(ns) * len(TIMINGS) * len(payloads)
             + len(ALGOS) * len(ns[:2]) * 2 * len(payloads))
    return {
        "ns": list(ns),
        "payloads": len(payloads),
        "timings": list(TIMINGS),
        "grid_cells": cells,
        "legacy_s": round(legacy_s, 4),
        "batched_s": round(batched_s, 4),
        "speedup": round(legacy_s / batched_s, 1),
        "bit_identical": mismatches == 0,
        "mismatched_cells": mismatches,
    }


def measure_tuner(cells=((1024, 64, None), (1024, 16, 16), (4096, 64, None))) -> list[dict]:
    """``tune_wrht`` argmin vs the analytic fan-out rule per (n, w, H)."""
    d = sm.PAPER_MODELS_BITS["ResNet50"]
    out = []
    for n, w, max_hops in cells:
        t0 = time.perf_counter()
        tr = timing.tune_wrht(n, w, d, max_hops)
        tune_s = time.perf_counter() - t0
        m_best, a2a = tr.best(0)
        # the sweep caps candidates at n; m >= n all share one schedule, so
        # min(analytic_m, n) is the analytic pick's representative row
        analytic_pick = min(tr.analytic_m, n)
        analytic_idx = [i for i, (m, _) in enumerate(tr.candidates)
                        if m == analytic_pick]
        analytic_total = float(tr.total_s[analytic_idx[0], 0])
        best_total = float(tr.best_total_s[0])
        out.append({
            "n": n,
            "w": w,
            "max_hops": max_hops,
            "candidates": len(tr.candidates),
            "tuned_m": m_best,
            "tuned_alltoall": a2a,
            "analytic_m": tr.analytic_m,
            "tuned_ms": round(best_total * 1e3, 4),
            "analytic_ms": round(analytic_total * 1e3, 4),
            "tuner_win_pct": round(100 * (1 - best_total / analytic_total), 3),
            "tune_wall_s": round(tune_s, 3),
        })
    return out


def sweep(quick: bool = False) -> dict:
    if quick:
        result = measure_sweep(ns=(256, 512), n_payloads=12)
        tuner = measure_tuner(cells=((256, 16, None), (256, 16, 8)))
    else:
        result = measure_sweep()
        tuner = measure_tuner()
    return {
        "benchmark": "sweep_wallclock",
        "quick": quick,
        "sweep": result,
        "tuner": tuner,
    }


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` harness (CI smoke)."""
    r = measure_sweep(ns=(256,), n_payloads=8)
    t = measure_tuner(cells=((256, 16, None),))[0]
    return [
        {
            "name": "sweep/legacy_vs_batched/N=256",
            "us_per_call": r["batched_s"] * 1e6 / r["grid_cells"],
            "derived": {k: r[k] for k in
                        ("grid_cells", "legacy_s", "batched_s", "speedup",
                         "bit_identical")},
        },
        {
            "name": "sweep/tune_wrht/N=256/w=16",
            "us_per_call": t["tune_wall_s"] * 1e6,
            "derived": {k: t[k] for k in
                        ("candidates", "tuned_m", "tuned_alltoall",
                         "analytic_m", "tuned_ms", "analytic_ms",
                         "tuner_win_pct")},
        },
    ]


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    result = sweep(quick=quick)
    path = Path(__file__).resolve().parents[1] / "BENCH_sweep.json"
    path.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {path}")
    s = result["sweep"]
    print(f"sweep: {s['grid_cells']} cells  legacy={s['legacy_s']}s  "
          f"batched={s['batched_s']}s  speedup={s['speedup']}x  "
          f"bit_identical={s['bit_identical']}")
    for t in result["tuner"]:
        print(f"tune n={t['n']} w={t['w']} H={t['max_hops']}: "
              f"m={t['tuned_m']} (analytic {t['analytic_m']}) "
              f"win={t['tuner_win_pct']}%  [{t['candidates']} candidates, "
              f"{t['tune_wall_s']}s]")


if __name__ == "__main__":
    main()
