"""Schedule-construction benchmark: vectorized engine vs reference greedy.

Tracks the cost of ``wrht.build_schedule`` — the repo's planning hot path —
from this PR on.  ``python -m benchmarks.bench_schedule_build`` runs the full
sweep (N up to 32768) and writes ``BENCH_schedule.json`` at the repo root;
``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness.

Per (n, w) cell it reports:
  build_s          vectorized build, no validation (the RWA itself)
  validate_s       structural + semantic validation of the built schedule
  reference_s      the original per-object First-Fit build (seed behaviour),
                   measured only up to ``REFERENCE_MAX_N`` (it is >10 s above)
  speedup          reference_s / build_s
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import wrht
from repro.core.topology import Ring

SWEEP = [(1024, 32), (4096, 32), (8192, 32), (16384, 32), (32768, 32)]
REFERENCE_MAX_N = 8192
REPEATS = 3


def _best(fn, repeats: int = REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_cell(n: int, w: int, measure_reference: bool = True) -> dict:
    build_s = _best(lambda: wrht.build_schedule(n, w, 1.0, validate=False))
    sched = wrht.build_schedule(n, w, 1.0, validate=False)
    ring = Ring(n, w)
    validate_s = _best(lambda: wrht.validate_schedule(sched, ring))
    cell = {
        "n": n,
        "w": w,
        "m": sched.m,
        "steps": sched.num_steps,
        "build_s": round(build_s, 6),
        "validate_s": round(validate_s, 6),
        "build_validate_s": round(build_s + validate_s, 6),
    }
    if measure_reference and n <= REFERENCE_MAX_N:
        ref_s = _best(
            lambda: wrht.build_schedule(n, w, 1.0, validate=False, rwa="reference"),
            repeats=1,
        )
        cell["reference_s"] = round(ref_s, 6)
        cell["speedup"] = round(ref_s / build_s, 1)
    return cell


def sweep(cells=SWEEP, measure_reference: bool = True) -> dict:
    return {
        "benchmark": "wrht.build_schedule",
        "unit": "seconds (best of 3)",
        "reference": "first_fit_assign_reference (seed per-object greedy), "
                     f"measured for N <= {REFERENCE_MAX_N}",
        "cells": [bench_cell(n, w, measure_reference) for n, w in cells],
    }


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` CSV harness / CI smoke."""
    out = []
    for n, w in [(1024, 32), (4096, 32)]:
        cell = bench_cell(n, w, measure_reference=(n <= 1024))
        derived = {k: cell[k] for k in ("steps", "build_s", "build_validate_s")}
        if "speedup" in cell:
            derived["speedup"] = cell["speedup"]
        out.append({
            "name": f"schedule_build/N={n},w={w}",
            "us_per_call": cell["build_s"] * 1e6,
            "derived": derived,
        })
    return out


def main() -> None:
    result = sweep()
    out = Path(__file__).resolve().parents[1] / "BENCH_schedule.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps(result, indent=2))
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
