"""Degraded-mode wall-clock: collective time and online re-plan latency as a
function of injected failure count (DESIGN.md §12, EXPERIMENTS.md §Degraded).

Written to ``BENCH_degraded.json`` by ``python -m benchmarks.bench_degraded``:

* ``allreduce`` — event-timed WRHT all-reduce under ``k = 0..8`` injected
  failures (alternating cut fiber spans on the CW lane and dead wavelengths
  piled on one node — the per-node λ loss is what actually shrinks the
  Lemma-1 group size) at ``N = 64..1024``.  Each cell is re-tuned under the
  mask (``timing.tune_wrht(failures=...)``), so the number is the best the
  degraded fabric can do, not the healthy schedule limping; the degradation
  ratio vs the ``k=0`` baseline is recorded per cell.  Cells the mask makes
  infeasible are recorded as such, never skipped silently.
* ``ring_pass`` — the reduce-scatter ring pass under the same masks
  (``planned_sharded``'s bandwidth phase).  Rerouted neighbour hops can
  exceed the wavelength budget at larger N; those cells report infeasible,
  which is exactly when the planner falls back to other strategies.
* ``replan`` — the trainer-facing number: wall-clock latency of
  ``SyncController.replan(mask)`` (the full ``plan_gradient_sync`` re-run
  under the mask that feeds new strategy codes into the already-compiled
  step with no retrace) with a DP axis of N nodes, plus the exact simulated
  planner's batched ``plan_buckets`` latency for N ≤ 256.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the grid for the CI smoke run (the workflow uploads the
JSON as an artifact).
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core import planner, step_models as sm, timing, wrht
from repro.core.topology import FailureMask, PhysicalParams
from repro.train import train_step as TS

NS = (64, 256, 1024)
QUICK_NS = (64,)
KS = tuple(range(9))                      # 0..8 injected failures
QUICK_KS = (0, 1, 2, 4, 8)
W = 64
D_BITS = sm.PAPER_MODELS_BITS["ResNet50"]
# bounded fan-out sweep (the planner's own candidate set + two larger trees)
M_CANDIDATES = (2, 3, 4, 8, 16, 32)


def mask_of(k: int, n: int) -> FailureMask:
    """Deterministic k-failure mask: even draws cut a CW fiber span (spread
    around the ring so the CCW fiber keeps everything routable), odd draws
    kill one more wavelength at node 0 (stacking per-node λ loss, the term
    that shrinks the feasible group size)."""
    segs, lams = [], []
    for i in range(k):
        if i % 2 == 0:
            segs.append((0, (i // 2) * max(1, n // 8) % n))
        else:
            lams.append((0, i // 2))
    return FailureMask(dead_segments=tuple(segs),
                       dead_wavelengths=tuple(lams))


def _optical() -> sm.OpticalParams:
    # the event engine + per-hop physics make reroute detours cost real
    # time; lockstep would hide lane flips entirely
    return sm.OpticalParams(wavelengths=W, physical=PhysicalParams())


def measure_allreduce(ns=NS, ks=KS) -> list[dict]:
    p = _optical()
    rows = []
    for n in ns:
        base = None
        for k in ks:
            mask = mask_of(k, n)
            t0 = time.perf_counter()
            try:
                tuned = timing.tune_wrht(n, W, D_BITS, p=p, timing="event",
                                         m_candidates=M_CANDIDATES,
                                         failures=mask)
            except (wrht.DegradedInfeasibleError, ValueError) as e:
                rows.append({"n": n, "failures": k, "feasible": False,
                             "reason": str(e)})
                continue
            tune_s = time.perf_counter() - t0
            best = float(tuned.best_total_s[0])
            m, a2a = tuned.best(0)
            if k == 0:
                base = best
            rows.append({
                "n": n, "failures": k, "feasible": True,
                "total_s": best, "best_m": m, "best_alltoall": a2a,
                "tune_s": tune_s,
                "degradation": (best / base) if base else None,
            })
    return rows


def measure_ring_pass(ns=NS, ks=KS) -> list[dict]:
    p = _optical()
    rows = []
    d = np.asarray([D_BITS])
    for n in ns:
        base = None
        for k in ks:
            mask = mask_of(k, n)
            try:
                t = timing.collective_times("reduce_scatter", n, d, p,
                                            timing="event",
                                            keep_per_step=False,
                                            failures=mask)
            except wrht.DegradedInfeasibleError as e:
                rows.append({"n": n, "failures": k, "feasible": False,
                             "reason": str(e)})
                continue
            best = float(np.asarray(t.total_s)[0])
            if k == 0:
                base = best
            rows.append({
                "n": n, "failures": k, "feasible": True, "total_s": best,
                "degradation": (best / base) if base else None,
            })
    return rows


class _AxisMesh:
    """Named-axis stub: the planner only reads axis_names and shape."""

    axis_names = ("data",)

    def __init__(self, n: int) -> None:
        self.shape = {"data": n}


def _abstract_grads():
    return {k: jax.ShapeDtypeStruct((n,), jnp.float32)
            for k, n in (("qkv", 1 << 16), ("mlp", 1 << 20),
                         ("emb", 1 << 22))}


def measure_replan(ns=NS, ks=KS, repeats: int = 3) -> list[dict]:
    tc = TrainConfig(sync_algorithm="planned_sharded", bucket_bytes=1 << 22)
    rows = []
    for n in ns:
        ctrl = TS.SyncController(_abstract_grads(), tc, _AxisMesh(n))
        n_buckets = sum(len(v) for v in ctrl.plans.rs_plans.values())
        for k in ks:
            mask = mask_of(k, n)
            lat = []
            for _ in range(repeats):
                ctrl.replan(mask if k else None)
                lat.append(ctrl.last_replan_s)
            row = {"n": n, "failures": k, "buckets": n_buckets,
                   "replan_ms": 1e3 * min(lat)}
            if n <= 256:
                sizes = [1 << 18, 1 << 22, 1 << 24]
                t0 = time.perf_counter()
                try:
                    planner.plan_buckets(n, sizes, backend="simulated",
                                         collective="reduce_scatter",
                                         failures=mask if k else None)
                    row["simulated_plan_ms"] = 1e3 * (time.perf_counter() - t0)
                except wrht.DegradedInfeasibleError:
                    row["simulated_plan_ms"] = None
            rows.append(row)
    return rows


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` CSV harness."""
    out = []
    for row in measure_allreduce(ns=QUICK_NS, ks=QUICK_KS):
        if row["feasible"]:
            out.append({
                "name": f"degraded_allreduce_n{row['n']}_k{row['failures']}",
                "us_per_call": row["total_s"] * 1e6,
                "derived": {"degradation": row["degradation"],
                            "best_m": row["best_m"]},
            })
    for row in measure_replan(ns=QUICK_NS, ks=(0, 8), repeats=1):
        out.append({
            "name": f"degraded_replan_n{row['n']}_k{row['failures']}",
            "us_per_call": row["replan_ms"] * 1e3,
            "derived": {"buckets": row["buckets"]},
        })
    return out


def main() -> None:
    quick = "--quick" in sys.argv
    ns = QUICK_NS if quick else NS
    ks = QUICK_KS if quick else KS
    payload = {
        "config": {
            "wavelengths": W,
            "d_bits": D_BITS,
            "timing": "event",
            "m_candidates": list(M_CANDIDATES),
            "mask": "k alternating: CW span cuts spread n/8 apart; "
                    "dead λs stacked on node 0",
            "quick": quick,
            "note": "allreduce cells are re-tuned under each mask; "
                    "infeasible cells are recorded, not skipped.  The "
                    "simulated planner runs at the CostParams-derived "
                    "fabric (w = links/2), so stacked per-node λ loss can "
                    "be genuinely infeasible there (simulated_plan_ms "
                    "null) while the w=64 timing cells still route",
        },
        "allreduce": measure_allreduce(ns=ns, ks=ks),
        "ring_pass": measure_ring_pass(ns=ns, ks=ks),
        "replan": measure_replan(ns=ns, ks=ks),
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_degraded.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    for row in payload["allreduce"]:
        if row["feasible"]:
            print(f"  N={row['n']:5d} k={row['failures']}: "
                  f"{row['total_s'] * 1e3:8.3f} ms  "
                  f"(x{row['degradation']:.3f} vs healthy, "
                  f"m={row['best_m']}, a2a={row['best_alltoall']})")
        else:
            print(f"  N={row['n']:5d} k={row['failures']}: infeasible")
    for row in payload["replan"]:
        sim = row.get("simulated_plan_ms")
        print(f"  replan N={row['n']:5d} k={row['failures']}: "
              f"{row['replan_ms']:7.2f} ms analytic"
              + (f", {sim:7.2f} ms simulated" if sim else ""))


if __name__ == "__main__":
    main()
