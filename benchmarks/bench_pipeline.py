"""Pipelined multi-collective overlap: composed RS/AG interleavings vs the
serial schedule sequence (DESIGN.md §13, EXPERIMENTS.md §Pipelined).

Written to ``BENCH_pipeline.json`` by ``python -m benchmarks.bench_pipeline``:

* ``overlap`` — one composed pipeline (``compose.build_pipeline_schedule``)
  of ``depth`` alternating RS/AG ring passes at full payload, event-timed
  against the sum of its constituents run serially, for ``N = 64..1024`` and
  ``depth = 1..4``.  Records the fused/serialized slot split (how much of
  the interleaving the fused-RWA pass actually accepted) and the overlap
  win ``1 - composed/serial``.  depth=1 is the degenerate case and must
  report exactly 0 win — the composed path is bit-identical to the plain
  schedule there.
* ``step`` — the end-to-end number: a model's gradient buckets synced
  RS-down/AG-up per bucket (``planned_sharded``, serial) vs the
  software-pipelined bucket stream (``planned_pipelined``) where bucket
  k+1's RS rides the same composed schedule as bucket k's AG.  Pipelined
  totals use the planner's own amortized model (composed total / depth per
  constituent, 2 constituents per bucket), so the reduction shown is
  exactly what ``planner.plan_buckets(depth=...)`` trades on.
* ``planner`` — ``plan_buckets(collective="reduce_scatter", depth=...)``
  on both backends: per-bucket composed-vs-serial gain and whether the
  composed plan won (``detail["pipeline"]``), plus planning wall-clock.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the grid for the CI smoke run (the workflow uploads the
JSON as an artifact).
"""

from __future__ import annotations

import json
import math
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import compose, planner, step_models as sm, timing, wrht
from repro.core.topology import PhysicalParams

NS = (64, 256, 1024)
QUICK_NS = (64,)
DEPTHS = (1, 2, 3, 4)
QUICK_DEPTHS = (1, 2)
W = 64
D_BITS = sm.PAPER_MODELS_BITS["ResNet50"]
BUCKET_BITS = 32 * 2**20 * 8            # 32 MB buckets, in bits


def _optical() -> sm.OpticalParams:
    return sm.OpticalParams(wavelengths=W, physical=PhysicalParams())


def _serial_total(n: int, d, p, depth: int) -> float:
    """Sum of the pipeline's constituents each run as its own schedule."""
    total = 0.0
    for c in compose.pipeline_collectives("reduce_scatter", depth):
        t = timing.collective_times(c, n, d, p, timing="event",
                                    keep_per_step=False)
        total += float(np.asarray(t.total_s)[0])
    return total


def measure_overlap(ns=NS, depths=DEPTHS) -> list[dict]:
    p = _optical()
    d = np.asarray([float(D_BITS)])
    rows = []
    for n in ns:
        for depth in depths:
            t0 = time.perf_counter()
            composed = compose.build_pipeline_schedule(
                "reduce_scatter", n, W, float(D_BITS), depth)
            build_s = time.perf_counter() - t0
            t = timing.collective_times("reduce_scatter", n, d, p,
                                        timing="event", keep_per_step=False,
                                        depth=depth)
            composed_s = float(np.asarray(t.total_s)[0])
            serial_s = _serial_total(n, d, p, depth)
            rows.append({
                "n": n, "depth": depth,
                "composed_s": composed_s, "serial_s": serial_s,
                "win": 1.0 - composed_s / serial_s,
                "slots": composed.num_steps,
                "serial_slots": composed.serial_steps,
                "fused_slots": composed.fused_steps,
                "slots_saved": composed.slots_saved,
                "build_s": build_s,
            })
    return rows


def _bucket_bits() -> list[float]:
    """The model's gradient vector cut into 32 MB buckets (last one ragged)."""
    n_buckets = math.ceil(D_BITS / BUCKET_BITS)
    full = [float(BUCKET_BITS)] * (n_buckets - 1)
    return full + [float(D_BITS - BUCKET_BITS * (n_buckets - 1))]


def measure_step(ns=NS, depths=DEPTHS) -> list[dict]:
    p = _optical()
    rows = []
    buckets = _bucket_bits()
    for n in ns:
        serial_total = 0.0
        for b in buckets:
            d = np.asarray([b])
            for c in ("reduce_scatter", "all_gather"):
                t = timing.collective_times(c, n, d, p, timing="event",
                                            keep_per_step=False)
                serial_total += float(np.asarray(t.total_s)[0])
        for depth in depths:
            if depth == 1:
                pipe_total = serial_total
            else:
                pipe_total = 0.0
                for b in buckets:
                    d = np.asarray([b])
                    t = timing.collective_times(
                        "reduce_scatter", n, d, p, timing="event",
                        keep_per_step=False, depth=depth)
                    # each bucket contributes 2 constituents (RS + AG) at
                    # the amortized composed rate — the planner's cost model
                    pipe_total += 2.0 * float(np.asarray(t.total_s)[0]) / depth
            rows.append({
                "n": n, "depth": depth, "buckets": len(buckets),
                "serial_step_s": serial_total, "pipelined_step_s": pipe_total,
                "reduction": 1.0 - pipe_total / serial_total,
            })
    return rows


def measure_planner(ns=NS, depths=DEPTHS) -> list[dict]:
    rows = []
    sizes = [b / 8 for b in _bucket_bits()]    # planner wants bytes
    for backend in ("analytic", "simulated"):
        for n in ns:
            if backend == "simulated" and n > 256:
                continue
            for depth in depths:
                t0 = time.perf_counter()
                try:
                    plans = planner.plan_buckets(
                        n, sizes, backend=backend,
                        collective="reduce_scatter", depth=depth)
                except wrht.DegradedInfeasibleError as e:
                    rows.append({"backend": backend, "n": n, "depth": depth,
                                 "feasible": False, "reason": str(e)})
                    continue
                plan_s = time.perf_counter() - t0
                pipe = [pl.detail.get("pipeline") for pl in plans]
                rows.append({
                    "backend": backend, "n": n, "depth": depth,
                    "feasible": True, "plan_ms": 1e3 * plan_s,
                    "composed_wins": sum(1 for q in pipe
                                         if q and q.get("composed")),
                    "buckets": len(plans),
                    "gains": [round(q["gain"], 4) if q and "gain" in q
                              else None for q in pipe],
                })
    return rows


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` CSV harness."""
    out = []
    for row in measure_overlap(ns=QUICK_NS, depths=QUICK_DEPTHS):
        out.append({
            "name": f"pipeline_overlap_n{row['n']}_d{row['depth']}",
            "us_per_call": row["composed_s"] * 1e6,
            "derived": {"win": round(row["win"], 4),
                        "fused_slots": row["fused_slots"],
                        "slots_saved": row["slots_saved"]},
        })
    for row in measure_step(ns=QUICK_NS, depths=QUICK_DEPTHS):
        out.append({
            "name": f"pipeline_step_n{row['n']}_d{row['depth']}",
            "us_per_call": row["pipelined_step_s"] * 1e6,
            "derived": {"reduction": round(row["reduction"], 4),
                        "buckets": row["buckets"]},
        })
    return out


def main() -> None:
    quick = "--quick" in sys.argv
    ns = QUICK_NS if quick else NS
    depths = QUICK_DEPTHS if quick else DEPTHS
    payload = {
        "config": {
            "wavelengths": W,
            "d_bits": D_BITS,
            "bucket_bits": BUCKET_BITS,
            "timing": "event",
            "pipeline": "alternating reduce_scatter/all_gather ring passes "
                        "(compose.pipeline_collectives)",
            "quick": quick,
            "note": "overlap rows time ONE composed schedule vs its "
                    "constituents run back-to-back; step rows amortize the "
                    "composed total over its constituents (the planner's "
                    "cost model) across the model's bucket stream, so the "
                    "reduction is what planned_pipelined is costed to save "
                    "over planned_sharded.  depth=1 must show win == 0: "
                    "composition is bit-identical to the plain schedule.",
        },
        "overlap": measure_overlap(ns=ns, depths=depths),
        "step": measure_step(ns=ns, depths=depths),
        "planner": measure_planner(ns=ns, depths=depths),
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_pipeline.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    for row in payload["overlap"]:
        print(f"  N={row['n']:5d} depth={row['depth']}: "
              f"composed {row['composed_s'] * 1e3:8.3f} ms vs serial "
              f"{row['serial_s'] * 1e3:8.3f} ms  (win {row['win']:+.3f}, "
              f"{row['fused_slots']}/{row['slots']} slots fused)")
    for row in payload["step"]:
        print(f"  step N={row['n']:5d} depth={row['depth']}: "
              f"{row['pipelined_step_s'] * 1e3:8.3f} ms pipelined vs "
              f"{row['serial_step_s'] * 1e3:8.3f} ms serial "
              f"({row['reduction']:+.1%})")


if __name__ == "__main__":
    main()
