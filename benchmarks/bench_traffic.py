"""Multi-tenant ring contention: p50/p99 collective latency vs offered load
(DESIGN.md §16, EXPERIMENTS.md §Traffic).

Written to ``BENCH_traffic.json`` by ``python -m benchmarks.bench_traffic``:

* ``load_sweep`` — one fixed Poisson arrival trace (two training tenants'
  all-reduces + one serving tenant's all-gathers) compressed/dilated by
  ``traffic.scale_jobs`` across offered loads, served under both wavelength
  policies.  Same sample path at every load, so p99 must grow monotonically
  with load per policy — the CI smoke asserts it.  Records p50/p99/mean
  overall and per tenant, fusion accounting (groups fused, slots saved) and
  the plan-memo hit/miss split.
* ``zero_load`` — the acceptance anchor: a single tenant's lone job under
  either policy must time *bit-identically* to ``simulate_composed`` on the
  same schedule (depth-1 composition reuses the original Step objects).
  ``bit_identical`` is an exact ``==``, not an approx.
* ``serving`` — the serve-engine bridge: rounds of a
  ``qwen2-1.5b``-configured engine (synthetic ``RoundStats``; the live
  ``Engine.round_log`` path is pinned in ``tests/test_serve.py``) become
  KV/activation-sized all-gathers via ``ServingTrafficSource``, measured
  alone, sharing the pool with training, and λ-partitioned from it —
  the isolation-vs-utilization trade the two policies embody.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the sweep for the CI smoke (the workflow uploads the
JSON as an artifact).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.configs import registry
from repro.core import compose, simulator, step_models as sm, traffic, wrht
from repro.serve.engine import RoundStats

N = 64
W = 64
MB = 2**20 * 8.0
LOADS = (0.25, 0.5, 1.0, 2.0, 4.0)
QUICK_LOADS = (0.25, 1.0, 4.0)
HORIZON_S = 1.0
QUICK_HORIZON_S = 0.4
SEED = 17
SERVE_ARCH = "qwen2-1.5b"
ROUNDS = 32          # synthetic serving rounds fed to ServingTrafficSource
ROUND_PERIOD_S = 2e-3


def _optical() -> sm.OpticalParams:
    return sm.OpticalParams(wavelengths=W)


def _tenants() -> list[traffic.TenantSpec]:
    """Two training tenants + one serving tenant, rates sized so load 1.0
    sits near the ring's fused service capacity."""
    return [
        traffic.TenantSpec("train-a", rate_hz=30.0, d_bits=32 * MB),
        traffic.TenantSpec("train-b", rate_hz=30.0, d_bits=8 * MB),
        traffic.TenantSpec("serve", rate_hz=60.0, d_bits=2 * MB,
                           collective="all_gather"),
    ]


def measure_load_sweep(loads=LOADS, horizon_s=HORIZON_S) -> list[dict]:
    tenants = _tenants()
    base = traffic.PoissonSource(tenants, seed=SEED).jobs(horizon_s)
    rows = []
    for policy in traffic.POLICIES:
        for load in loads:
            sim = traffic.RingTrafficSim(N, _optical(), policy=policy)
            res = sim.run(traffic.scale_jobs(base, load), tenants=tenants)
            row = {"load": load, **res.summary()}
            rows.append(row)
    return rows


def measure_zero_load() -> list[dict]:
    """One tenant, one job, idle ring: the traffic path must reduce to the
    single-job composed simulation exactly."""
    d = 32 * MB
    p = _optical()
    sched = wrht.build_collective_schedule("allreduce", N, W, d,
                                           validate=False)
    direct = simulator.simulate_composed(
        compose.compose_schedules([sched]), d, p).total_s
    rows = []
    for policy in traffic.POLICIES:
        sim = traffic.RingTrafficSim(N, p, policy=policy)
        res = sim.run([traffic.CollectiveJob("solo", 0.0, "allreduce", d)])
        lat = res.jobs[0].latency_s
        rows.append({
            "policy": policy, "d_bits": d,
            "traffic_s": lat, "simulate_composed_s": float(direct),
            "bit_identical": lat == direct,
        })
    return rows


def _serve_jobs(horizon_s: float) -> list[traffic.CollectiveJob]:
    cfg = registry.get(SERVE_ARCH)
    log = [RoundStats(admitted=4, batch=4, prefill_len=128, decode_steps=64)
           for _ in range(ROUNDS)]
    src = traffic.ServingTrafficSource(cfg, log,
                                       round_period_s=ROUND_PERIOD_S)
    return src.jobs(horizon_s)


def measure_serving(horizon_s=HORIZON_S) -> dict:
    """Three tenants (serve + two training jobs' streams) so the
    partitioned policy's λ split is non-trivial: at K=2 the n=64
    collectives fit either half-pool unchanged (allreduce peaks at 32 λ,
    the all_gather ring pass at 1) and both policies time identically;
    at K=3 the 21-λ slice stretches the all-reduce and the isolation
    cost shows up."""
    serve_jobs = _serve_jobs(horizon_s)
    train = [traffic.TenantSpec("train", rate_hz=40.0, d_bits=32 * MB),
             traffic.TenantSpec("train-b", rate_hz=40.0, d_bits=8 * MB)]
    train_jobs = traffic.PoissonSource(train, seed=SEED + 1).jobs(horizon_s)
    mixed = sorted(serve_jobs + train_jobs,
                   key=lambda j: (j.arrival_s, j.tenant))

    alone = traffic.RingTrafficSim(N, _optical(), policy="shared") \
        .run(serve_jobs)
    cells = {"serve_alone": {"p50_s": alone.percentile(50),
                             "p99_s": alone.percentile(99),
                             "jobs": len(alone.jobs)}}
    for policy in traffic.POLICIES:
        sim = traffic.RingTrafficSim(N, _optical(), policy=policy)
        res = sim.run(mixed)
        cells[f"mixed_{policy}"] = {
            "serve_p50_s": res.percentile(50, "serve"),
            "serve_p99_s": res.percentile(99, "serve"),
            "train_p99_s": res.percentile(99, "train"),
            "train_b_p99_s": res.percentile(99, "train-b"),
            "fused_groups": sum(1 for g in res.groups if len(g.jobs) > 1),
        }
        cells[f"mixed_{policy}"]["serve_p99_interference"] = (
            cells[f"mixed_{policy}"]["serve_p99_s"]
            / cells["serve_alone"]["p99_s"])
    cfg = registry.get(SERVE_ARCH)
    cells["shapes"] = {
        "arch": SERVE_ARCH,
        "kv_bits_per_token": traffic.kv_bits_per_token(cfg),
        "activation_bits_per_token": traffic.activation_bits_per_token(cfg),
        "rounds": ROUNDS, "round_period_s": ROUND_PERIOD_S,
    }
    return cells


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` CSV harness."""
    out = []
    for row in measure_load_sweep(loads=QUICK_LOADS,
                                  horizon_s=QUICK_HORIZON_S):
        out.append({
            "name": f"traffic_{row['policy']}_load{row['load']:g}",
            "us_per_call": row["p99_s"] * 1e6,
            "derived": {"p50_ms": round(row["p50_s"] * 1e3, 3),
                        "p99_ms": round(row["p99_s"] * 1e3, 3),
                        "fused_groups": row["fused_groups"],
                        "slots_saved": row["slots_saved"]},
        })
    for row in measure_zero_load():
        out.append({
            "name": f"traffic_zero_load_{row['policy']}",
            "us_per_call": row["traffic_s"] * 1e6,
            "derived": {"bit_identical": row["bit_identical"]},
        })
    return out


def main() -> None:
    quick = "--quick" in sys.argv
    loads = QUICK_LOADS if quick else LOADS
    horizon_s = QUICK_HORIZON_S if quick else HORIZON_S
    payload = {
        "config": {
            "n": N, "wavelengths": W, "seed": SEED,
            "horizon_s": horizon_s, "loads": list(loads),
            "tenants": [{"name": t.name, "rate_hz": t.rate_hz,
                         "d_bits": t.d_bits, "collective": t.collective}
                        for t in _tenants()],
            "quick": quick,
            "note": "load_sweep scales ONE fixed arrival trace by 1/load "
                    "(traffic.scale_jobs), so p99 is monotone in load along "
                    "the same sample path per policy.  zero_load must be "
                    "bit_identical: an uncontended job composes depth-1 and "
                    "reuses the original Step objects, so its latency IS "
                    "simulate_composed on that schedule.",
        },
        "load_sweep": measure_load_sweep(loads=loads, horizon_s=horizon_s),
        "zero_load": measure_zero_load(),
        "serving": measure_serving(horizon_s=horizon_s),
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_traffic.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    for row in payload["load_sweep"]:
        print(f"  {row['policy']:12s} load={row['load']:<5g} "
              f"p50 {row['p50_s'] * 1e3:9.3f} ms  "
              f"p99 {row['p99_s'] * 1e3:9.3f} ms  "
              f"({row['jobs']} jobs, {row['fused_groups']} fused groups, "
              f"{row['slots_saved']} slots saved)")
    for row in payload["zero_load"]:
        print(f"  zero-load {row['policy']:12s} "
              f"{row['traffic_s'] * 1e3:.6f} ms "
              f"bit_identical={row['bit_identical']}")
    s = payload["serving"]
    print(f"  serve alone p99 {s['serve_alone']['p99_s'] * 1e3:.3f} ms; "
          f"vs train shared ×{s['mixed_shared']['serve_p99_interference']:.2f}, "
          f"partitioned ×{s['mixed_partitioned']['serve_p99_interference']:.2f}")


if __name__ == "__main__":
    main()
