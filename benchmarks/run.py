"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Modules:
  table1_steps       paper Table I step counts
  fig4_optical       paper Fig. 4 (optical ring comparison)
  fig5_electrical    paper Fig. 5 (electrical vs optical)
  planner_crossover  beyond-paper alpha-beta planner behaviour
  roofline           aggregated dry-run roofline terms (reads experiments/)
  schedule_build     WRHT schedule-construction cost (full sweep writes
                     BENCH_schedule.json via `python -m benchmarks.bench_schedule_build`)
  insertion_loss     insertion-loss feasibility frontier (full sweep writes
                     BENCH_insertion_loss.json via `python -m benchmarks.bench_insertion_loss`)
  sweep              per-point vs batched grid-evaluation wall-clock + WRHT
                     auto-tuner (full sweep writes BENCH_sweep.json via
                     `python -m benchmarks.bench_sweep`)
  planner_batch      amortized planning: batched tuner vs per-candidate loop
                     + plan-cache cold/warm throughput (full sweep writes
                     BENCH_planner.json via `python -m benchmarks.bench_planner`)
  collectives        scheduled collective algebra: per-collective times +
                     the RS+AG-vs-AR crossover (full sweep writes
                     BENCH_collectives.json via
                     `python -m benchmarks.bench_collectives`)
  degraded           failure-masked schedules: collective time + online
                     re-plan latency vs injected failure count (full sweep
                     writes BENCH_degraded.json via
                     `python -m benchmarks.bench_degraded`)
  pipeline           pipelined multi-collective overlap: composed RS/AG
                     interleavings vs serial, overlap + end-to-end step
                     reduction (full sweep writes BENCH_pipeline.json via
                     `python -m benchmarks.bench_pipeline`)
  storm              failure-storm survival: escalating nested masks vs the
                     composed pipeline (monotone degradation to the
                     infeasibility cliff) + hysteresis-vs-naive replan
                     counts (full sweep writes BENCH_storm.json via
                     `python -m benchmarks.bench_storm`)
  compression        bits-per-element planning frontiers: same-width and
                     overhead-included RS+AG-vs-AR crossovers at int8/int4,
                     Fig. 5 at compressed widths, and the per-bucket tuner
                     decline boundary (full sweep writes
                     BENCH_compression.json via
                     `python -m benchmarks.bench_compression`)
  traffic            multi-tenant ring contention: p50/p99 collective
                     latency vs offered load under shared vs partitioned
                     wavelength policies + the zero-load bit-identity
                     anchor (full sweep writes BENCH_traffic.json via
                     `python -m benchmarks.bench_traffic`)
"""

from __future__ import annotations

import json
import sys


def main() -> None:
    from . import (
        bench_collectives,
        bench_compression,
        bench_degraded,
        bench_insertion_loss,
        bench_pipeline,
        bench_planner,
        bench_schedule_build,
        bench_storm,
        bench_sweep,
        bench_traffic,
        fig4_optical,
        fig5_electrical,
        planner_crossover,
        roofline,
        table1_steps,
    )

    modules = {
        "table1_steps": table1_steps,
        "fig4_optical": fig4_optical,
        "fig5_electrical": fig5_electrical,
        "planner_crossover": planner_crossover,
        "roofline": roofline,
        "schedule_build": bench_schedule_build,
        "insertion_loss": bench_insertion_loss,
        "sweep": bench_sweep,
        "planner_batch": bench_planner,
        "collectives": bench_collectives,
        "degraded": bench_degraded,
        "pipeline": bench_pipeline,
        "storm": bench_storm,
        "compression": bench_compression,
        "traffic": bench_traffic,
    }
    selected = sys.argv[1:] or list(modules)
    print("name,us_per_call,derived")
    for name in selected:
        mod = modules[name]
        for row in mod.rows():
            derived = row.get("derived", "")
            if isinstance(derived, (dict, list)):
                derived = json.dumps(derived, separators=(",", ":"))
            paper = row.get("paper")
            suffix = f",paper={paper}" if paper is not None else ""
            print(f"{row['name']},{row.get('us_per_call', 0.0):.1f},"
                  f"\"{derived}\"{suffix}")


if __name__ == "__main__":
    main()
