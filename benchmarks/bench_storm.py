"""Failure-storm survival: escalating masks against the composed pipeline
(DESIGN.md §14, EXPERIMENTS.md §Storms).

Written to ``BENCH_storm.json`` by ``python -m benchmarks.bench_storm``:

* ``storm`` — a nested ladder of failure masks (``storm_masks``: fleet-wide
  λ kills shrinking the pool one wavelength at a time, then a single-lane
  span cut, then its both-lane twin turning the ring into a line, then the
  second-to-last λ forcing full serialization, finally a severed ring)
  applied to the depth-2 ``planned_pipelined`` composed schedule
  (``compose.build_pipeline_schedule``).  Per stage: the event-timed
  composed sync total, its ratio vs the healthy stage, and the composer's
  fusion bookkeeping (``fused_steps`` / ``slots_saved`` /
  ``fusion_efficiency``) showing the serialization fallback engaging as
  the λ pool shrinks.  Because each stage's mask *covers* the previous
  one, the degraded plan space shrinks monotonically and the ratio must be
  non-decreasing — the graceful-degradation invariant CI asserts (no cliff
  before the severed stage, which must raise the uniform
  ``DegradedInfeasibleError`` and is recorded as ``feasible: false``,
  never skipped).
* ``flapping`` — the closed loop under transient faults: a flapping λ
  (``FlapSchedule.periodic``) driven through ``FaultManager`` with the
  hysteresis ``ReplanPolicy`` vs the naive one-replan-per-transition count
  (``FaultTimeline.transitions``), plus a slow flapper that the cooldown
  coalesces.  Replan counts must never exceed the naive count, and on the
  fast flapper must come out strictly below it.
* ``roundtrip`` — healthy→degraded→healed plan-swap latency through
  ``SyncController.replan``: the degrade leg re-runs the planner, the heal
  leg must be a memo hit (``last_replan_cached``) at near-zero latency.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the grid for the CI smoke run (the workflow uploads the
JSON as an artifact and asserts monotonicity + bounded flapping replans).
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core import compose, step_models as sm, timing, wrht
from repro.core.simulator import observe_faults
from repro.core.topology import (FailureMask, FaultTimeline, FlapSchedule,
                                 PhysicalParams)
from repro.runtime.fault_tolerance import FaultManager, ReplanPolicy
from repro.train import train_step as TS

NS = (64, 256)
QUICK_NS = (64,)
W = 8                                     # scarce pool — λ kills must bite
DEPTH = 2
D_BITS = sm.PAPER_MODELS_BITS["ResNet50"]
N_LAMBDA_STAGES = 6                       # λ kills before the span cuts


def storm_masks(n: int) -> list[FailureMask]:
    """The escalation ladder: a list of *nested* masks (each covers the
    previous), from healthy to a severed ring.

    Stages 1..6 kill one more wavelength fleet-wide each (the pool shrinks
    ``w`` → ``w-6``); stage 7 cuts one CW span (reroutes); stage 8 cuts its
    CCW twin (both lanes dead — the ring becomes a line); stage 9 kills the
    second-to-last λ (pool = 1, so the depth-2 composition has no disjoint
    wavelengths left and must fully serialize); stage 10 cuts both lanes of
    a second span, severing the ring (``DegradedInfeasibleError``).
    Nesting makes the degraded-time ratio provably monotone: every later
    stage's plan is also a valid plan for every earlier stage.
    """
    masks = [FailureMask()]

    def fleet(k: int) -> tuple[tuple[int, int], ...]:
        return tuple((node, lam) for lam in range(k) for node in range(n))

    for k in range(1, N_LAMBDA_STAGES + 1):
        masks.append(FailureMask(dead_wavelengths=fleet(k)))
    far, near = n // 2, n // 4
    masks.append(FailureMask(dead_wavelengths=fleet(N_LAMBDA_STAGES),
                             dead_segments=((0, far),)))
    masks.append(FailureMask(dead_wavelengths=fleet(N_LAMBDA_STAGES),
                             dead_segments=((0, far), (1, far))))
    masks.append(FailureMask(dead_wavelengths=fleet(N_LAMBDA_STAGES + 1),
                             dead_segments=((0, far), (1, far))))
    masks.append(FailureMask(
        dead_wavelengths=fleet(N_LAMBDA_STAGES + 1),
        dead_segments=((0, far), (1, far), (0, near), (1, near))))
    assert all(b.covers(a) for a, b in zip(masks, masks[1:]))
    return masks


def _optical() -> sm.OpticalParams:
    return sm.OpticalParams(wavelengths=W, physical=PhysicalParams())


def measure_storm(ns=NS, depth: int = DEPTH) -> list[dict]:
    p = _optical()
    d = np.asarray([float(D_BITS)])
    rows = []
    for n in ns:
        base = None
        for k, mask in enumerate(storm_masks(n)):
            failures = None if mask.empty else mask
            row = {"n": n, "intensity": k, "mask": mask.fingerprint(),
                   "dead_lambdas": len(mask.dead_wavelengths),
                   "dead_segments": len(mask.dead_segments)}
            try:
                t = timing.collective_times(
                    "reduce_scatter", n, d, p, timing="event",
                    keep_per_step=False, failures=failures, depth=depth)
                composed = compose.build_pipeline_schedule(
                    "reduce_scatter", n, W, float(D_BITS), depth,
                    failures=failures)
            except wrht.DegradedInfeasibleError as e:
                row.update(feasible=False, error="DegradedInfeasibleError",
                           reason=str(e))
                rows.append(row)
                continue
            total = float(np.asarray(t.total_s)[0])
            if k == 0:
                base = total
            row.update(feasible=True, total_s=total, ratio=total / base,
                       slots=composed.num_steps,
                       fused_steps=composed.fused_steps,
                       slots_saved=composed.slots_saved,
                       fusion_efficiency=composed.fusion_efficiency)
            rows.append(row)
    return rows


def measure_flapping(steps: int = 200) -> list[dict]:
    """Replan counts under transient faults: hysteresis vs naive."""
    rows = []
    cases = [
        ("fast_flap", FlapSchedule.periodic("wavelength", (0, 3), 2, 2),
         ReplanPolicy(confirm_k=3, recover_k=3, cooldown_steps=8)),
        ("slow_flap", FlapSchedule.periodic("wavelength", (0, 3), 30, 30),
         ReplanPolicy(confirm_k=3, recover_k=3, cooldown_steps=60)),
        ("permanent", FlapSchedule.permanent("wavelength", (0, 3), at=20),
         ReplanPolicy()),
    ]
    for name, flap, policy in cases:
        tl = FaultTimeline((flap,))
        mgr = FaultManager(lambda s, tl=tl: observe_faults(tl, s), policy)
        mgr.attach(lambda mask: None)     # count proposals, no planner here
        for s in range(steps):
            mgr.on_step(s)
        naive = tl.transitions(0, steps - 1)
        rows.append({
            "case": name, "steps": steps,
            "transitions": naive,
            "replans_naive": naive,
            "replans_hysteresis": mgr.replan_count,
            "policy": {"confirm_k": policy.confirm_k,
                       "recover_k": policy.recover_k,
                       "cooldown_steps": policy.cooldown_steps},
        })
    return rows


class _AxisMesh:
    axis_names = ("data",)

    def __init__(self, n: int) -> None:
        self.shape = {"data": n}


def _abstract_grads():
    return {k: jax.ShapeDtypeStruct((n,), jnp.float32)
            for k, n in (("qkv", 1 << 16), ("mlp", 1 << 20),
                         ("emb", 1 << 22))}


def measure_roundtrip(ns=NS, repeats: int = 3) -> list[dict]:
    """Healthy→degraded→healed plan-swap latency through the controller."""
    tc = TrainConfig(sync_algorithm="planned_pipelined", bucket_bytes=1 << 22)
    mask = FailureMask(dead_wavelengths=((0, 0), (0, 1)))
    rows = []
    for n in ns:
        ctrl = TS.SyncController(_abstract_grads(), tc, _AxisMesh(n))
        degrade_ms, heal_ms = [], []
        heal_cached = True
        for _ in range(repeats):
            ctrl._plan_memo.pop(ctrl._memo_key(mask), None)  # fresh degrade
            t0 = time.perf_counter()
            ctrl.replan(mask)
            degrade_ms.append(1e3 * (time.perf_counter() - t0))
            t0 = time.perf_counter()
            ctrl.replan(None)
            heal_ms.append(1e3 * (time.perf_counter() - t0))
            heal_cached = heal_cached and ctrl.last_replan_cached
        rows.append({"n": n, "degrade_ms": min(degrade_ms),
                     "heal_ms": min(heal_ms),
                     "roundtrip_ms": min(degrade_ms) + min(heal_ms),
                     "heal_cached": heal_cached})
    return rows


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` CSV harness."""
    out = []
    for row in measure_storm(ns=QUICK_NS):
        if row["feasible"]:
            out.append({
                "name": f"storm_n{row['n']}_k{row['intensity']}",
                "us_per_call": row["total_s"] * 1e6,
                "derived": {"ratio": row["ratio"],
                            "fusion_efficiency": row["fusion_efficiency"]},
            })
    for row in measure_flapping(steps=100):
        out.append({
            "name": f"storm_flap_{row['case']}",
            "us_per_call": 0.0,
            "derived": {"replans_hysteresis": row["replans_hysteresis"],
                        "replans_naive": row["replans_naive"]},
        })
    return out


def main() -> None:
    quick = "--quick" in sys.argv
    ns = QUICK_NS if quick else NS
    payload = {
        "config": {
            "wavelengths": W, "d_bits": D_BITS, "depth": DEPTH,
            "timing": "event", "quick": quick,
            "ladder": "nested masks: λs stacked on node 0, then span cuts "
                      "(single-lane -> both-lane line topology -> severed)",
            "note": "storm stages are nested (each mask covers the last), "
                    "so the degraded-time ratio is monotone by construction "
                    "up to the DegradedInfeasibleError cliff; infeasible "
                    "stages are recorded, not skipped",
        },
        "storm": measure_storm(ns=ns),
        "flapping": measure_flapping(steps=100 if quick else 200),
        "roundtrip": measure_roundtrip(ns=ns, repeats=1 if quick else 3),
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_storm.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    for row in payload["storm"]:
        if row["feasible"]:
            print(f"  N={row['n']:4d} k={row['intensity']}: "
                  f"{row['total_s'] * 1e3:8.3f} ms  x{row['ratio']:.3f}  "
                  f"(fused {row['fused_steps']}, "
                  f"eff {row['fusion_efficiency']:.2f})")
        else:
            print(f"  N={row['n']:4d} k={row['intensity']}: infeasible "
                  f"({row['error']})")
    for row in payload["flapping"]:
        print(f"  flap {row['case']:10s}: {row['replans_hysteresis']} "
              f"replans vs {row['replans_naive']} naive "
              f"({row['transitions']} transitions)")
    for row in payload["roundtrip"]:
        print(f"  roundtrip N={row['n']:4d}: degrade "
              f"{row['degrade_ms']:.2f} ms + heal {row['heal_ms']:.2f} ms "
              f"(cached={row['heal_cached']})")


if __name__ == "__main__":
    main()
