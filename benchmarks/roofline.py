"""Aggregate the dry-run artifacts into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch × shape × mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio and roofline fraction.  Also renders
the markdown table embedded in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(pattern: str = "*.json") -> list[dict]:
    cells = []
    for p in sorted(OUT_DIR.glob(pattern)):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def rows() -> list[dict]:
    out = []
    for c in load_cells():
        if not c.get("ok"):
            out.append({"name": f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                        "us_per_call": 0.0,
                        "derived": {"ok": False, "error": c.get("error", "?")[:80]}})
            continue
        r = c["roofline"]
        out.append({
            "name": f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            "us_per_call": c.get("seconds", 0) * 1e6,
            "derived": {
                "compute_ms": round(r["compute_s"] * 1e3, 2),
                "memory_ms": round(r["memory_s"] * 1e3, 2),
                "collective_ms": round(r["collective_s"] * 1e3, 2),
                "bottleneck": r["bottleneck"],
                "useful": round(r["useful_ratio"], 3),
                "roofline_frac": round(r["roofline_fraction"], 4),
                "hbm_gib": round(c["memory"]["per_device_hbm_bytes"] / 2**30, 2),
                "fits": c["fits_16gb"],
            },
        })
    return out


def markdown_table(mesh: str = "16x16") -> str:
    """Baseline cells only (tagged hillclimb variants are excluded).

    'steady (GiB)' = argument residency (weights + optimizer + caches) —
    the true per-device steady state; 'HBM/dev' additionally includes XLA
    CPU temp modelling (f32-promotion of bf16 dot operands + scan cache
    double-buffering, both absent on TPU — EXPERIMENTS.md §Methodology-5)."""
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "bottleneck | steady (GiB) | HBM/dev (GiB) | fits 16G | useful | "
        "roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in load_cells():
        if c.get("mesh") != mesh or not c.get("ok") or c.get("tag"):
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['bottleneck']} | "
            f"{c['memory']['argument_size_in_bytes']/2**30:.2f} | "
            f"{c['memory']['per_device_hbm_bytes']/2**30:.2f} | "
            f"{'yes' if c['fits_16gb'] else 'NO'} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
