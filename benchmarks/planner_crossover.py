"""Beyond-paper: the α–β planner's schedule choice vs bucket size (Lemma 1 on
TPU).  Small buckets -> WRHT m-ary tree (latency-bound); large -> hierarchical
scatter (bandwidth-bound).  Also shows the paper's optical regime."""

from __future__ import annotations

import time

from repro.core.planner import CostParams, crossover_table, plan_bucket


def rows() -> list[dict]:
    out = []
    t0 = time.perf_counter()
    for row in crossover_table(256):
        out.append({
            "name": f"planner/tpu_v5e/bytes={row['bytes']}",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "derived": {"strategy": row["strategy"], "m": row["m"],
                        "factors": list(row["factors"]),
                        "cost_us": round(row["cost_us"], 2)},
        })
        t0 = time.perf_counter()
    # the paper's optical regime: 25 µs steps, AlexNet gradients
    p = CostParams.optical(64)
    plan = plan_bucket(1024, 62.3e6 * 4, p, m_candidates=(2, 8, 129))
    out.append({
        "name": "planner/optical_w64/alexnet",
        "us_per_call": 0.0,
        "derived": {"strategy": plan.strategy, "m": plan.m,
                    "factors": list(plan.factors),
                    "cost_ms": round(plan.cost_s * 1e3, 2)},
    })
    # analytic vs simulated backend: same candidates, costs from the
    # batched flit-level simulator (repro.core.timing) instead of the
    # closed forms — the two are interchangeable planner backends
    for bytes_ in (1 << 14, 62.3e6 * 4):
        t0 = time.perf_counter()
        sim = plan_bucket(1024, bytes_, p, m_candidates=(2, 8, 129),
                          backend="simulated")
        ana = plan_bucket(1024, bytes_, p, m_candidates=(2, 8, 129))
        us = (time.perf_counter() - t0) * 1e6
        out.append({
            "name": f"planner/simulated_vs_analytic/bytes={int(bytes_)}",
            "us_per_call": us,
            "derived": {
                "sim_strategy": sim.strategy, "sim_m": sim.m,
                "sim_cost_ms": round(sim.cost_s * 1e3, 3),
                "analytic_strategy": ana.strategy, "analytic_m": ana.m,
                "analytic_cost_ms": round(ana.cost_s * 1e3, 3),
            },
        })
    # full crossover under the simulated backend (one batched plan_buckets
    # call via the crossover_table pass-through), with and without a hop
    # budget — where the simulated crossover moves vs the closed forms
    p_sim = CostParams.optical(8)
    for max_hops in (None, 8):
        t0 = time.perf_counter()
        rows_sim = crossover_table(64, params=p_sim, backend="simulated",
                                   max_hops=max_hops)
        us = (time.perf_counter() - t0) * 1e6
        flips = [r["bytes"] for prev, r in zip(rows_sim, rows_sim[1:])
                 if r["strategy"] != prev["strategy"]]
        out.append({
            "name": f"planner/crossover_simulated/H={max_hops}",
            "us_per_call": us / len(rows_sim),
            "derived": {
                "strategies": [r["strategy"] for r in rows_sim],
                "crossover_bytes": flips,
            },
        })
    return out
