"""Paper Fig. 5: electrical fat-tree (E-Ring, RD) vs optical (O-Ring, WRHT).

N ∈ {128, 256, 512, 1024} × four DNN payloads.  Paper claims: WRHT reduces
comm time by 86.69 % vs E-Ring and 84.71 % vs RD; O-Ring beats E-Ring by
74.74 % on average.

The optical side is one batched ``timing.evaluate_grid`` call (the
electrical side stays closed-form); ``us_per_call`` is the per-cell cost of
the electrical models plus the amortized grid time.
"""

from __future__ import annotations

import statistics
import time

from repro.core import step_models as sm, timing

NS = (128, 256, 512, 1024)


def rows() -> list[dict]:
    p, e = sm.OpticalParams(), sm.ElectricalParams()
    payloads = list(sm.PAPER_MODELS_BITS.values())
    t0 = time.perf_counter()
    grid = timing.evaluate_grid(("wrht", "ring"), NS, payloads,
                                ("lockstep",), p)
    grid_us = (time.perf_counter() - t0) * 1e6 / (len(NS) * len(payloads))
    out = []
    red_er, red_rd, red_oring = [], [], []
    for n in NS:
        for di, (model, bits) in enumerate(sm.PAPER_MODELS_BITS.items()):
            t0 = time.perf_counter()
            wrht_t = float(grid.total("wrht", n, "lockstep")[di])
            oring_t = float(grid.total("ring", n, "lockstep")[di])
            ering_t = sm.t_ring_electrical(n, bits, e)
            rd_t = sm.t_rd_electrical(n, bits, e)
            us = (time.perf_counter() - t0) * 1e6 + grid_us
            red_er.append(1 - wrht_t / ering_t)
            red_rd.append(1 - wrht_t / rd_t)
            red_oring.append(1 - oring_t / ering_t)
            out.append({
                "name": f"fig5/{model}/N={n}",
                "us_per_call": us,
                "derived": {"wrht_ms": round(wrht_t * 1e3, 2),
                            "o_ring_ms": round(oring_t * 1e3, 2),
                            "e_ring_ms": round(ering_t * 1e3, 2),
                            "rd_ms": round(rd_t * 1e3, 2)},
            })
    out.append({"name": "fig5/wrht_vs_ering", "us_per_call": 0.0,
                "derived": f"{100 * statistics.mean(red_er):.2f}%",
                "paper": "86.69%"})
    out.append({"name": "fig5/wrht_vs_rd", "us_per_call": 0.0,
                "derived": f"{100 * statistics.mean(red_rd):.2f}%",
                "paper": "84.71%"})
    out.append({"name": "fig5/oring_vs_ering", "us_per_call": 0.0,
                "derived": f"{100 * statistics.mean(red_oring):.2f}%",
                "paper": "74.74%"})
    return out
