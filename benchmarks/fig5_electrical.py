"""Paper Fig. 5: electrical fat-tree (E-Ring, RD) vs optical (O-Ring, WRHT).

N ∈ {128, 256, 512, 1024} × four DNN payloads.  Paper claims: WRHT reduces
comm time by 86.69 % vs E-Ring and 84.71 % vs RD; O-Ring beats E-Ring by
74.74 % on average.
"""

from __future__ import annotations

import statistics
import time

from repro.core import simulator, step_models as sm


def rows() -> list[dict]:
    p, e = sm.OpticalParams(), sm.ElectricalParams()
    out = []
    red_er, red_rd, red_oring = [], [], []
    for n in (128, 256, 512, 1024):
        for model, bits in sm.PAPER_MODELS_BITS.items():
            t0 = time.perf_counter()
            wrht_t = simulator.run_optical("wrht", n, bits, p).total_s
            oring_t = simulator.run_optical("ring", n, bits, p).total_s
            ering_t = sm.t_ring_electrical(n, bits, e)
            rd_t = sm.t_rd_electrical(n, bits, e)
            us = (time.perf_counter() - t0) * 1e6
            red_er.append(1 - wrht_t / ering_t)
            red_rd.append(1 - wrht_t / rd_t)
            red_oring.append(1 - oring_t / ering_t)
            out.append({
                "name": f"fig5/{model}/N={n}",
                "us_per_call": us,
                "derived": {"wrht_ms": round(wrht_t * 1e3, 2),
                            "o_ring_ms": round(oring_t * 1e3, 2),
                            "e_ring_ms": round(ering_t * 1e3, 2),
                            "rd_ms": round(rd_t * 1e3, 2)},
            })
    out.append({"name": "fig5/wrht_vs_ering", "us_per_call": 0.0,
                "derived": f"{100 * statistics.mean(red_er):.2f}%",
                "paper": "86.69%"})
    out.append({"name": "fig5/wrht_vs_rd", "us_per_call": 0.0,
                "derived": f"{100 * statistics.mean(red_rd):.2f}%",
                "paper": "84.71%"})
    out.append({"name": "fig5/oring_vs_ering", "us_per_call": 0.0,
                "derived": f"{100 * statistics.mean(red_oring):.2f}%",
                "paper": "74.74%"})
    return out
