"""Insertion-loss feasibility frontier: which (m, w) WRHT trees survive the
optical power budget, and what they cost under the three timing engines.

The paper's abstract and Sec. III note that insertion loss bounds how many
nodes a wavelength can traverse; ``topology.PhysicalParams`` turns that into
a hop budget ``H`` and ``wrht.build_schedule`` caps the tree fan-out at
``2H + 1`` (relaying deeper levels through O/E/O regeneration when even the
surviving representatives drift out of reach).  This sweep varies the
per-hop loss at a fixed 32 dB power budget and reports, per cell:

  max_hops        the resulting hop budget H
  m_effective     level-0 group size actually used (min of Lemma 1 and 2H+1)
  steps           schedule length (relays inflate it at tight budgets)
  lockstep_ms     golden per-step-max timing
  overlap_ms      SWOT-style reconfiguration-overlap timing (always <=)
  bt_feasible     whether the binary-tree baseline's fixed lightpaths fit H

``python -m benchmarks.bench_insertion_loss`` runs the full sweep and writes
``BENCH_insertion_loss.json`` at the repo root (the feasibility-frontier
artifact, tracked like ``BENCH_schedule.json``); ``rows()`` exposes a cheap
subset to the ``benchmarks.run`` harness.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import simulator, step_models as sm, timing
from repro.core.topology import PhysicalParams

# per-hop insertion loss sweep (dB); the 32 dB default budget gives
# H = 128, 64, 32, 16, 8 hops respectively
LOSS_SWEEP = (0.25, 0.5, 1.0, 2.0, 4.0)
N_SWEEP = (256, 1024)
WAVELENGTHS = (16, 64)
D_BITS = 25e6 * 32  # ResNet50 gradients


def bench_cell(n: int, w: int, loss_db: float) -> dict:
    phys = PhysicalParams(insertion_loss_db_per_hop=loss_db)
    p = sm.OpticalParams(wavelengths=w, physical=phys)
    # one evaluate_grid call per cell (DESIGN.md §9): the WRHT schedule is
    # built+validated once (same cache key as run_optical), both timing
    # modes come out of the compiled profile, and the binary tree's
    # infeasibility under the hop budget lands in ``grid.feasible`` instead
    # of an exception
    sched = simulator._cached_wrht_schedule(n, w, None, phys.max_hops)
    grid = timing.evaluate_grid(("wrht", "bt"), (n,), (D_BITS,),
                                ("lockstep", "overlap"), p)
    return {
        "n": n,
        "w": w,
        "loss_db_per_hop": loss_db,
        "max_hops": phys.max_hops,
        "fan_out_cap": phys.fan_out_cap,
        "m_effective": sched.m,
        "level_group_sizes": sched.level_group_sizes,
        "steps": sched.num_steps,
        "lockstep_ms": round(float(grid.total("wrht", n, "lockstep")[0]) * 1e3, 4),
        "overlap_ms": round(float(grid.total("wrht", n, "overlap")[0]) * 1e3, 4),
        "bt_feasible": grid.is_feasible("bt", n),
    }


def sweep() -> dict:
    cells = [
        bench_cell(n, w, loss)
        for loss in LOSS_SWEEP for n in N_SWEEP for w in WAVELENGTHS
    ]
    return {
        "benchmark": "insertion_loss_frontier",
        "power_budget_db": PhysicalParams().power_budget_db,
        "d_bits": D_BITS,
        "cells": cells,
    }


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` harness (CI smoke)."""
    out = []
    for loss in (0.5, 4.0):
        for n in (256,):
            t0 = time.perf_counter()
            cell = bench_cell(n, 64, loss)
            us = (time.perf_counter() - t0) * 1e6
            out.append({
                "name": f"insertion_loss/N={n}/loss={loss}dB",
                "us_per_call": us,
                "derived": {k: cell[k] for k in (
                    "max_hops", "m_effective", "steps",
                    "lockstep_ms", "overlap_ms", "bt_feasible")},
            })
    return out


def main() -> None:
    result = sweep()
    path = Path(__file__).resolve().parents[1] / "BENCH_insertion_loss.json"
    path.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {path}")
    for cell in result["cells"]:
        print(f"n={cell['n']} w={cell['w']} loss={cell['loss_db_per_hop']}dB "
              f"H={cell['max_hops']} m={cell['m_effective']} "
              f"steps={cell['steps']} lockstep={cell['lockstep_ms']}ms "
              f"overlap={cell['overlap_ms']}ms bt={cell['bt_feasible']}")


if __name__ == "__main__":
    main()
