"""Scheduled collective algebra wall-clock: per-collective times + the
RS+AG-vs-AR crossover (DESIGN.md §11, EXPERIMENTS.md §Collectives).

Two measurements, written to ``BENCH_collectives.json`` by
``python -m benchmarks.bench_collectives``:

* ``collectives`` — simulated lockstep time of every scheduled collective
  (reduce_scatter / all_gather / broadcast / alltoall / allreduce) across
  ``N × d`` through the batched timing engine (one ``collective_times``
  call per cell covers the whole payload grid).  Infeasible cells (the
  single-step all-to-all beyond its ``⌈N²/8⌉`` wavelength budget) are
  recorded as such, not skipped silently.
* ``rs_ag_vs_ar`` — the ZeRO-style decomposition against the monolithic
  all-reduce: per ring size, the payload ``d*`` where ``t_RS(d) + t_AG(d)``
  crosses below ``t_AR(d)``.  Small buckets are step-bound (WRHT's
  ``2⌈log_m N⌉−1`` full-vector steps win), large buckets are
  bandwidth-bound (the ring passes move ``2·(N−1)/N·d`` total).  The
  committed artifact records the measured crossover per N, which
  ``sync_algorithm="planned_sharded"`` exploits per bucket.

``rows()`` exposes a cheap subset to the ``benchmarks.run`` harness;
``--quick`` shrinks the grid for the CI smoke run (the workflow uploads the
JSON as an artifact).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

import numpy as np

from repro.core import step_models as sm, timing, wrht
from repro.core.wavelength import InsertionLossError, WavelengthConflictError

NS = (16, 64, 256, 1024)
QUICK_NS = (16, 64)
D_GRID = tuple(float(2 ** e) for e in range(13, 34))   # 8 Kb .. 8 Gb
RESNET50 = sm.PAPER_MODELS_BITS["ResNet50"]

COLLECTIVES = ("reduce_scatter", "all_gather", "broadcast", "alltoall",
               "allreduce")


def measure_collectives(ns=NS, d_grid=D_GRID,
                        p: sm.OpticalParams | None = None) -> list[dict]:
    """Lockstep totals of every collective over the N × d grid."""
    p = p or sm.OpticalParams()
    rows = []
    d = np.asarray(d_grid)
    for n in ns:
        for coll in COLLECTIVES:
            try:
                times = timing.collective_times(coll, n, d, p,
                                                keep_per_step=False)
            except (WavelengthConflictError, InsertionLossError) as e:
                rows.append({"collective": coll, "n": n, "feasible": False,
                             "reason": str(e)})
                continue
            rows.append({
                "collective": coll, "n": n, "feasible": True,
                "steps": int(times.steps),
                "max_wavelengths": int(times.max_wavelengths),
                "d_bits": list(d),
                "total_s": [float(t) for t in times.total_s],
            })
    return rows


def _rs_ag_and_ar(n: int, d, p: sm.OpticalParams):
    d = np.atleast_1d(np.asarray(d, dtype=np.float64))
    rs = timing.collective_times("reduce_scatter", n, d, p,
                                 keep_per_step=False).total_s
    ag = timing.collective_times("all_gather", n, d, p,
                                 keep_per_step=False).total_s
    ar = timing.collective_times("allreduce", n, d, p,
                                 keep_per_step=False).total_s
    return rs + ag, ar


def measure_crossover(ns=NS, p: sm.OpticalParams | None = None) -> list[dict]:
    """Per ring size: the payload where RS+AG overtakes the all-reduce.

    The grid bracket is refined by bisection on the continuous payload axis
    (both curves are piecewise-affine in d, so 60 iterations pin the
    crossover to the flit granularity).
    """
    p = p or sm.OpticalParams()
    rows = []
    d = np.asarray(D_GRID)
    for n in ns:
        sharded, mono = _rs_ag_and_ar(n, d, p)
        wins = sharded <= mono
        row = {
            "n": n,
            "ar_steps": int(timing.collective_times(
                "allreduce", n, [1e6], p, keep_per_step=False).steps),
            "rs_ag_steps": 2 * (n - 1),
            "at_resnet50": {
                "rs_ag_s": float(_rs_ag_and_ar(n, RESNET50, p)[0][0]),
                "ar_s": float(_rs_ag_and_ar(n, RESNET50, p)[1][0]),
            },
        }
        if wins.all() or not wins.any():
            row["crossover_d_bits"] = None
            row["rs_ag_always_wins"] = bool(wins.all())
        else:
            i = int(np.argmax(wins))          # first grid point RS+AG wins
            lo, hi = float(d[i - 1]), float(d[i])
            for _ in range(60):
                mid = 0.5 * (lo + hi)
                s, m_ = _rs_ag_and_ar(n, mid, p)
                if s[0] <= m_[0]:
                    hi = mid
                else:
                    lo = mid
            row["crossover_d_bits"] = hi
            row["crossover_mbytes"] = hi / 8 / 1e6
        rows.append(row)
    return rows


def rows() -> list[dict]:
    """Cheap subset for the ``benchmarks.run`` CSV harness."""
    p = sm.OpticalParams()
    out = []
    for n in QUICK_NS:
        for coll in COLLECTIVES:
            try:
                t = timing.collective_times(coll, n, [RESNET50], p,
                                            keep_per_step=False)
            except (WavelengthConflictError, InsertionLossError):
                continue
            out.append({
                "name": f"collective_{coll}_n{n}",
                "us_per_call": float(t.total_s[0]) * 1e6,
                "derived": {"steps": int(t.steps),
                            "wavelengths": int(t.max_wavelengths)},
            })
    for row in measure_crossover(ns=QUICK_NS):
        out.append({
            "name": f"rs_ag_vs_ar_crossover_n{row['n']}",
            "us_per_call": 0.0,
            "derived": {"crossover_d_bits": row.get("crossover_d_bits")},
        })
    return out


def main() -> None:
    quick = "--quick" in sys.argv
    ns = QUICK_NS if quick else NS
    p = sm.OpticalParams()
    payload = {
        "config": {
            "wavelengths": p.wavelengths,
            "bandwidth_bps": p.bandwidth_bps,
            "reconfig_delay_s": p.reconfig_delay_s,
            "collectives": list(COLLECTIVES),
            "quick": quick,
            "note": "allreduce = WRHT at the analytic fan-out (Lemma 1); "
                    "RS/AG = the N-1-step ring passes (DESIGN.md §11)",
        },
        "collectives": measure_collectives(ns=ns, p=p),
        "rs_ag_vs_ar": measure_crossover(ns=ns, p=p),
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_collectives.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    for row in payload["rs_ag_vs_ar"]:
        cx = row.get("crossover_d_bits")
        print(f"  N={row['n']:5d}: RS+AG vs AR crossover at "
              + (f"{cx:.3g} bits ({cx / 8 / 1e6:.2f} MB)" if cx
                 else f"none on grid (rs_ag_always_wins="
                      f"{row.get('rs_ag_always_wins')})"))


if __name__ == "__main__":
    main()
