"""Paper Fig. 4: WRHT vs Ring/H-Ring/BT on the optical ring.

Four DNN gradient payloads × N ∈ {1024, 2048, 3072, 4096}, flit-level
simulation with Table II parameters.  Reports per-cell times and the average
reduction of WRHT vs each baseline next to the paper's claimed numbers
(75.59 % / 49.25 % / 70.1 %); our baselines are bandwidth-optimal
implementations (stronger than the paper's — see EXPERIMENTS.md §Repro).

The trailing rows exercise the two physical-layer knobs added on top of the
paper's model: an insertion-loss-constrained WRHT (``PhysicalParams``, hop
budget capping the tree fan-out) and the SWOT-style event-timed engine with
reconfiguration–communication overlap (``timing="overlap"``) — both through
``step_models.OpticalParams``.
"""

from __future__ import annotations

import time

from repro.core import simulator, step_models as sm
from repro.core.topology import PhysicalParams

PAPER_CLAIMS = {"ring": 75.59, "hring": 49.25, "bt": 70.1}


def rows() -> list[dict]:
    p = sm.OpticalParams()
    out = []
    reductions = {a: [] for a in ("ring", "hring", "bt")}
    for n in (1024, 2048, 3072, 4096):
        for model, bits in sm.PAPER_MODELS_BITS.items():
            t0 = time.perf_counter()
            res = {a: simulator.run_optical(a, n, bits, p)
                   for a in ("wrht", "ring", "bt", "hring")}
            us = (time.perf_counter() - t0) * 1e6
            for a in reductions:
                reductions[a].append(1 - res["wrht"].total_s / res[a].total_s)
            out.append({
                "name": f"fig4/{model}/N={n}",
                "us_per_call": us,
                "derived": {a: round(r.total_s * 1e3, 2) for a, r in res.items()},
            })
    for a, vals in reductions.items():
        out.append({
            "name": f"fig4/avg_reduction_vs_{a}",
            "us_per_call": 0.0,
            "derived": f"{100 * sum(vals) / len(vals):.2f}%",
            "paper": f"{PAPER_CLAIMS[a]}%",
        })
    # ---- beyond-paper knobs: insertion loss + reconfig overlap ----------
    bits = sm.PAPER_MODELS_BITS["ResNet50"]
    phys = sm.OpticalParams(physical=PhysicalParams())
    for n in (1024, 4096):
        t0 = time.perf_counter()
        ideal = simulator.run_optical("wrht", n, bits, p).total_s
        lossy = simulator.run_optical("wrht", n, bits, phys).total_s
        ovl = simulator.run_optical("wrht", n, bits, phys, timing="overlap").total_s
        us = (time.perf_counter() - t0) * 1e6
        out.append({
            "name": f"fig4/wrht_physical/N={n}",
            "us_per_call": us,
            "derived": {
                "ideal_ms": round(ideal * 1e3, 2),
                "hop_budget_ms": round(lossy * 1e3, 2),
                "overlap_ms": round(ovl * 1e3, 2),
                "max_hops": phys.physical.max_hops,
            },
        })
    return out
