"""Paper Fig. 4: WRHT vs Ring/H-Ring/BT on the optical ring.

Four DNN gradient payloads × N ∈ {1024, 2048, 3072, 4096}, flit-level
simulation with Table II parameters.  Reports per-cell times and the average
reduction of WRHT vs each baseline next to the paper's claimed numbers
(75.59 % / 49.25 % / 70.1 %); our baselines are bandwidth-optimal
implementations (stronger than the paper's — see EXPERIMENTS.md §Repro).

The whole sweep is one ``timing.evaluate_grid`` call (DESIGN.md §9):
schedules are compiled to ``ScheduleProfile`` arrays once per ``(alg, N)``
and the payload axis is evaluated in a single broadcasted pass — per-cell
numbers are bit-identical to calling ``simulator.run_optical`` point-wise
(``benchmarks/bench_sweep.py`` measures the wall-clock gap between the two
paths).  ``us_per_call`` therefore reports the *amortized* grid time per
cell.

The trailing rows exercise the two physical-layer knobs added on top of the
paper's model: an insertion-loss-constrained WRHT (``PhysicalParams``, hop
budget capping the tree fan-out) and the SWOT-style event-timed engine with
reconfiguration–communication overlap (``timing="overlap"``) — both through
``step_models.OpticalParams``.
"""

from __future__ import annotations

import time

from repro.core import step_models as sm, timing
from repro.core.topology import PhysicalParams

PAPER_CLAIMS = {"ring": 75.59, "hring": 49.25, "bt": 70.1}
NS = (1024, 2048, 3072, 4096)
ALGOS = ("wrht", "ring", "bt", "hring")


def rows() -> list[dict]:
    p = sm.OpticalParams()
    payloads = list(sm.PAPER_MODELS_BITS.values())
    t0 = time.perf_counter()
    grid = timing.evaluate_grid(ALGOS, NS, payloads, ("lockstep",), p)
    cells = len(NS) * len(payloads)
    us_per_cell = (time.perf_counter() - t0) * 1e6 / cells
    out = []
    reductions = {a: [] for a in ("ring", "hring", "bt")}
    for n in NS:
        for di, model in enumerate(sm.PAPER_MODELS_BITS):
            res = {a: grid.total(a, n, "lockstep")[di] for a in ALGOS}
            for a in reductions:
                reductions[a].append(1 - res["wrht"] / res[a])
            out.append({
                "name": f"fig4/{model}/N={n}",
                "us_per_call": us_per_cell,
                "derived": {a: round(t * 1e3, 2) for a, t in res.items()},
            })
    for a, vals in reductions.items():
        out.append({
            "name": f"fig4/avg_reduction_vs_{a}",
            "us_per_call": 0.0,
            "derived": f"{100 * sum(vals) / len(vals):.2f}%",
            "paper": f"{PAPER_CLAIMS[a]}%",
        })
    # ---- beyond-paper knobs: insertion loss + reconfig overlap ----------
    bits = sm.PAPER_MODELS_BITS["ResNet50"]
    phys = sm.OpticalParams(physical=PhysicalParams())
    t0 = time.perf_counter()
    ideal_g = timing.evaluate_grid(("wrht",), (1024, 4096), [bits],
                                   ("lockstep",), p)
    lossy_g = timing.evaluate_grid(("wrht",), (1024, 4096), [bits],
                                   ("lockstep", "overlap"), phys)
    us = (time.perf_counter() - t0) * 1e6 / 2
    for n in (1024, 4096):
        out.append({
            "name": f"fig4/wrht_physical/N={n}",
            "us_per_call": us,
            "derived": {
                "ideal_ms": round(ideal_g.total("wrht", n, "lockstep")[0] * 1e3, 2),
                "hop_budget_ms": round(lossy_g.total("wrht", n, "lockstep")[0] * 1e3, 2),
                "overlap_ms": round(lossy_g.total("wrht", n, "overlap")[0] * 1e3, 2),
                "max_hops": phys.physical.max_hops,
            },
        })
    return out
