"""The paper's algorithm zoo on BOTH substrates.

Left: flit-level optical-ring simulation (the paper's Fig. 4 setting).
Right: the same four algorithms as real JAX collectives on an 8-device mesh
(CPU-simulated), counting the collective-permute/all-reduce ops each lowers
to — the HLO-level analogue of the paper's "communication steps".

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/allreduce_comparison.py
"""

import os
import re

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import collectives as C, simulator, step_models as sm

print("=== optical ring (paper Fig. 4 setting): 1024 nodes, VGG16 ===")
for alg in ("wrht", "hring", "ring", "bt"):
    r = simulator.run_optical(alg, 1024, 138e6 * 32)
    print(f"  {alg:6s} {r.total_s*1e3:9.2f} ms  {r.steps:5d} steps  "
          f"λ_max={r.max_wavelengths}")

print("\n=== JAX collectives on an 8-device mesh (HLO census) ===")
mesh = jax.make_mesh((8,), ("ax",), axis_types=(AxisType.Auto,))
x = jnp.ones((8, 4096), jnp.float32)
with jax.set_mesh(mesh):
    for alg, kw in [("psum", {}), ("ring", {}), ("rd", {}), ("bt", {}),
                    ("wrht", {"m": 3, "alltoall_max": 4})]:
        f = jax.jit(C.make_sharded_allreduce(mesh, "ax", alg, **kw))
        hlo = f.lower(x).compile().as_text()
        census = {op: len(re.findall(rf"= \S+ {op}", hlo))
                  for op in ("all-reduce", "collective-permute", "all-gather",
                             "reduce-scatter")}
        census = {k: v for k, v in census.items() if v}
        out = np.asarray(f(x))
        ok = np.allclose(out, 8.0)
        print(f"  {alg:6s} {kw or '':24} correct={ok}  HLO: {census}")

print("\nsame structure, two substrates: steps are wavelength-parallel "
      "transfers on the ring, ppermute/all-reduce ops on the TPU mesh.")
