"""Serve a small model with batched requests through the KV-cache engine.

Optionally load the checkpoint produced by examples/train_lm.py (the
engine's decode step is exactly the serve_step the decode_32k dry-run cells
lower, at production shapes).

  PYTHONPATH=src python examples/serve_lm.py --requests 6
"""

import argparse
import time

import numpy as np

import jax

from repro.checkpoint import load_latest
from repro.configs import registry
from repro.models import api as mapi
from repro.serve import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = registry.get(args.arch, smoke=True)
    api = mapi.get_api(cfg, remat="none")
    params = api.init(jax.random.key(0))
    restored, step = load_latest(args.ckpt_dir, {"params": params})
    if restored is not None and args.arch == "qwen2-1.5b":
        params, note = restored["params"], f"(checkpoint step {step})"
    else:
        note = "(random weights)"

    eng = Engine(cfg, params, batch_slots=4, max_seq=128)
    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = [eng.submit(list(rng.integers(1, cfg.vocab_size, rng.integers(3, 10))),
                       max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    eng.run()
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests {note}: {tokens} tokens "
          f"in {dt:.2f}s ({tokens/dt:.1f} tok/s)")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.prompt[:5]}... -> {r.output}")


if __name__ == "__main__":
    main()
