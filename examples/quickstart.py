"""Quickstart: the paper's all-reduce end to end, in four acts.

  1. Build the WRHT schedule for a 64-node optical ring and show the paper's
     step-count win over Ring/BT (Sec. III).
  2. Time all four algorithms in the flit-level optical simulator (Fig. 4).
  3. Re-run WRHT under the insertion-loss power budget (Sec. III) and the
     SWOT-style event-timed engine with reconfiguration overlap.
  4. Train a tiny LM for 30 steps with WRHT-planned gradient sync (the TPU
     port) and watch the loss drop.

Runs on CPU in ~1 minute:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.core import simulator, step_models as sm, wrht
from repro.core.topology import PhysicalParams
from repro.data.pipeline import CorpusLM
from repro.train import Trainer, TrainerOptions

# ---- 1. the schedule itself ------------------------------------------------
n, w = 64, 8
sched = wrht.build_schedule(n, w, d_bits=25e6 * 32)
print(f"WRHT on a {n}-node ring with {w} wavelengths: m={sched.m}, "
      f"{sched.num_steps} steps "
      f"(ring: {sm.ring_steps(n)}, binary tree: {sm.bt_steps(n)})")
for i, step in enumerate(sched.steps):
    print(f"  step {i}: {step.kind:9s} {len(step.transfers):3d} transfers, "
          f"{step.wavelengths} wavelengths")

# ---- 2. simulated communication time (Fig. 4 machinery) --------------------
print("\nResNet50 gradients (100 MB), 1024-node ring:")
for alg in ("wrht", "hring", "ring", "bt"):
    r = simulator.run_optical(alg, 1024, 25e6 * 32)
    print(f"  {alg:6s} {r.total_s*1e3:9.2f} ms  ({r.steps} steps)")

# ---- 2b. the scheduled collective algebra (DESIGN.md §11) ------------------
from repro.core import timing

d = 25e6 * 32
rs = timing.collective_times("reduce_scatter", 1024, [d])
ag = timing.collective_times("all_gather", 1024, [d])
ar = timing.collective_times("allreduce", 1024, [d])
print(f"\nZeRO-style sharded sync on 1024 nodes (ResNet50 bucket): "
      f"RS+AG {float(rs.total_s[0] + ag.total_s[0])*1e3:.2f} ms vs "
      f"monolithic all-reduce {float(ar.total_s[0])*1e3:.2f} ms "
      f"(per-bucket crossover: BENCH_collectives.json; "
      f'train with sync_algorithm="planned_sharded")')

# ---- 3. physical layer: insertion loss + event-timed simulation ------------
phys = PhysicalParams(insertion_loss_db_per_hop=2.0)  # 32 dB budget -> 16 hops
pp = sm.OpticalParams(physical=phys)
print(f"\nInsertion loss at {phys.insertion_loss_db_per_hop} dB/hop: "
      f"hop budget {phys.max_hops}, WRHT fan-out capped at "
      f"{sm.max_feasible_m(pp)}")
for timing in ("lockstep", "overlap"):
    r = simulator.run_optical("wrht", 1024, 25e6 * 32, pp, timing=timing)
    print(f"  wrht N=1024 under budget, {timing:8s} {r.total_s*1e3:9.2f} ms "
          f"({r.steps} steps, relays included)")

# ---- 4. the TPU port: WRHT-planned gradient sync in a real train loop ------
print("\nTraining a tiny LM (planner-scheduled hierarchical sync on 1 CPU "
      "device degenerates to local sum — same code path as the 512-chip "
      "dry-run):")
cfg = registry.get("qwen2-1.5b", smoke=True)
tc = TrainConfig(lr=1e-3, total_steps=30, warmup_steps=5, remat="none")
src = CorpusLM(cfg.vocab_size, seq_len=32, global_batch=8)
trainer = Trainer(cfg, tc, src, options=TrainerOptions(
    # fresh dir each run: a stale checkpoint would restore at step 30 and
    # train (and log) nothing
    ckpt_dir=tempfile.mkdtemp(prefix="repro_quickstart_"),
    ckpt_every=1000, log_every=10))
trainer.run(30)
print("loss:", " -> ".join(f"{h['loss']:.2f}" for h in trainer.history))
