"""Quickstart: the paper's all-reduce end to end, in three acts.

  1. Build the WRHT schedule for a 64-node optical ring and show the paper's
     step-count win over Ring/BT (Sec. III).
  2. Time all four algorithms in the flit-level optical simulator (Fig. 4).
  3. Train a tiny LM for 30 steps with WRHT-planned gradient sync (the TPU
     port) and watch the loss drop.

Runs on CPU in ~1 minute:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.core import simulator, step_models as sm, wrht
from repro.data.pipeline import CorpusLM
from repro.train import Trainer, TrainerOptions

# ---- 1. the schedule itself ------------------------------------------------
n, w = 64, 8
sched = wrht.build_schedule(n, w, d_bits=25e6 * 32)
print(f"WRHT on a {n}-node ring with {w} wavelengths: m={sched.m}, "
      f"{sched.num_steps} steps "
      f"(ring: {sm.ring_steps(n)}, binary tree: {sm.bt_steps(n)})")
for i, step in enumerate(sched.steps):
    print(f"  step {i}: {step.kind:9s} {len(step.transfers):3d} transfers, "
          f"{step.wavelengths} wavelengths")

# ---- 2. simulated communication time (Fig. 4 machinery) --------------------
print("\nResNet50 gradients (100 MB), 1024-node ring:")
for alg in ("wrht", "hring", "ring", "bt"):
    r = simulator.run_optical(alg, 1024, 25e6 * 32)
    print(f"  {alg:6s} {r.total_s*1e3:9.2f} ms  ({r.steps} steps)")

# ---- 3. the TPU port: WRHT-planned gradient sync in a real train loop ------
print("\nTraining a tiny LM (planner-scheduled hierarchical sync on 1 CPU "
      "device degenerates to local sum — same code path as the 512-chip "
      "dry-run):")
cfg = registry.get("qwen2-1.5b", smoke=True)
tc = TrainConfig(lr=1e-3, total_steps=30, warmup_steps=5, remat="none")
src = CorpusLM(cfg.vocab_size, seq_len=32, global_batch=8)
trainer = Trainer(cfg, tc, src, options=TrainerOptions(
    ckpt_dir="/tmp/repro_quickstart", ckpt_every=1000, log_every=10))
trainer.run(30)
print("loss:", " -> ".join(f"{h['loss']:.2f}" for h in trainer.history))
