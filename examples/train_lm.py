"""End-to-end driver: train an LM with WRHT gradient sync + checkpointing +
fault tolerance.

Presets:
  tiny  (default)  ~0.4M params, 200 steps — CPU-friendly demo (~2 min)
  100m             ~100M params, few hundred steps — the assignment's
                   end-to-end scale; run on real hardware (or be patient)

Demonstrates: corpus data pipeline, cosine schedule, grad clip, periodic
checkpoints, auto-resume (kill it mid-run and rerun: it continues), and
the straggler watchdog.

  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
"""

import argparse
import dataclasses
import logging

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data.pipeline import CorpusLM
from repro.train import Trainer, TrainerOptions


def preset_config(name: str):
    base = registry.get("qwen2-1.5b", smoke=True)
    if name == "tiny":
        return base
    if name == "100m":  # ~100M params, qwen2-family
        return dataclasses.replace(
            base, name="qwen2-100m", n_layers=12, d_model=640, n_heads=10,
            n_kv_heads=2, d_ff=2560, vocab_size=32000)
    raise SystemExit(f"unknown preset {name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=("tiny", "100m"))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sync", default="auto",
                    help="gradient sync: auto|psum|ring|rd|bt|wrht|planned")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = preset_config(args.preset)
    tc = TrainConfig(lr=3e-4 if args.preset == "100m" else 1e-3,
                     total_steps=args.steps, warmup_steps=max(10, args.steps // 20),
                     remat="none", sync_algorithm=args.sync)
    src = CorpusLM(cfg.vocab_size, args.seq, args.batch)
    trainer = Trainer(cfg, tc, src, options=TrainerOptions(
        ckpt_dir=args.ckpt_dir, ckpt_every=max(20, args.steps // 5)))
    trainer.run(args.steps)
    hist = trainer.history
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{args.steps} steps; straggler events: {len(trainer.watchdog.events)}")


if __name__ == "__main__":
    main()
