"""Event-timed simulator vs the lock-step golden path (DESIGN.md §7).

Invariants pinned here:
  * engine-level: ``simulate_steps_event(overlap=False)`` equals
    ``simulate_steps`` bit-for-bit on the same schedule (same accumulation);
  * ``overlap=True`` never exceeds lock-step (clamped exactly, not approx);
  * overlap strictly wins when per-step payloads are heterogeneous (the
    SWOT scenario: a node retunes during another node's tail transfer).
"""

import math

import pytest

from repro.core import simulator, step_models as sm, wrht
from repro.core.topology import CW, PhysicalParams, Ring, TransferBatch

ALGOS = ("wrht", "ring", "bt", "hring")


def _ring(n, w=8, physical=None):
    return Ring(n, w, physical=physical)


# ---------------------------------------------------------------------------
# engine-level equalities on identical schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w", [(15, 2), (64, 8), (100, 8)])
def test_event_barrier_equals_lockstep_exactly(n, w):
    sched = wrht.build_schedule(n, w, 1e6)
    ring = _ring(n, w)
    lock = simulator.simulate_steps("x", sched.steps, ring, 1e6)
    evt = simulator.simulate_steps_event("x", sched.steps, ring, 1e6)
    assert evt.total_s == lock.total_s  # bit-for-bit, not approx
    assert evt.timing == "event"
    assert evt.steps == lock.steps


def test_event_barrier_equals_lockstep_with_physical():
    phys = PhysicalParams(insertion_loss_db_per_hop=2.0)  # H=16, with prop
    sched = wrht.build_schedule(100, 8, 1e6, physical=phys)
    ring = _ring(100, 8, physical=phys)
    lock = simulator.simulate_steps("x", sched.steps, ring, 1e6)
    evt = simulator.simulate_steps_event("x", sched.steps, ring, 1e6)
    assert evt.total_s == lock.total_s


def test_overlap_never_exceeds_lockstep_engine_level():
    for n, w in [(15, 2), (64, 8), (100, 8)]:
        sched = wrht.build_schedule(n, w, 1e6)
        ring = _ring(n, w)
        lock = simulator.simulate_steps("x", sched.steps, ring, 1e6)
        ovl = simulator.simulate_steps_event("x", sched.steps, ring, 1e6,
                                             overlap=True)
        assert ovl.total_s <= lock.total_s  # exact: clamped in the engine
        assert ovl.timing == "overlap"


# ---------------------------------------------------------------------------
# run_optical-level ordering (lockstep path may use analytic shortcuts, so
# equality there is up to FP association, not bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg", ALGOS)
@pytest.mark.parametrize("n", [64, 256])
def test_run_optical_event_matches_lockstep(alg, n):
    p = sm.OpticalParams()
    lock = simulator.run_optical(alg, n, 1e8, p, timing="lockstep")
    evt = simulator.run_optical(alg, n, 1e8, p, timing="event")
    assert math.isclose(evt.total_s, lock.total_s, rel_tol=1e-12)
    assert evt.steps == lock.steps


@pytest.mark.parametrize("alg", ALGOS)
@pytest.mark.parametrize("n", [64, 256])
def test_run_optical_overlap_upper_bounded(alg, n):
    p = sm.OpticalParams()
    lock = simulator.run_optical(alg, n, 1e8, p, timing="lockstep")
    ovl = simulator.run_optical(alg, n, 1e8, p, timing="overlap")
    assert ovl.total_s <= lock.total_s * (1 + 1e-12)


def test_run_optical_overlap_with_physical_model():
    p = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=1.0))
    for alg in ("wrht", "ring", "hring"):
        lock = simulator.run_optical(alg, 256, 1e8, p, timing="lockstep")
        ovl = simulator.run_optical(alg, 256, 1e8, p, timing="overlap")
        assert ovl.total_s <= lock.total_s * (1 + 1e-12)


def test_unknown_timing_rejected():
    with pytest.raises(ValueError, match="unknown timing"):
        simulator.run_optical("bt", 64, 1e6, timing="warp")


# ---------------------------------------------------------------------------
# strict overlap win: heterogeneous payloads (the SWOT scenario)
# ---------------------------------------------------------------------------

def test_overlap_strictly_faster_on_skewed_payloads():
    # step 0: node 0->1 carries a huge payload while 2->3 finishes early;
    # step 1: 2->3 again — its endpoints retune during 0->1's tail, so the
    # second reconfiguration delay and the first big serialization overlap
    ring = _ring(8, 4)
    s0 = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [0, 2], [1, 3], CW, [1e9, 1e3], wavelength=[0, 0]))
    s1 = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [2], [3], CW, [1e9], wavelength=[0]))
    lock = simulator.simulate_steps("syn", [s0, s1], ring, 1.0)
    ovl = simulator.simulate_steps_event("syn", [s0, s1], ring, 1.0,
                                         overlap=True)
    # both 1e9-bit serializations run concurrently: ~half the lock-step time
    assert ovl.total_s < lock.total_s * 0.55
    # and the barrier event engine still reproduces lock-step exactly
    evt = simulator.simulate_steps_event("syn", [s0, s1], ring, 1.0)
    assert evt.total_s == lock.total_s


def test_overlap_respects_data_dependencies():
    # chain 0->1 then 1->2: the second hop cannot start before the first
    # delivers, overlap or not — total is two full (reconfig + ser) terms
    ring = _ring(8, 4)
    s0 = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [0], [1], CW, [1e6], wavelength=[0]))
    s1 = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [1], [2], CW, [1e6], wavelength=[0]))
    lock = simulator.simulate_steps("chain", [s0, s1], ring, 1.0)
    ovl = simulator.simulate_steps_event("chain", [s0, s1], ring, 1.0,
                                         overlap=True)
    assert ovl.total_s == lock.total_s


def test_per_step_makespans_sum_to_total():
    sched = wrht.build_schedule(64, 8, 1e6)
    ring = _ring(64, 8)
    for overlap in (False, True):
        r = simulator.simulate_steps_event("x", sched.steps, ring, 1e6,
                                           overlap=overlap)
        if r.event_total_s is not None:
            assert sum(r.per_step_s) == pytest.approx(r.event_total_s)


def test_empty_step_accounting_consistent_across_engines():
    """Regression: the event engine used to append 0.0 for an empty step and
    skip its reconfiguration while ``reconfig_s`` still charged it — the
    per-step list and the reported totals disagreed.  An empty step retunes
    every node's MRRs: all three engines now charge exactly ``a`` for it,
    and ``sum(per_step_s)`` equals the reported total everywhere."""
    ring = _ring(8, 4)
    real = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [0, 2], [1, 3], CW, [1e6, 1e3], wavelength=[0, 0]))
    empty = wrht.Step("reduce", 0, TransferBatch.empty())
    steps = [empty, real, empty, real, empty]
    a = ring.reconfig_delay_s
    results = {
        "lockstep": simulator.simulate_steps("x", steps, ring, 1.0),
        "event": simulator.simulate_steps_event("x", steps, ring, 1.0),
        "overlap": simulator.simulate_steps_event("x", steps, ring, 1.0,
                                                  overlap=True),
    }
    for name, r in results.items():
        assert r.reconfig_s == len(steps) * a, name
        assert len(r.per_step_s) == len(steps), name
        for i in (0, 2, 4):
            assert r.per_step_s[i] == a, (name, i)
        assert sum(r.per_step_s) == pytest.approx(r.total_s), name
    # empty steps contribute no serialization, so all engines agree exactly
    assert results["event"].total_s == results["lockstep"].total_s
    assert results["overlap"].total_s <= results["lockstep"].total_s


def test_relayed_schedule_times_under_both_engines():
    # tight hop budget forces relay sub-steps; both engines must agree on
    # the ordering invariant over the longer schedule
    phys = PhysicalParams(insertion_loss_db_per_hop=4.0)  # H=8
    sched = wrht.build_schedule(256, 16, 1e6, physical=phys)
    ring = _ring(256, 16, physical=phys)
    lock = simulator.simulate_steps("x", sched.steps, ring, 1e6)
    evt = simulator.simulate_steps_event("x", sched.steps, ring, 1e6)
    ovl = simulator.simulate_steps_event("x", sched.steps, ring, 1e6,
                                         overlap=True)
    assert evt.total_s == lock.total_s
    assert ovl.total_s <= lock.total_s
