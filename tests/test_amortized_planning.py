"""Amortized planning layer (DESIGN.md §10).

The contract pinned here is *bit-identity*: the batched multi-candidate
builder must reproduce ``wrht.build_schedule`` exactly — every step's
arrays, wavelengths included, for every ``(m, alltoall)`` candidate,
hop-budget relay cases included — and the batched ``tune_wrht`` must
reproduce the per-candidate ``tune_wrht_reference`` argmin and totals while
being ≥5× faster on a PR-3 sweep tuner cell.  Also covered: the
concatenated First-Fit entry point and the batched ``planner.plan_buckets``
against per-bucket ``plan_bucket``, plus the training-stack wiring
(``plan_gradient_sync``)."""

import time
import types

import numpy as np
import pytest

from repro.core import planner, step_models as sm, timing, wrht
from repro.core.topology import CCW, CW, TransferBatch
from repro.core.wavelength import first_fit_assign, first_fit_assign_concat


def assert_schedules_identical(got: wrht.WRHTSchedule,
                               ref: wrht.WRHTSchedule) -> None:
    assert (got.n, got.w, got.m, got.max_hops) == (ref.n, ref.w, ref.m,
                                                   ref.max_hops)
    assert got.levels == ref.levels
    assert got.level_group_sizes == ref.level_group_sizes
    assert len(got.steps) == len(ref.steps)
    for i, (a, b) in enumerate(zip(got.steps, ref.steps)):
        assert (a.kind, a.level) == (b.kind, b.level), i
        for col in ("src", "dst", "direction", "bits", "wavelength"):
            np.testing.assert_array_equal(
                getattr(a.transfers, col), getattr(b.transfers, col),
                err_msg=f"step {i} column {col}")


# ---------------------------------------------------------------------------
# batched multi-candidate builder: golden bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w,max_hops", [
    (15, 2, None),     # the paper's Fig. 2 scale
    (64, 8, None),
    (64, 8, 4),        # hop budget binds the fan-out
    (100, 5, None),    # ragged groups
    (255, 16, 3),      # deep relays
    (37, 3, 2),        # relays + prime N
    (33, 4, 1),        # tightest budget: every level relayed
    (2, 1, None),      # degenerate pair
])
def test_builder_bit_identical_to_per_candidate(n, w, max_hops):
    batch = wrht.build_candidate_schedules(n, w, 1.0, max_hops=max_hops)
    assert batch  # at least one candidate
    for (m, a2a), got in batch.items():
        ref = wrht.build_schedule(n, w, 1.0, m=m, allow_alltoall=a2a,
                                  validate=True, max_hops=max_hops)
        assert_schedules_identical(got, ref)


def test_builder_absent_noa2a_key_means_identical_schedules():
    """(m, False) is only materialized when the all-to-all was taken; when
    absent, build_schedule(allow_alltoall=False) must equal the (m, True)
    entry."""
    batch = wrht.build_candidate_schedules(64, 8, 1.0)
    missing = [m for (m, _) in batch if (m, False) not in batch]
    assert missing  # large fan-outs never take the all-to-all at N=64
    for m in missing[:3]:
        ref = wrht.build_schedule(64, 8, 1.0, m=m, allow_alltoall=False,
                                  validate=False)
        assert_schedules_identical(batch[(m, True)], ref)


def test_builder_shares_steps_between_variants():
    """The two variants of one fan-out share their common-level Step
    objects — the structural sharing the profile compiler exploits."""
    batch = wrht.build_candidate_schedules(64, 8, 1.0, m_candidates=(2,))
    with_a2a, without = batch[(2, True)], batch[(2, False)]
    shared = {id(s.transfers) for s in with_a2a.steps if s.kind != "alltoall"}
    assert shared <= {id(s.transfers) for s in without.steps}


def test_builder_validate_flag_checks_semantics():
    scheds = wrht.build_candidate_schedules(27, 4, 1.0, validate=True)
    for sched in scheds.values():
        # spot-check against the standalone validator too
        wrht.validate_schedule(sched)


def test_builder_rejects_bad_inputs():
    with pytest.raises(ValueError, match="m must be >= 2"):
        wrht.build_candidate_schedules(16, 4, 1.0, m_candidates=(1,))
    with pytest.raises(ValueError, match="hop budget"):
        wrht.build_candidate_schedules(16, 4, 1.0, max_hops=0)


# ---------------------------------------------------------------------------
# concatenated First-Fit
# ---------------------------------------------------------------------------

def _random_step(rng, n):
    t = int(rng.integers(1, 40))
    src = rng.integers(0, n, size=t)
    off = rng.integers(1, n, size=t)
    dst = (src + off) % n
    direction = np.where(rng.random(t) < 0.5, CW, CCW)
    return TransferBatch.from_arrays(src, dst, direction, 1.0, check=False)


def test_concat_first_fit_matches_per_step():
    rng = np.random.default_rng(7)
    n, w = 96, 64
    steps = [_random_step(rng, n) for _ in range(12)]
    ptr = np.cumsum([0] + [len(s) for s in steps])
    cat = TransferBatch.from_arrays(
        np.concatenate([s.src for s in steps]),
        np.concatenate([s.dst for s in steps]),
        np.concatenate([s.direction for s in steps]),
        1.0, check=False)
    cache: dict = {}
    got = first_fit_assign_concat(cat, ptr, n, w, cache=cache)
    for i, step in enumerate(steps):
        ref = first_fit_assign(step, n, w)
        np.testing.assert_array_equal(
            got.wavelength[ptr[i]:ptr[i + 1]], ref.wavelength, err_msg=str(i))
    # a second pass over translated copies resolves purely from the cache
    before = len(cache)
    shifted = TransferBatch.from_arrays(
        (cat.src + 5) % n, (cat.dst + 5) % n, cat.direction, 1.0, check=False)
    got2 = first_fit_assign_concat(shifted, ptr, n, w, cache=cache)
    np.testing.assert_array_equal(got2.wavelength, got.wavelength)
    assert len(cache) == before


def test_concat_first_fit_rejects_bad_ptr():
    step = TransferBatch.from_arrays([0], [2], CW, 1.0)
    with pytest.raises(ValueError, match="ptr"):
        first_fit_assign_concat(step, [0], 8, 4)


def test_concat_first_fit_cache_safe_across_n_and_w():
    """The shared memo keys carry (n, w): reusing one cache dict across
    ring sizes / wavelength budgets must never replay a stale assignment
    (here: the same arc pattern that fits w=64 must raise at w=2)."""
    from repro.core.wavelength import WavelengthConflictError

    src = np.zeros(5, dtype=np.int64)
    dst = np.arange(1, 6)
    step = TransferBatch.from_arrays(src, dst, CW, 1.0, check=False)
    ptr = np.asarray([0, 5])
    cache: dict = {}
    wide = first_fit_assign_concat(step, ptr, 16, 64, cache=cache)
    assert int(wide.wavelength.max()) == 4
    with pytest.raises(WavelengthConflictError):
        first_fit_assign_concat(step, ptr, 16, 2, cache=cache)
    # and a different ring size re-solves rather than reusing n=16 geometry
    other_n = first_fit_assign_concat(step, ptr, 7, 64, cache=cache)
    ref = first_fit_assign(step, 7, 64)
    np.testing.assert_array_equal(other_n.wavelength, ref.wavelength)


# ---------------------------------------------------------------------------
# batched tuner: bit-identity + the ≥5× acceptance bar
# ---------------------------------------------------------------------------

def assert_tunes_identical(ref, bat) -> None:
    assert ref.candidates == bat.candidates
    np.testing.assert_array_equal(ref.total_s, bat.total_s)
    np.testing.assert_array_equal(ref.steps, bat.steps)
    np.testing.assert_array_equal(ref.best_m, bat.best_m)
    np.testing.assert_array_equal(ref.best_alltoall, bat.best_alltoall)
    np.testing.assert_array_equal(ref.best_total_s, bat.best_total_s)
    assert ref.analytic_m == bat.analytic_m


@pytest.mark.parametrize("n,w,max_hops,timing_mode", [
    (64, 8, None, "lockstep"),
    (64, 8, 4, "lockstep"),      # relay candidates in the sweep
    (96, 8, None, "overlap"),    # event engine over the batched schedules
])
def test_tuner_bit_identical_to_reference(n, w, max_hops, timing_mode):
    d = np.asarray([1e4, 1e6, 62.3e6 * 32])
    timing.clear_caches()
    ref = timing.tune_wrht_reference(n, w, d, max_hops, timing=timing_mode)
    timing.clear_caches()
    bat = timing.tune_wrht(n, w, d, max_hops, timing=timing_mode)
    assert_tunes_identical(ref, bat)


@pytest.mark.slow
def test_tuner_speedup_on_pr3_sweep_cell():
    """Acceptance bar: ≥5× over the per-candidate loop, bit-identical, on a
    PR-3 sweep tuner cell (benchmarks/bench_sweep.measure_tuner; the full
    three-cell run is recorded in BENCH_planner.json).  The N=4096 cell is
    used here because its margin is the widest (~15×) — a CI-noise-proof
    witness of the ≥5× bar."""
    n, w = 4096, 64
    d = sm.PAPER_MODELS_BITS["ResNet50"]
    timing.clear_caches()
    t0 = time.perf_counter()
    ref = timing.tune_wrht_reference(n, w, d)
    ref_s = time.perf_counter() - t0
    timing.clear_caches()
    t0 = time.perf_counter()
    bat = timing.tune_wrht(n, w, d)
    bat_s = time.perf_counter() - t0
    assert_tunes_identical(ref, bat)
    assert ref_s / bat_s >= 5.0, (ref_s, bat_s)


# ---------------------------------------------------------------------------
# planner.plan_buckets == per-bucket plan_bucket
# ---------------------------------------------------------------------------

BUCKETS = [4096.0, 1 << 14, 1 << 20, 1 << 26, 1 << 30, 123456.0]


@pytest.mark.parametrize("axis", [1, 7, 64, 256, 1024])
def test_plan_buckets_matches_plan_bucket_analytic(axis):
    plans = planner.plan_buckets(axis, BUCKETS)
    assert plans == [planner.plan_bucket(axis, b) for b in BUCKETS]


def test_plan_buckets_matches_plan_bucket_analytic_optical_hops():
    p = planner.CostParams.optical(64)
    plans = planner.plan_buckets(1024, BUCKETS, p, m_candidates=(2, 8, 129),
                                 max_hops=5)
    assert plans == [planner.plan_bucket(1024, b, p, m_candidates=(2, 8, 129),
                                         max_hops=5) for b in BUCKETS]


def test_plan_buckets_matches_plan_bucket_simulated():
    p = planner.CostParams.optical(8)
    timing.clear_caches()
    plans = planner.plan_buckets(64, BUCKETS, p, backend="simulated")
    ref = [planner.plan_bucket(64, b, p, backend="simulated") for b in BUCKETS]
    assert plans == ref
    for got, exp in zip(plans, ref):
        assert got.cost_s == exp.cost_s and got.detail == exp.detail


def test_plan_buckets_axis_one_and_errors():
    assert all(pl == planner.Plan("flat", 0.0)
               for pl in planner.plan_buckets(1, BUCKETS))
    p = planner.CostParams.optical(8)
    assert all(pl.strategy == "flat" and pl.cost_s == 0.0 for pl in
               planner.plan_buckets(1, BUCKETS, p, backend="simulated"))
    with pytest.raises(ValueError, match="backend"):
        planner.plan_buckets(64, BUCKETS, backend="magic")
    with pytest.raises(ValueError, match="simulated"):
        planner.plan_buckets(64, BUCKETS, p, backend="simulated",
                             allow=("rd",))


def test_crossover_table_backend_passthrough():
    p = planner.CostParams.optical(8)
    rows = planner.crossover_table(64, params=p, backend="simulated",
                                   max_hops=8)
    assert [set(r) for r in rows] == [
        {"bytes", "strategy", "m", "factors", "cost_us"}] * len(rows)
    # same tie-breaking/selection as the scalar entry point
    scalar = planner.plan_bucket(64, rows[0]["bytes"], p, backend="simulated",
                                 max_hops=8)
    assert rows[0]["strategy"] == scalar.strategy


# ---------------------------------------------------------------------------
# training-stack wiring: one batched planning call at setup
# ---------------------------------------------------------------------------

def _fake_mesh(**shape):
    return types.SimpleNamespace(shape=shape,
                                 axis_names=tuple(shape) + ("model",))


def test_plan_gradient_sync_matches_per_bucket_planner():
    import jax
    import jax.numpy as jnp

    from repro.configs.base import TrainConfig
    from repro.core import bucketing
    from repro.train.train_step import plan_gradient_sync

    tc = TrainConfig(bucket_bytes=1 << 20)
    grads = {
        "emb": jax.ShapeDtypeStruct((512, 128), jnp.float32),
        "w1": jax.ShapeDtypeStruct((128, 512), jnp.float32),
        "b": jax.ShapeDtypeStruct((128,), jnp.float32),
    }
    mesh = _fake_mesh(pod=2, data=8)
    sp = plan_gradient_sync(grads, tc, mesh)
    spec = bucketing.plan_buckets(grads, tc.bucket_bytes)
    assert sp.spec == spec
    assert set(sp.plans) == {"pod", "data"}
    for ax, plans in sp.plans.items():
        assert len(plans) == len(spec.bucket_sizes)
        # bucket bytes are counted in the wire dtype (f32 sync default)
        assert list(plans) == [planner.plan_bucket(mesh.shape[ax], s * 4)
                               for s in spec.bucket_sizes]


def test_bucketed_apply_indexed_passes_indices_and_roundtrips():
    import jax.numpy as jnp

    from repro.core import bucketing

    tree = {"a": jnp.arange(300, dtype=jnp.float32),
            "b": jnp.arange(500, dtype=jnp.float32) * 2}
    spec = bucketing.plan_buckets(tree, max_bucket_bytes=1000)
    seen = []

    def apply_fn(flat, nbytes, i):
        seen.append((i, int(nbytes)))
        return flat * 1.0

    out = bucketing.bucketed_apply_indexed(tree, apply_fn, spec)
    assert [i for i, _ in seen] == list(range(len(spec.bucket_sizes)))
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"], tree["b"])
    with pytest.raises(ValueError, match="BucketSpec"):
        bucketing.bucketed_apply_indexed(
            {"a": tree["a"]}, apply_fn, spec)
