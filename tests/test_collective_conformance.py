"""Property-based differential conformance harness for the scheduled
collective algebra (DESIGN.md §11).

Three layers, each independent of the machinery it checks:

1. **Schedule semantics vs a plain-Python oracle** — every
   ``(collective, n, m, w, max_hops, rwa)`` cell builds a schedule and
   replays it through :func:`interpret_schedule`, a deliberately naive
   per-object interpreter (dict-of-sets, one row at a time) that shares no
   code with the vectorized data-flow in ``repro.core.wrht``.  The oracle's
   end state must match the collective's semantic spec AND the repo's own
   vectorized simulation, bit for bit.
2. **Payload accounting** — chunked collectives carry exactly ``d/n`` per
   transfer, tree collectives the constant full ``d``; wavelength counts
   stay within ``w`` and every lightpath within the hop budget.
3. **Device-twin equivalence** — each scheduled collective's shard_map body
   (``repro.core.collectives``) runs on 8 simulated devices and must
   reproduce the same ownership semantics (device ``i`` owns chunk ``i``,
   broadcast fills every device with the root's value, the all-to-all is a
   message transpose).

The hypothesis sweep widens layer 1; the ``deep`` lane re-runs it with
``REPRO_DEEP_EXAMPLES`` (default 300) examples on the scheduled CI job.
"""

import os
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import compose, wrht
from repro.core.topology import FailureMask, Ring
from repro.core.wavelength import (
    FailedResourceError,
    InsertionLossError,
    WavelengthConflictError,
    validate_no_conflicts,
)

ALL_COLLECTIVES = tuple(wrht.COLLECTIVES)


# ---------------------------------------------------------------------------
# layer 1: the independent oracle
# ---------------------------------------------------------------------------

def interpret_schedule(sched: wrht.WRHTSchedule) -> dict:
    """Naive per-row replay: ``state[(node, chunk)]`` is the set of original
    contributions held in node's partial of that chunk (chunk 0 stands for
    the whole vector on unchunked collectives).  Reads precede writes within
    a step; ``broadcast`` steps overwrite, everything else accumulates."""
    n = sched.n
    chunked = wrht.COLLECTIVES[sched.collective].chunked
    chunks_axis = range(n) if chunked else (0,)
    state = {}
    for v in range(n):
        for c in chunks_axis:
            if sched.collective == "all_gather":
                state[(v, c)] = {v} if c == v else set()
            else:
                state[(v, c)] = {v}
    for step in sched.steps:
        b = step.transfers
        incoming: dict[tuple[int, int], set] = {}
        for row in range(len(b)):
            src, dst = int(b.src[row]), int(b.dst[row])
            c = int(step.chunks[row]) if step.chunks is not None else 0
            incoming.setdefault((dst, c), set()).update(state[(src, c)])
        for key, vals in incoming.items():
            if step.kind == "broadcast":
                state[key] = set(vals)
            else:
                state[key] |= vals
    return state


def check_cell(collective: str, n: int, m: int | None, w: int,
               max_hops: int | None, rwa: str, d: float = 1e6,
               failures: FailureMask | None = None) -> None:
    degraded = failures is not None and not failures.empty
    try:
        sched = wrht.build_collective_schedule(
            collective, n, w, d, m=m, max_hops=max_hops, rwa=rwa,
            failures=failures)
    except wrht.DegradedInfeasibleError:
        # the uniform infeasibility signal of degraded building — a valid
        # outcome under a mask (severed ring, no surviving λ, ...), never
        # valid on a healthy fabric
        assert degraded
        return
    except WavelengthConflictError:
        # only the single-step all-to-all can run out of wavelengths —
        # either at the ⌈n²/8⌉ budget precheck or in First Fit itself
        # (the bound is necessary, not sufficient for a greedy RWA)
        assert collective == "alltoall" and not degraded
        return
    except InsertionLossError:
        assert collective == "alltoall" and max_hops is not None
        assert not degraded
        assert n // 2 > max_hops
        return
    check_schedule(sched, collective, n, w, max_hops=max_hops, d=d,
                   failures=failures)


def check_schedule(sched: wrht.WRHTSchedule, collective: str, n: int, w: int,
                   max_hops: int | None = None, d: float = 1e6,
                   failures: FailureMask | None = None) -> None:
    """Layers 1+2 against an already-built schedule — factored out of
    :func:`check_cell` so composed constituent views
    (:meth:`~repro.core.compose.ComposedSchedule.constituent_view`) run
    through the *identical* oracle machinery as plain schedules."""
    spec = wrht.COLLECTIVES[collective]
    degraded = failures is not None and not failures.empty

    # ---- structural: RWA + hop budget + wavelength budget + failure mask
    ring = Ring(max(n, 2), w)
    for step in sched.steps:
        validate_no_conflicts(step.transfers, ring.n, w, max_hops=max_hops,
                              failures=failures)
        assert step.wavelengths <= w
    if degraded:
        assert sched.failures == failures

    # ---- payload accounting per the spec ----
    want_bits = d / n if spec.chunked else d
    for step in sched.steps:
        if len(step.transfers):
            assert (step.transfers.bits == want_bits).all(), (
                collective, n, step.kind)

    # ---- semantics: oracle end state matches the spec ----
    state = interpret_schedule(sched)
    full = set(range(n))
    if collective == "allreduce":
        assert all(state[(v, 0)] == full for v in range(n))
    elif collective == "broadcast":
        root = wrht.broadcast_root(sched)
        if n > 1:
            assert all(state[(v, 0)] == {root} for v in range(n))
    elif collective == "reduce_scatter":
        # node i owns the complete reduction of chunk i
        assert all(state[(v, v)] == full for v in range(n))
    elif collective == "all_gather":
        # every node holds every chunk, each carrying exactly its originator
        assert all(state[(v, c)] == {c}
                   for v in range(n) for c in range(n))
    else:  # alltoall: every ordered pair exchanged exactly once
        if n > 1:
            b = sched.steps[0].transfers
            pairs = sorted(zip(b.src.tolist(), b.dst.tolist()))
            assert pairs == sorted((i, j) for i in range(n) for j in range(n)
                                   if i != j)
            assert np.array_equal(sched.steps[0].chunks, b.dst)

    # ---- differential: the repo's vectorized data-flow agrees row-for-row
    if collective in ("allreduce", "broadcast"):
        got = wrht.simulate_contributions(sched)
        assert got == [frozenset(state[(v, 0)]) for v in range(n)]
    elif collective in ("reduce_scatter", "all_gather"):
        got = wrht.simulate_chunk_contributions(sched)
        assert got == [[frozenset(state[(v, c)]) for c in range(n)]
                       for v in range(n)]


# deterministic sweep: spec-aware axes (the fan-out only exists for trees,
# the reference RWA is spot-checked, hop budgets exercise relays)
def _cells():
    cells = []
    for coll in ALL_COLLECTIVES:
        tree = wrht.COLLECTIVES[coll].tree
        for n in (1, 2, 3, 5, 8, 13, 16):
            for w in (2, 8, 64):
                for m in ((None, 2, 3) if tree else (None,)):
                    cells.append((coll, n, m, w, None, "fast"))
        cells.append((coll, 33, 3 if tree else None, 8, None, "fast"))
        cells.append((coll, 64, None, 8, None, "fast"))
        # hop budgets: relays for the trees, reach checks for the mesh
        for hops in (2, 5):
            cells.append((coll, 16, None, 8, hops, "fast"))
            cells.append((coll, 33, None, 64, hops, "fast"))
        # the reference (per-object greedy) RWA must agree
        cells.append((coll, 13, None, 4, None, "reference"))
        cells.append((coll, 16, 3 if tree else None, 64, 3, "reference"))
    return cells


@pytest.mark.parametrize("coll", ALL_COLLECTIVES)
def test_conformance_sweep(coll):
    for cell in _cells():
        if cell[0] == coll:
            check_cell(*cell)


def test_reduce_scatter_then_all_gather_composes_to_allreduce():
    """The ZeRO-style decomposition: chain the RS oracle's end state into
    the AG oracle — every node must end with the full reduction of every
    chunk, i.e. the composition is semantically an all-reduce."""
    n, w = 13, 8
    rs = wrht.build_collective_schedule("reduce_scatter", n, w, 1e6)
    ag = wrht.build_collective_schedule("all_gather", n, w, 1e6)
    state = interpret_schedule(rs)
    # hand the owned shards to the all-gather as its initial ownership
    ag_state = {(v, c): set() for v in range(n) for c in range(n)}
    for v in range(n):
        ag_state[(v, v)] = set(state[(v, v)])
    for step in ag.steps:
        b = step.transfers
        incoming = {}
        for row in range(len(b)):
            src, dst = int(b.src[row]), int(b.dst[row])
            c = int(step.chunks[row])
            incoming.setdefault((dst, c), set()).update(ag_state[(src, c)])
        for key, vals in incoming.items():
            ag_state[key] |= vals
    full = set(range(n))
    assert all(ag_state[(v, c)] == full for v in range(n) for c in range(n))


def test_validate_schedule_catches_semantic_violations():
    """The in-repo validator must reject a schedule whose data-flow breaks
    its collective's spec (differential guard on the validator itself)."""
    sched = wrht.build_collective_schedule("reduce_scatter", 8, 8, 1e6)
    sched.steps = sched.steps[:-1]          # drop the last ring step
    with pytest.raises(AssertionError, match="reduce-scatter semantics"):
        wrht.validate_schedule(sched)

    sched = wrht.build_collective_schedule("all_gather", 8, 8, 1e6)
    sched.steps = sched.steps[1:]
    with pytest.raises(AssertionError, match="all-gather semantics"):
        wrht.validate_schedule(sched)

    sched = wrht.build_collective_schedule("broadcast", 9, 4, 1e6)
    sched.steps = sched.steps[:-1]
    with pytest.raises(AssertionError, match="broadcast semantics"):
        wrht.validate_schedule(sched)

    sched = wrht.build_collective_schedule("alltoall", 8, 64, 1e6)
    batch = sched.steps[0].transfers
    sched.steps[0] = wrht.Step(
        "alltoall", 0,
        type(batch)(batch.src[:-1], batch.dst[:-1], batch.direction[:-1],
                    batch.bits[:-1], batch.wavelength[:-1]),
        chunks=sched.steps[0].chunks[:-1])
    with pytest.raises(AssertionError, match="all-to-all semantics"):
        wrht.validate_schedule(sched)


def test_collective_steps_closed_forms():
    for n in (2, 5, 16, 100):
        assert wrht.collective_steps("reduce_scatter", n) == n - 1
        assert wrht.collective_steps("all_gather", n) == n - 1
        assert wrht.collective_steps("alltoall", n) == 1
        for m in (2, 3, 5):
            sched = wrht.build_collective_schedule("broadcast", n, 64, 1.0,
                                                   m=m)
            assert sched.num_steps == wrht.collective_steps("broadcast", n,
                                                            m=m)
    assert wrht.collective_steps("allreduce", 1) == 0


def test_plan_field_normalization():
    """Non-tree collectives must not fragment plan-cache keys on (m, a2a)."""
    assert wrht.collective_plan_fields("reduce_scatter", 7, False) == (None, True)
    assert wrht.collective_plan_fields("alltoall", 3, False) == (None, True)
    assert wrht.collective_plan_fields("broadcast", 7, True) == (7, False)
    assert wrht.collective_plan_fields("allreduce", 7, False) == (7, False)
    with pytest.raises(ValueError, match="unknown collective"):
        wrht.coerce_collective("scatter_gather")


# ---------------------------------------------------------------------------
# failure-mask lane: degraded schedules must satisfy the same oracles
# ---------------------------------------------------------------------------
# Degraded building only *re-routes* (direction flips, O/E/O relay detours)
# and *shrinks budgets* — it never changes what data moves where, so every
# semantic oracle above applies unchanged.  check_cell additionally runs the
# structural validator WITH the mask, proving no schedule touches a dead
# arc/λ/transceiver, and accepts DegradedInfeasibleError as the one valid
# alternative outcome.

def _failure_masks(n: int) -> list[FailureMask]:
    return [
        # one dead CW span
        FailureMask(dead_segments=((0, 1),)),
        # one dead λ at one node
        FailureMask(dead_wavelengths=((n // 2, 0),)),
        # the ISSUE's acceptance cell: ≥1 dead arc AND ≥1 dead λ (plus a
        # dead transceiver for good measure)
        FailureMask(dead_segments=((1, n // 3),),
                    dead_wavelengths=((0, 0),),
                    dead_transceivers=((n // 2, 1),)),
        # both fibers cut at one span: the ring degenerates to a line —
        # still routable (every pair has a one-sided path)
        FailureMask(dead_segments=((0, 2), (1, 2))),
        # ring severed at two distinct spans on both lanes: some pairs are
        # unreachable — builders must raise DegradedInfeasibleError, which
        # check_cell accepts (and would reject on a healthy fabric)
        FailureMask(dead_segments=((0, 0), (1, 0), (0, n // 2), (1, n // 2))),
    ]


@pytest.mark.parametrize("coll", ALL_COLLECTIVES)
def test_conformance_failure_masks(coll):
    for n in (4, 5, 8, 16):
        for mask in _failure_masks(n):
            check_cell(coll, n, None, 8, None, "fast", failures=mask)
            check_cell(coll, n, None, 8, 3, "fast", failures=mask)
    # tree fan-outs and the reference RWA under the combined mask
    mask = _failure_masks(16)[2]
    if wrht.COLLECTIVES[coll].tree:
        check_cell(coll, 16, 3, 8, None, "fast", failures=mask)
    check_cell(coll, 13, None, 4, None, "reference", failures=mask)


def test_empty_mask_is_healthy():
    """FailureMask.empty must normalize to the healthy build bit-for-bit."""
    healthy = wrht.build_collective_schedule("allreduce", 16, 8, 1e6)
    masked = wrht.build_collective_schedule("allreduce", 16, 8, 1e6,
                                            failures=FailureMask())
    assert masked.failures is None
    assert wrht.simulate_contributions(masked) == \
        wrht.simulate_contributions(healthy)
    assert masked.num_steps == healthy.num_steps


def test_validator_rejects_failed_resources():
    """Negative lane: a healthy schedule run against a mask that kills a
    resource it uses must trip FailedResourceError — for each of the three
    resource kinds (arc, λ, transceiver)."""
    n = w = 8
    sched = wrht.build_collective_schedule("allreduce", n, w, 1e6)
    b = sched.steps[0].transfers
    assert len(b), "first step unexpectedly empty"
    lane, start, _hops = b.arcs(n)
    # covered directed span of row 0
    dead_arc = FailureMask(dead_segments=((int(lane[0]), int(start[0]) % n),))
    with pytest.raises(FailedResourceError, match="dead fiber span"):
        validate_no_conflicts(b, n, w, failures=dead_arc)
    # the λ row 0 adds at its source
    dead_lam = FailureMask(
        dead_wavelengths=((int(b.src[0]), int(b.wavelength[0])),))
    with pytest.raises(FailedResourceError, match="dead wavelength"):
        validate_no_conflicts(b, n, w, failures=dead_lam)
    # row 0's transmit-side transceiver
    dead_trx = FailureMask(dead_transceivers=((int(b.src[0]), int(lane[0])),))
    with pytest.raises(FailedResourceError, match="dead transceiver"):
        validate_no_conflicts(b, n, w, failures=dead_trx)
    # the degraded builder's own output never trips any of these
    degraded = wrht.build_collective_schedule("allreduce", n, w, 1e6,
                                              failures=dead_arc)
    for step in degraded.steps:
        validate_no_conflicts(step.transfers, n, w, failures=dead_arc)


# ---------------------------------------------------------------------------
# composed lane: interleaved schedules still satisfy every constituent oracle
# ---------------------------------------------------------------------------
# The composer (DESIGN.md §13) re-assigns wavelengths on fused slots but must
# never change what data moves where: each constituent view of a composed
# pipeline is run through the *same* check_schedule machinery as a plain
# build — structural RWA under the mask, payload accounting, the naive oracle
# AND the vectorized differential, per collective.

def check_composed_cell(start: str, n: int, w: int, depth: int,
                        max_hops: int | None = None, d: float = 1e6,
                        failures: FailureMask | None = None,
                        offsets: tuple | None = None) -> None:
    degraded = failures is not None and not failures.empty
    colls = compose.pipeline_collectives(start, depth)
    try:
        composed = compose.build_pipeline_schedule(
            start, n, w, d, depth, max_hops=max_hops, failures=failures,
            offsets=offsets)
    except wrht.DegradedInfeasibleError:
        assert degraded
        return
    except WavelengthConflictError:
        assert "alltoall" in colls and not degraded
        return
    except InsertionLossError:
        assert "alltoall" in colls and max_hops is not None
        assert not degraded
        return
    compose.validate_composed(composed)
    assert composed.depth == depth
    assert composed.num_steps <= composed.serial_steps
    for j, coll in enumerate(colls):
        check_schedule(composed.constituent_view(j), coll, n, w,
                       max_hops=max_hops, d=d, failures=failures)


@pytest.mark.parametrize("start", ALL_COLLECTIVES)
def test_composed_conformance_sweep(start):
    for n in (2, 3, 5, 8, 16):
        for w in (1, 2, 8, 64):
            for depth in (1, 2, 3, 4):
                check_composed_cell(start, n, w, depth)
    # staggered starts (the bucket pipeline's ramp-up shape)
    check_composed_cell(start, 8, 8, 3, offsets=(0, 1, 2))
    # hop-budgeted fusion
    check_composed_cell(start, 16, 8, 2, max_hops=3)


def test_composed_heterogeneous_mix_conformance():
    """A mix the partner map never produces — a reduce-scatter with a
    broadcast prefetch riding the same ring — still satisfies both
    constituent oracles after interleaving."""
    n, w, d = 13, 8, 1e6
    rs = wrht.build_collective_schedule("reduce_scatter", n, w, d)
    bc = wrht.build_collective_schedule("broadcast", n, w, d)
    composed = compose.compose_schedules([rs, bc])
    compose.validate_composed(composed)
    check_schedule(composed.constituent_view(0), "reduce_scatter", n, w, d=d)
    check_schedule(composed.constituent_view(1), "broadcast", n, w, d=d)


@pytest.mark.parametrize("start", ("reduce_scatter", "all_gather",
                                   "broadcast"))
def test_composed_conformance_failure_masks(start):
    for n in (4, 8, 16):
        for mask in _failure_masks(n):
            check_composed_cell(start, n, 8, 2, failures=mask)
    check_composed_cell(start, 16, 8, 3,
                        failures=_failure_masks(16)[2])


# ---------------------------------------------------------------------------
# hypothesis sweep (layer 1, randomized) — fast lane + scheduled deep lane
# ---------------------------------------------------------------------------

DEEP_EXAMPLES = int(os.environ.get("REPRO_DEEP_EXAMPLES", "300"))

if HAVE_HYPOTHESIS:
    _strategy = dict(
        coll=st.sampled_from(ALL_COLLECTIVES),
        n=st.integers(min_value=1, max_value=33),
        m=st.one_of(st.none(), st.integers(min_value=2, max_value=9)),
        w=st.sampled_from([1, 2, 4, 8, 64]),
        max_hops=st.one_of(st.none(), st.integers(min_value=1, max_value=8)),
        rwa=st.sampled_from(["fast", "reference"]),
    )

    @settings(max_examples=25, deadline=None)
    @given(**_strategy)
    def test_conformance_hypothesis(coll, n, m, w, max_hops, rwa):
        check_cell(coll, n, m, w, max_hops, rwa)

    @pytest.mark.deep
    @settings(max_examples=DEEP_EXAMPLES, deadline=None)
    @given(**_strategy)
    def test_conformance_hypothesis_deep(coll, n, m, w, max_hops, rwa):
        check_cell(coll, n, m, w, max_hops, rwa)

    # randomized failure masks: raw draws are reduced mod (n, w) inside the
    # test so the strategy stays independent of the drawn cell size
    _fail_strategy = dict(
        coll=st.sampled_from(ALL_COLLECTIVES),
        n=st.integers(min_value=2, max_value=33),
        w=st.sampled_from([2, 4, 8, 64]),
        max_hops=st.one_of(st.none(), st.integers(min_value=2, max_value=8)),
        segs=st.lists(st.tuples(st.integers(0, 1), st.integers(0, 99)),
                      max_size=3),
        lams=st.lists(st.tuples(st.integers(0, 99), st.integers(0, 63)),
                      max_size=3),
        trx=st.lists(st.tuples(st.integers(0, 99), st.integers(0, 1)),
                     max_size=2),
    )

    def _mask_cell(coll, n, w, max_hops, segs, lams, trx):
        mask = FailureMask(
            dead_segments=tuple((l, s % n) for l, s in segs),
            dead_wavelengths=tuple((v % n, lam % w) for v, lam in lams),
            dead_transceivers=tuple((v % n, l) for v, l in trx))
        check_cell(coll, n, None, w, max_hops, "fast", failures=mask)

    @settings(max_examples=25, deadline=None)
    @given(**_fail_strategy)
    def test_conformance_failure_hypothesis(coll, n, w, max_hops, segs,
                                            lams, trx):
        _mask_cell(coll, n, w, max_hops, segs, lams, trx)

    @pytest.mark.deep
    @settings(max_examples=DEEP_EXAMPLES, deadline=None)
    @given(**_fail_strategy)
    def test_conformance_failure_hypothesis_deep(coll, n, w, max_hops, segs,
                                                 lams, trx):
        _mask_cell(coll, n, w, max_hops, segs, lams, trx)

    # randomized composed pipelines: (start, n, w, depth, stagger) cells,
    # each constituent view re-checked by its own oracle after interleaving
    _composed_strategy = dict(
        start=st.sampled_from(ALL_COLLECTIVES),
        n=st.integers(min_value=2, max_value=17),
        w=st.sampled_from([1, 2, 4, 8, 64]),
        depth=st.integers(min_value=1, max_value=4),
        stagger=st.booleans(),
    )

    def _composed_cell(start, n, w, depth, stagger):
        offsets = tuple(range(depth)) if stagger else None
        check_composed_cell(start, n, w, depth, offsets=offsets)

    @settings(max_examples=25, deadline=None)
    @given(**_composed_strategy)
    def test_composed_conformance_hypothesis(start, n, w, depth, stagger):
        _composed_cell(start, n, w, depth, stagger)

    @pytest.mark.deep
    @settings(max_examples=DEEP_EXAMPLES, deadline=None)
    @given(**_composed_strategy)
    def test_composed_conformance_hypothesis_deep(start, n, w, depth,
                                                  stagger):
        _composed_cell(start, n, w, depth, stagger)
else:  # pragma: no cover - exercised only without hypothesis installed
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_conformance_hypothesis():
        pass


# ---------------------------------------------------------------------------
# layer 3: device-level shard_map twins on 8 simulated devices
# ---------------------------------------------------------------------------
# The subprocess uses a shard_map compat shim (jax.shard_map, else the
# experimental API) so the twins run even on jax builds that predate
# jax.shard_map — unlike the AxisType-gated mesh tests, nothing here needs
# a named-axis-typed mesh.

TWINS = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import collectives as C

try:
    _sm = jax.shard_map
    def smap(body):
        return _sm(body, mesh=mesh, in_specs=P('ax'), out_specs=P('ax'),
                   axis_names={'ax'})
except AttributeError:
    from jax.experimental.shard_map import shard_map as _sm
    def smap(body):
        return _sm(body, mesh=mesh, in_specs=P('ax'), out_specs=P('ax'),
                   check_rep=False)

S = 8
mesh = Mesh(np.array(jax.devices()).reshape(S,), ('ax',))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(S, 131)).astype(np.float32))  # odd: pad paths
xs = np.asarray(x)
total = xs.sum(0)
pad = (-131) % S
padded = np.concatenate([total, np.zeros(pad, np.float32)])
shards = padded.reshape(S, -1)

def run(body):
    return np.asarray(jax.jit(smap(body))(x))

# reduce-scatter twins: device i ends owning fully-reduced chunk i — the
# exact ownership map of the scheduled reduce_scatter collective
for name, fn in (('ring', C.reduce_scatter_ring),
                 ('alltoall', C.reduce_scatter_alltoall)):
    got = run(lambda st, fn=fn: fn(st[0], 'ax', S)[None])
    assert np.abs(got - shards).max() < 1e-4, ('rs', name)
print('RS_TWINS_OK')

# all-gather twins: start from the owned shard, end with the concatenation
for name, fn in (('ring', C.all_gather_ring), ('alltoall', C.all_gather_alltoall)):
    def body(st, fn=fn):
        shard = C.reduce_scatter_ring(st[0], 'ax', S)
        return fn(shard, 'ax', S)[None]
    got = run(body)
    assert np.abs(got - padded[None]).max() < 1e-4, ('ag', name)
print('AG_TWINS_OK')

# rs+ag composition == psum (the planned_sharded bucket body)
def rs_ag(st):
    flat = st[0]
    L = flat.shape[0]
    shard = C.reduce_scatter_ring(flat, 'ax', S)
    return C.all_gather_ring(shard, 'ax', S)[:L][None]
got = run(rs_ag)
assert np.abs(got - total[None]).max() < 1e-4
print('RS_AG_COMPOSE_OK')

# broadcast twin: every device ends with the root's (device 0) value,
# matching the scheduled broadcast's everyone-holds-exactly-the-root spec
for m in (2, 3, 5):
    got = run(lambda st, m=m: C.broadcast_wrht_tree(st[0], 'ax', S, m=m)[None])
    assert np.abs(got - xs[0][None]).max() == 0.0, m
print('BCAST_TWIN_OK')

# alltoall twin: a message transpose, the device face of the scheduled
# one-step full-mesh exchange
y = jnp.asarray(rng.normal(size=(S, S, 5)).astype(np.float32))
got = np.asarray(jax.jit(smap(lambda st: C.alltoall_ppermute(st[0], 'ax', S)[None]))(y))
assert np.abs(got - np.asarray(y).transpose(1, 0, 2)).max() == 0.0
print('A2A_TWIN_OK')
"""


def test_device_twins_match_scheduled_semantics(subproc):
    out = subproc(TWINS)
    for marker in ("RS_TWINS_OK", "AG_TWINS_OK", "RS_AG_COMPOSE_OK",
                   "BCAST_TWIN_OK", "A2A_TWIN_OK"):
        assert marker in out


PLANNED_SHARDED = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.configs.base import TrainConfig
from repro.train import train_step as TS

try:
    _sm = jax.shard_map
    def smap(body, mesh, spec):
        return _sm(body, mesh=mesh, in_specs=spec, out_specs=spec,
                   axis_names={'data', 'pod'})
except AttributeError:
    from jax.experimental.shard_map import shard_map as _sm
    def smap(body, mesh, spec):
        return _sm(body, mesh=mesh, in_specs=spec, out_specs=spec,
                   check_rep=False)

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ('data', 'pod'))
tc = TrainConfig(sync_algorithm="planned_sharded", bucket_bytes=1 << 10)
rng = np.random.default_rng(0)
tree = {k: rng.normal(size=(8, n)).astype(np.float32)
        for k, n in (('a', 37), ('b', 129), ('c', 513))}

plans = TS.plan_gradient_sync(
    jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], jnp.float32),
                 tree),
    tc, mesh, sharded=True)
assert plans.rs_plans and plans.ag_plans
strategies = {p.strategy for pls in plans.rs_plans.values() for p in pls}
assert strategies <= {'flat', 'alltoall'}, strategies

def body(stacked):
    local = jax.tree.map(lambda x: x[0], stacked)
    out, _ = TS.sync_gradients(local, tc, mesh, sync_plans=plans)
    return jax.tree.map(lambda x: x[None], out)

spec = P(('data', 'pod'))
got = jax.jit(smap(body, mesh, spec))(tree)
for k, v in tree.items():
    want = np.asarray(v).mean(axis=0)
    assert np.abs(np.asarray(got[k]) - want[None]).max() < 1e-5, k
print('PLANNED_SHARDED_OK', sorted(strategies))
"""


def test_planned_sharded_sync_equals_mean(subproc):
    """``sync_algorithm="planned_sharded"``'s bucket body (RS down the DP
    axes, AG back up, per-bucket planned strategies) produces exactly the
    DP-mean gradients on a 4×2 device mesh — the device-level face of the
    acceptance criterion (the full train-loop equality runs in
    tests/test_system.py's multi-device E2E)."""
    assert "PLANNED_SHARDED_OK" in subproc(PLANNED_SHARDED)
