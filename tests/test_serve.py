"""Serving engine: determinism, batching equivalence, EOS handling."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api as mapi
from repro.serve import Engine


def _engine(batch_slots=2, arch="qwen2-1.5b"):
    cfg = registry.get(arch, smoke=True)
    api = mapi.get_api(cfg, remat="none")
    params = api.init(jax.random.key(0))
    return cfg, Engine(cfg, params, batch_slots=batch_slots, max_seq=64)


def test_greedy_decode_deterministic():
    _, e1 = _engine()
    _, e2 = _engine()
    r1 = e1.submit([5, 6, 7], max_new_tokens=6)
    r2 = e2.submit([5, 6, 7], max_new_tokens=6)
    e1.run(), e2.run()
    assert r1.output == r2.output
    assert len(r1.output) == 6


def test_batched_equals_singleton():
    """A request's output must not depend on its batch-mates."""
    _, eng = _engine(batch_slots=2)
    ra = eng.submit([9, 10, 11], max_new_tokens=5)
    rb = eng.submit([3, 4], max_new_tokens=5)
    eng.run()

    _, solo = _engine(batch_slots=2)
    rs = solo.submit([9, 10, 11], max_new_tokens=5)
    solo.run()
    assert ra.output == rs.output


def test_eos_stops_generation():
    cfg, eng = _engine()
    r = eng.submit([1, 2, 3], max_new_tokens=8)
    eng.run()
    eos = r.output[0]
    _, eng2 = _engine()
    r2 = eng2.submit([1, 2, 3], max_new_tokens=8, eos_id=eos)
    eng2.run()
    assert len(r2.output) == 1 and r2.output[0] == eos


def test_queue_drains_multiple_rounds():
    _, eng = _engine(batch_slots=2)
    reqs = [eng.submit([i + 1, i + 2], max_new_tokens=3) for i in range(5)]
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in reqs)
