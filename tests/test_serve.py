"""Serving engine: determinism, batching equivalence, EOS handling,
submit-time KV-geometry validation, finish reasons, bucket-bounded jit
cache, and the round_log → traffic-source bridge."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import traffic
from repro.models import api as mapi
from repro.serve import Engine


def _engine(batch_slots=2, arch="qwen2-1.5b"):
    cfg = registry.get(arch, smoke=True)
    api = mapi.get_api(cfg, remat="none")
    params = api.init(jax.random.key(0))
    return cfg, Engine(cfg, params, batch_slots=batch_slots, max_seq=64)


def test_greedy_decode_deterministic():
    _, e1 = _engine()
    _, e2 = _engine()
    r1 = e1.submit([5, 6, 7], max_new_tokens=6)
    r2 = e2.submit([5, 6, 7], max_new_tokens=6)
    e1.run(), e2.run()
    assert r1.output == r2.output
    assert len(r1.output) == 6


def test_batched_equals_singleton():
    """A request's output must not depend on its batch-mates."""
    _, eng = _engine(batch_slots=2)
    ra = eng.submit([9, 10, 11], max_new_tokens=5)
    rb = eng.submit([3, 4], max_new_tokens=5)
    eng.run()

    _, solo = _engine(batch_slots=2)
    rs = solo.submit([9, 10, 11], max_new_tokens=5)
    solo.run()
    assert ra.output == rs.output


def test_eos_stops_generation():
    cfg, eng = _engine()
    r = eng.submit([1, 2, 3], max_new_tokens=8)
    eng.run()
    eos = r.output[0]
    _, eng2 = _engine()
    r2 = eng2.submit([1, 2, 3], max_new_tokens=8, eos_id=eos)
    eng2.run()
    assert len(r2.output) == 1 and r2.output[0] == eos


def test_queue_drains_multiple_rounds():
    _, eng = _engine(batch_slots=2)
    reqs = [eng.submit([i + 1, i + 2], max_new_tokens=3) for i in range(5)]
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in reqs)


def test_submit_rejects_prompt_overflowing_kv_cache():
    _, eng = _engine()  # max_seq=64
    with pytest.raises(ValueError, match="max_seq=64"):
        eng.submit(list(range(1, 65)))  # fills all 64 positions at prefill
    with pytest.raises(ValueError, match="max_seq=64"):
        eng.submit(list(range(1, 80)))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=0)
    eng.submit(list(range(1, 64)))  # 63 tokens: one decode slot left — fits


def test_finish_reasons():
    # budget
    _, eng = _engine()
    r = eng.submit([1, 2, 3], max_new_tokens=4)
    eng.run()
    assert r.finish_reason == "budget" and len(r.output) == 4
    # eos (probe greedy's first token, then rerun with it as eos_id)
    eos = r.output[0]
    _, eng2 = _engine()
    r2 = eng2.submit([1, 2, 3], max_new_tokens=8, eos_id=eos)
    eng2.run()
    assert r2.finish_reason == "eos"
    # seq_limit: budget larger than the cache positions left after prefill
    _, eng3 = _engine()
    r3 = eng3.submit(list(range(1, 61)), max_new_tokens=32)
    eng3.run()
    assert r3.finish_reason == "seq_limit"
    assert len(r3.output) < 32


def test_batch_bucket_sized_to_admitted_count():
    """A half-empty round must trace the admitted-count bucket, not the
    full batch_slots width — and re-serving the same shape must not
    retrace (the jit bucket cache stays bounded)."""
    _, eng = _engine(batch_slots=4)
    eng.submit([1, 2, 3], max_new_tokens=2)
    eng.run()  # round of 1
    assert eng.prefill_traces == 1 and eng.decode_traces == 1
    assert eng.round_log[-1].batch == 1

    for _ in range(4):
        eng.submit([4, 5, 6], max_new_tokens=2)
    eng.run()  # round of 4: new bucket, one more trace each
    assert eng.prefill_traces == 2 and eng.decode_traces == 2
    assert eng.round_log[-1].batch == 4

    eng.submit([7, 8], max_new_tokens=2)
    eng.run()  # round of 1 again, shorter prompt: decode bucket reused
    assert eng.decode_traces == 2
    assert eng.round_log[-1].batch == 1


def test_round_log_feeds_traffic_source():
    """The serving bridge end-to-end: a real engine's rounds become
    all-gather jobs sized from the model's KV/activation shapes, and the
    traffic simulator serves them alongside a training tenant."""
    cfg, eng = _engine(batch_slots=2)
    for i in range(3):
        eng.submit([i + 1, i + 2, i + 3], max_new_tokens=3)
    eng.run()
    assert len(eng.round_log) == 2
    src = traffic.ServingTrafficSource.from_engine(eng, round_period_s=1e-3)
    jobs = src.jobs(1.0)
    assert jobs
    kv = traffic.kv_bits_per_token(cfg, src.compute_bits)
    r0 = eng.round_log[0]
    assert jobs[0].d_bits == r0.admitted * r0.prefill_len * kv
    train = [traffic.CollectiveJob("train", 0.0, "allreduce", 2**20 * 8)]
    sim = traffic.RingTrafficSim(8, policy="shared")
    res = sim.run(sorted(jobs + train,
                         key=lambda j: (j.arrival_s, j.tenant)))
    assert set(res.tenants) == {"serve", "train"}
