"""Device-level all-reduce zoo == psum, on 8 simulated devices (subprocess)."""

import pytest

CODE_ALGOS = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.core import collectives as C

mesh = jax.make_mesh((8,), ('ax',), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 129)).astype(np.float32))  # odd length: pad paths
want = np.tile(np.asarray(x).sum(0, keepdims=True), (8, 1))
with jax.set_mesh(mesh):
    for alg, kw in [('psum', {}), ('ring', {}), ('rd', {}), ('bt', {}),
                    ('wrht', {'m': 3}), ('wrht', {'m': 3, 'alltoall_max': 4}),
                    ('wrht', {'m': 5, 'alltoall_max': 2}), ('wrht', {'m': 8}),
                    ('wrht', {'m': 2, 'alltoall_max': None})]:
        f = jax.jit(C.make_sharded_allreduce(mesh, 'ax', alg, **kw))
        got = np.asarray(f(x))
        err = np.abs(got - want).max()
        assert err < 1e-4, (alg, kw, err)
print('ALGOS_OK')
"""

CODE_HIER = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.core import collectives as C

mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'), axis_types=(AxisType.Auto,)*3)
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(size=(4, 37)).astype(np.float32))  # pod*data rows

for mode in ('faithful', 'scatter', 'flat'):
    def body(stacked):
        local = stacked[0]
        out = C.hierarchical_allreduce(local, ('data', 'pod'), (2, 2), mode=mode)
        return out[None]
    f = jax.shard_map(body, mesh=mesh, in_specs=P(('pod', 'data')),
                      out_specs=P(('pod', 'data')), axis_names={'pod', 'data'})
    with jax.set_mesh(mesh):
        got = np.asarray(jax.jit(f)(x))
    want = np.tile(np.asarray(x).sum(0, keepdims=True), (4, 1))
    assert np.abs(got - want).max() < 1e-4, mode
print('HIER_OK')
"""

CODE_COMPRESS = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.core import compression as comp

mesh = jax.make_mesh((8,), ('ax',), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(2)
x = jnp.asarray(rng.normal(size=(8, 257)).astype(np.float32))

def body(stacked):
    return comp.compressed_allreduce_rd(stacked[0], 'ax', 8)[None]
f = jax.shard_map(body, mesh=mesh, in_specs=P('ax'), out_specs=P('ax'), axis_names={'ax'})
with jax.set_mesh(mesh):
    got = np.asarray(jax.jit(f)(x))
want = np.asarray(x).sum(0, keepdims=True)
rel = np.abs(got - want).max() / np.abs(want).max()
assert rel < 0.05, rel  # int8 quantization error over log2(8)=3 hops
print('COMPRESS_OK', rel)
"""


def test_all_algorithms_match_psum(subproc):
    assert "ALGOS_OK" in subproc(CODE_ALGOS)


def test_hierarchical_allreduce_modes(subproc):
    assert "HIER_OK" in subproc(CODE_HIER)


def test_compressed_allreduce_error_bounded(subproc):
    assert "COMPRESS_OK" in subproc(CODE_COMPRESS)
