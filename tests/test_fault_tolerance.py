"""Fault tolerance: watchdog, injected failures, bit-identical recovery."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data.pipeline import CorpusLM
from repro.runtime.fault_tolerance import (FailureInjector, InjectedFailure,
                                           StepWatchdog)
from repro.train import Trainer, TrainerOptions


def test_watchdog_flags_stragglers():
    clock = iter([0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 15, 15, 16]).__next__
    wd = StepWatchdog(threshold=3.0, clock=lambda: float(clock()))
    for step in range(7):
        wd.start()
        wd.stop(step)
    assert len(wd.events) == 1
    assert wd.events[0].duration_s == 10.0


def test_injector_fires_once():
    inj = FailureInjector((5,))
    inj.check(4)
    with pytest.raises(InjectedFailure):
        inj.check(5)
    inj.check(5)  # second pass: already fired


def _params_fingerprint(state):
    return np.concatenate([np.asarray(l, np.float32).ravel()[:16]
                           for l in jax.tree.leaves(state["params"])])


def _run(tmp_path, tag, fail_at=()):
    cfg = registry.get("qwen2-1.5b", smoke=True)
    tc = TrainConfig(lr=1e-3, total_steps=12, warmup_steps=2, remat="none")
    src = CorpusLM(cfg.vocab_size, 16, 4)
    tr = Trainer(cfg, tc, src, mesh=None,
                 options=TrainerOptions(ckpt_dir=tmp_path / tag, ckpt_every=4,
                                        log_every=100),
                 injector=FailureInjector(tuple(fail_at)) if fail_at else None)
    return tr.run(12)


def test_restart_after_failure_is_bit_identical(tmp_path):
    """Kill at step 9, auto-restart from the step-8 checkpoint: final params
    must equal the uninterrupted run exactly (deterministic data + carried
    step counter)."""
    clean = _run(tmp_path, "clean")
    crashed = _run(tmp_path, "crashed", fail_at=(9,))
    np.testing.assert_array_equal(_params_fingerprint(clean),
                                  _params_fingerprint(crashed))


def test_too_many_restarts_raises(tmp_path):
    cfg = registry.get("qwen2-1.5b", smoke=True)
    tc = TrainConfig(lr=1e-3, total_steps=10, warmup_steps=2, remat="none")
    src = CorpusLM(cfg.vocab_size, 16, 4)
    inj = FailureInjector((3,))
    inj.fired = set()

    class AlwaysFail(FailureInjector):
        def check(self, step):
            if step == 3:
                raise InjectedFailure("permafail")

    tr = Trainer(cfg, tc, src, mesh=None,
                 options=TrainerOptions(ckpt_dir=tmp_path, ckpt_every=100,
                                        max_restarts=2, log_every=100),
                 injector=AlwaysFail())
    with pytest.raises(InjectedFailure):
        tr.run(10)
