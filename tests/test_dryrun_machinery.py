"""The dry-run pipeline itself, exercised on an 8-device mesh (subprocess):
lower + compile + memory/cost/collective extraction for train, prefill and
decode kinds with a smoke config — guards the central deliverable without
needing the 512-device production mesh."""

import pytest

CODE = """
import dataclasses
import jax
from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import lower_cell, _memory, _costs, _train_config
from repro.launch.mesh import make_host_mesh
from repro.launch import analytic

mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = registry.get("qwen2-1.5b", smoke=True)
shapes = [ShapeConfig("t", 64, 8, "train"), ShapeConfig("p", 64, 8, "prefill"),
          ShapeConfig("d", 64, 8, "decode")]
for shape in shapes:
    tc = _train_config(cfg, {"microbatches": 2})
    lowered, compiled = lower_cell(cfg, shape, mesh, tc)
    mem = _memory(compiled)
    costs = _costs(compiled)
    assert mem["per_device_hbm_bytes"] > 0
    assert costs["flops"] > 0
    assert costs["bytes"] > 0
    # the lowered text must contain real collectives (TP/DP are active)
    assert costs["collective_bytes"] > 0, shape.kind
    print(shape.kind, "ok",
          round(mem["per_device_hbm_bytes"] / 2**20, 1), "MiB",
          costs["collective_counts"])

# depth variants compile too (the extrapolation path)
c0 = analytic.with_depth(cfg, 0)
c1 = analytic.with_depth(cfg, 1)
for c in (c0, c1):
    lower_cell(c, shapes[0], mesh, _train_config(c, {"microbatches": 2}))
print("DRYRUN_MACHINERY_OK")
"""


def test_dryrun_pipeline_on_host_mesh(subproc):
    out = subproc(CODE, timeout=900)
    assert "DRYRUN_MACHINERY_OK" in out
    assert "train ok" in out and "prefill ok" in out and "decode ok" in out
