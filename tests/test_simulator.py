"""Flit-level optical simulator (Fig. 4 reproduction machinery)."""

import pytest

from repro.core import simulator, step_models as sm
from repro.core.wrht import Step


def test_known_algorithms_run_and_validate():
    for alg in ("wrht", "ring", "bt", "hring"):
        r = simulator.run_optical(alg, 64, 1e8)
        assert r.total_s > 0
        assert r.steps > 0


def test_bt_matches_closed_form_steps():
    r = simulator.run_optical("bt", 256, 1e6)
    assert r.steps == sm.bt_steps(256)


def test_ring_matches_closed_form_steps():
    r = simulator.run_optical("ring", 128, 1e6)
    assert r.steps == sm.ring_steps(128)


def test_wrht_reduction_vs_bt():
    """Paper claims −70.1% vs BT on average; with our flit-exact model the
    reduction is even larger — assert the direction and a sane band."""
    p = sm.OpticalParams()
    reductions = []
    for n in (1024, 2048, 4096):
        for d in sm.PAPER_MODELS_BITS.values():
            w = simulator.run_optical("wrht", n, d, p).total_s
            b = simulator.run_optical("bt", n, d, p).total_s
            reductions.append(1 - w / b)
    avg = sum(reductions) / len(reductions)
    assert avg > 0.5


def test_wrht_flat_scaling():
    p = sm.OpticalParams()
    d = 25e6 * 32
    t1 = simulator.run_optical("wrht", 1024, d, p).total_s
    t4 = simulator.run_optical("wrht", 4096, d, p).total_s
    assert t4 <= 2.0 * t1


def test_hring_schedule_steps_match_decomposition():
    n, g = 64, 8
    sched = simulator.hring_allreduce_schedule(n, g, 1e6)
    assert len(sched) == 2 * (g - 1) + 2 * (n // g - 1)


def test_simulator_counts_reconfig_per_step():
    r = simulator.run_optical("bt", 64, 1e3)
    assert r.reconfig_s == pytest.approx(r.steps * 25e-6)


def test_hring_prime_n_falls_back_to_flat_ring():
    """Regression: the g|N search used to reach g=1, where the intra wrap
    link becomes a self-transfer and schedule construction crashed."""
    for n in (7, 13, 127):
        r = simulator.run_optical("hring", n, 1e6)
        assert r.algorithm == "hring"
        assert r.steps == sm.ring_steps(n)  # flat-ring fallback
        assert r.total_s > 0


def test_hring_schedule_rejects_trivial_group_size():
    with pytest.raises(ValueError):
        simulator.hring_allreduce_schedule(8, 1, 1.0)


def test_wrht_cached_schedule_validates_at_large_n():
    """The n<=1024 validation cap is gone: cached schedules are validated
    (structurally and semantically) at every N."""
    r = simulator.run_optical("wrht", 2048, 1e6)
    assert r.steps > 0
    sched = simulator._cached_wrht_schedule(2048, sm.OpticalParams().wavelengths, None)
    # would have raised inside build_schedule(validate=True) otherwise
    assert sched.num_steps == r.steps
