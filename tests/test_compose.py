"""Composed-schedule differential-timing harness (DESIGN.md §13).

Three pillars, mirroring the composer's contract:

1. **Degeneracy** — a depth-1 composition is the uncomposed schedule:
   bit-identical compiled profile (identity-keyed segment dedup preserved),
   bit-identical totals through every engine.
2. **Differential timing** — a composed pipeline never times worse than its
   constituents run serially, on any engine; a λ-infeasible interleaving
   (w=1) serializes completely and then times *exactly* like the serial
   sequence on the barrier engines — the three-engine agreement regression
   for the overlap clamp audit (see the comment blocks in
   ``timing.ScheduleProfile.evaluate`` / ``simulator.simulate_steps_event``).
3. **Fused RWA** — every fused slot's union batch is conflict-free under
   the composed budget and failure mask, and the serialization fallback
   only triggers when the fused assignment genuinely cannot exist.
"""

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import compose, simulator, step_models as sm, timing, wrht
from repro.core.timing import PayloadClass
from repro.core.topology import FailureMask, Ring
from repro.core.wavelength import (
    FailedResourceError,
    WavelengthConflictError,
    first_fit_assign,
    validate_no_conflicts,
)

D = 1e6
MODES = ("lockstep", "event", "overlap")


def _params(w: int) -> sm.OpticalParams:
    return sm.OpticalParams(wavelengths=w)


def _profiles_equal(a, b) -> bool:
    meta_a, arr_a = timing.profile_to_arrays(a)
    meta_b, arr_b = timing.profile_to_arrays(b)
    return meta_a == meta_b and all(
        np.array_equal(arr_a[k], arr_b[k]) for k in arr_a)


def _ring(n: int, w: int, p: sm.OpticalParams) -> Ring:
    return Ring(max(n, 2), w, bandwidth_bps=p.bandwidth_bps,
                reconfig_delay_s=p.reconfig_delay_s, physical=p.physical)


# ---------------------------------------------------------------------------
# degeneracy: depth-1 composition == the plain schedule, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("coll", ("reduce_scatter", "all_gather",
                                  "broadcast"))
def test_depth1_composition_bit_identical(coll):
    n, w = 16, 8
    p = _params(w)
    ring = _ring(n, w, p)
    sched = wrht.build_collective_schedule(coll, n, w, 1.0)
    composed = compose.compose_schedules([sched])
    assert composed.depth == 1 and composed.fused_steps == 0
    assert composed.num_steps == sched.num_steps
    # single-part slots hand back the constituent's original Step objects
    assert all(a is b for a, b in zip(composed.as_steps(), sched.steps))

    classes = (PayloadClass(wrht.COLLECTIVES[coll].payload_divisors(n)),)
    plain = timing.ScheduleProfile.from_steps(sched.steps, ring,
                                              classes=classes,
                                              validate=False)
    comp = timing.ScheduleProfile.from_composed(composed, ring)
    assert _profiles_equal(plain, comp)
    d = np.asarray([1e4, D, 2.56e8])
    for mode in MODES:
        np.testing.assert_array_equal(comp.evaluate(ring, d, mode).total_s,
                                      plain.evaluate(ring, d, mode).total_s)


def test_depth1_collective_times_unchanged():
    """``collective_times(depth=1)`` must take the plain (uncomposed) path
    and agree bit-for-bit with the default call."""
    p = _params(8)
    d = np.asarray([D])
    for mode in MODES:
        a = timing.collective_times("reduce_scatter", 16, d, p, timing=mode)
        b = timing.collective_times("reduce_scatter", 16, d, p, timing=mode,
                                    depth=1)
        np.testing.assert_array_equal(a.total_s, b.total_s)


# ---------------------------------------------------------------------------
# differential timing: composed <= serial sum, on every engine
# ---------------------------------------------------------------------------

def _check_composed_le_serial(start: str, n: int, w: int, depth: int) -> None:
    p = _params(w)
    d = np.asarray([D])
    for mode in MODES:
        composed = float(np.asarray(timing.collective_times(
            start, n, d, p, timing=mode, keep_per_step=False,
            depth=depth).total_s)[0])
        serial = sum(
            float(np.asarray(timing.collective_times(
                c, n, d, p, timing=mode, keep_per_step=False).total_s)[0])
            for c in compose.pipeline_collectives(start, depth))
        assert composed <= serial * (1 + 1e-9) + 1e-12, (
            start, n, w, depth, mode, composed, serial)


@pytest.mark.parametrize("start", ("reduce_scatter", "all_gather",
                                   "broadcast"))
def test_composed_never_worse_than_serial_sweep(start):
    for n in (2, 5, 16):
        for w in (1, 2, 8):
            for depth in (1, 2, 3):
                _check_composed_le_serial(start, n, w, depth)


def test_overlap_gain_rs_ag_depth2():
    """The acceptance cell: RS+AG ring passes ride disjoint wavelengths, so
    the depth-2 composed pipeline must show a *strict, large* win over the
    serial pair — this is the measured end-to-end reduction the
    ``planned_pipelined`` mode trades on (BENCH_pipeline.json)."""
    n, w = 64, 8
    p = _params(w)
    d = np.asarray([D])
    composed_sched = compose.build_pipeline_schedule(
        "reduce_scatter", n, w, D, 2)
    # every slot fused: the RS pass and the AG pass co-exist at 2 λs
    assert composed_sched.fused_steps == composed_sched.num_steps == n - 1
    assert composed_sched.slots_saved == n - 1
    for mode in MODES:
        composed = float(np.asarray(timing.collective_times(
            "reduce_scatter", n, d, p, timing=mode, keep_per_step=False,
            depth=2).total_s)[0])
        serial = sum(
            float(np.asarray(timing.collective_times(
                c, n, d, p, timing=mode, keep_per_step=False).total_s)[0])
            for c in ("reduce_scatter", "all_gather"))
        assert composed <= 0.6 * serial, (mode, composed, serial)


# ---------------------------------------------------------------------------
# serialization fallback: λ-infeasible interleavings wait — and then the
# composed timeline times exactly like the serial sequence (clamp audit)
# ---------------------------------------------------------------------------

def test_infeasible_interleaving_serializes_at_w1():
    n, w = 16, 1
    composed = compose.build_pipeline_schedule("reduce_scatter", n, w, D, 2)
    # nothing fused: both ring passes want the single wavelength
    assert composed.fused_steps == 0
    assert composed.num_steps == composed.serial_steps
    assert composed.slots_saved == 0
    compose.validate_composed(composed)
    # the serialization was forced: the union batch genuinely cannot exist
    rs, ag = composed.schedules
    cat, _ = wrht._concat_batches([rs.steps[0].transfers,
                                   ag.steps[0].transfers])
    with pytest.raises(WavelengthConflictError):
        first_fit_assign(cat, n, w)


def test_serialized_composition_times_like_serial_three_engines():
    """Clamp-audit regression (simulate_steps_event / evaluate comment
    blocks): a fully-serialized composition must cost exactly the sum of
    its constituents on the barrier engines (lockstep, event) — the
    overlap engine may only ever *save* time across the seam."""
    n, w = 16, 1
    p = _params(w)
    d = np.asarray([1e4, D])
    composed = {}
    serial = {}
    for mode in MODES:
        composed[mode] = np.asarray(timing.collective_times(
            "reduce_scatter", n, d, p, timing=mode, keep_per_step=False,
            depth=2).total_s)
        serial[mode] = sum(
            np.asarray(timing.collective_times(
                c, n, d, p, timing=mode, keep_per_step=False).total_s)
            for c in ("reduce_scatter", "all_gather"))
    np.testing.assert_array_equal(composed["lockstep"], serial["lockstep"])
    np.testing.assert_array_equal(composed["event"], serial["event"])
    assert (composed["overlap"] <= serial["overlap"] * (1 + 1e-12)).all()
    # engine ordering holds on the composed path too
    assert (composed["overlap"] <= composed["event"] * (1 + 1e-12)).all()
    assert (composed["event"] <= composed["lockstep"] * (1 + 1e-12)).all()


def test_scalar_and_batched_composed_engines_agree():
    """``simulator.simulate_composed`` (per-point, build-time bits) and
    ``ScheduleProfile.from_composed`` (compiled grid) are the same number
    on every engine — the composed twin of the repo's standing
    scalar-vs-batched differential."""
    n, w = 16, 8
    p = _params(w)
    ring = _ring(n, w, p)
    composed = compose.build_pipeline_schedule("reduce_scatter", n, w, D, 2)
    prof = timing.ScheduleProfile.from_composed(composed, ring, d_ref=D)
    d = np.asarray([D])
    for mode in MODES:
        batched = float(np.asarray(prof.evaluate(ring, d, mode).total_s)[0])
        scalar = simulator.simulate_composed(composed, D, p,
                                             timing=mode).total_s
        assert batched == scalar, (mode, batched, scalar)


# ---------------------------------------------------------------------------
# fused RWA: conflict-freedom, staggered starts, failure masks
# ---------------------------------------------------------------------------

def test_fused_batches_are_conflict_free():
    n, w = 16, 8
    composed = compose.build_pipeline_schedule("reduce_scatter", n, w, D, 3)
    assert composed.fused_steps > 0
    for cs in composed.steps:
        validate_no_conflicts(cs.transfers, n, w,
                              max_hops=composed.max_hops)
        if cs.fused:
            # the union genuinely shares the slot: rows from >= 2 schedules
            assert len({part.constituent for part in cs.parts}) >= 2


def test_staggered_offsets_ramp_up():
    n, w, lag = 8, 8, 3
    rs = wrht.build_collective_schedule("reduce_scatter", n, w, D)
    ag = wrht.build_collective_schedule("all_gather", n, w, D)
    composed = compose.compose_schedules([rs, ag], offsets=(0, lag))
    compose.validate_composed(composed)
    # constituent 1 must not appear in the first `lag` emitted slots
    for cs in composed.steps[:lag]:
        assert {part.constituent for part in cs.parts} == {0}
    assert composed.num_steps < rs.num_steps + ag.num_steps


def test_composition_under_failure_mask():
    mask = FailureMask(dead_segments=((0, 1),))
    n, w = 16, 8
    composed = compose.build_pipeline_schedule("reduce_scatter", n, w, D, 2,
                                               failures=mask)
    assert composed.failures == mask
    compose.validate_composed(composed)
    for cs in composed.steps:
        if cs.fused:
            validate_no_conflicts(cs.transfers, n, w,
                                  max_hops=composed.max_hops, failures=mask)
    # degraded composition still beats (or ties) the degraded serial pair
    p = _params(w)
    d = np.asarray([D])
    for mode in MODES:
        composed_t = float(np.asarray(timing.collective_times(
            "reduce_scatter", n, d, p, timing=mode, keep_per_step=False,
            depth=2, failures=mask).total_s)[0])
        serial_t = sum(
            float(np.asarray(timing.collective_times(
                c, n, d, p, timing=mode, keep_per_step=False,
                failures=mask).total_s)[0])
            for c in ("reduce_scatter", "all_gather"))
        assert composed_t <= serial_t * (1 + 1e-9)


def test_mixed_masks_rejected():
    n, w = 8, 8
    mask = FailureMask(dead_segments=((0, 1),))
    rs = wrht.build_collective_schedule("reduce_scatter", n, w, D,
                                        failures=mask)
    ag = wrht.build_collective_schedule("all_gather", n, w, D)
    with pytest.raises(ValueError, match="failure mask"):
        compose.compose_schedules([rs, ag])


def test_validator_rejects_fused_batch_using_dead_resource():
    """Negative lane: a healthy fused batch checked against a mask that
    kills a resource it uses must trip FailedResourceError — the
    differential guard that validate_composed actually checks the mask."""
    n, w = 16, 8
    composed = compose.build_pipeline_schedule("reduce_scatter", n, w, D, 2)
    fused = next(cs.transfers for cs in composed.steps if cs.fused)
    lane, start, _hops = fused.arcs(n)
    killer = FailureMask(
        dead_segments=((int(lane[0]), int(start[0]) % n),))
    with pytest.raises(FailedResourceError, match="dead fiber span"):
        validate_no_conflicts(fused, n, w, failures=killer)


# ---------------------------------------------------------------------------
# composer API edges
# ---------------------------------------------------------------------------

def test_compose_api_validation():
    with pytest.raises(ValueError, match="at least one"):
        compose.compose_schedules([])
    a = wrht.build_collective_schedule("reduce_scatter", 8, 8, D)
    b = wrht.build_collective_schedule("all_gather", 16, 8, D)
    with pytest.raises(ValueError, match="share one ring"):
        compose.compose_schedules([a, b])
    with pytest.raises(ValueError, match="depth"):
        compose.build_pipeline_schedule("reduce_scatter", 8, 8, D, 0)
    with pytest.raises(ValueError, match="offsets"):
        compose.compose_schedules([a], offsets=(0, 1))


def test_pipeline_collectives_alternation():
    assert compose.pipeline_collectives("reduce_scatter", 4) == (
        "reduce_scatter", "all_gather", "reduce_scatter", "all_gather")
    assert compose.pipeline_collectives("all_gather", 3) == (
        "all_gather", "reduce_scatter", "all_gather")
    # partnerless collectives pipeline against themselves
    assert compose.pipeline_collectives("broadcast", 2) == (
        "broadcast", "broadcast")


# ---------------------------------------------------------------------------
# hypothesis sweep — fast lane + scheduled deep lane
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    import os

    DEEP_EXAMPLES = int(os.environ.get("REPRO_DEEP_EXAMPLES", "300"))

    _strategy = dict(
        start=st.sampled_from(["reduce_scatter", "all_gather", "broadcast"]),
        n=st.integers(min_value=2, max_value=17),
        w=st.sampled_from([1, 2, 4, 8]),
        depth=st.integers(min_value=1, max_value=3),
    )

    @settings(max_examples=15, deadline=None)
    @given(**_strategy)
    def test_composed_le_serial_hypothesis(start, n, w, depth):
        _check_composed_le_serial(start, n, w, depth)

    @pytest.mark.deep
    @settings(max_examples=DEEP_EXAMPLES, deadline=None)
    @given(**_strategy)
    def test_composed_le_serial_hypothesis_deep(start, n, w, depth):
        _check_composed_le_serial(start, n, w, depth)
else:  # pragma: no cover - exercised only without hypothesis installed
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_composed_le_serial_hypothesis():
        pass
