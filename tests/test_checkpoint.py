"""Checkpointer: roundtrip, integrity, retention, async, elastic reshard."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, load_latest


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(7, t, blocking=True)
    out = ck.restore(7, jax.tree.map(lambda x: jnp.zeros_like(x), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree())
    ck.save(2, _tree())
    ck.wait()
    out, step = load_latest(tmp_path, _tree())
    assert step == 2 and out is not None


def test_crc_detects_corruption(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(3, _tree(), blocking=True)
    man = tmp_path / "step_3" / "manifest.json"
    m = json.loads(man.read_text())
    m["leaves"][0]["crc32"] ^= 0xFF
    man.write_text(json.dumps(m))
    with pytest.raises(IOError, match="crc"):
        ck.restore(3, _tree())


def test_retention_keeps_newest(tmp_path):
    ck = Checkpointer(tmp_path, keep_n=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), blocking=True)
    assert ck.steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), blocking=True)
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((5,), jnp.int32)}}
    with pytest.raises(ValueError, match="shape"):
        ck.restore(1, bad)


ELASTIC = """
import numpy as np, tempfile, jax, jax.numpy as jnp
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer

tmp = tempfile.mkdtemp()
mesh8 = jax.make_mesh((8,), ('data',), axis_types=(AxisType.Auto,))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh8, P('data', None)))
ck = Checkpointer(tmp)
ck.save(1, {'x': xs}, blocking=True)

# elastic restore onto a SHRUNKEN 4-way mesh with a different layout
mesh4 = jax.make_mesh((4, 2), ('data', 'model'), axis_types=(AxisType.Auto,)*2)
out = ck.restore(1, {'x': jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                 mesh=mesh4, spec_tree={'x': P('data', 'model')})
assert out['x'].sharding.mesh.shape['data'] == 4
np.testing.assert_array_equal(np.asarray(out['x']), np.asarray(x))
print('ELASTIC_OK')
"""


def test_elastic_reshard_across_meshes(subproc):
    assert "ELASTIC_OK" in subproc(ELASTIC)
