"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------- flash attn

@pytest.mark.parametrize("b,sq,skv,h,k,d", [
    (1, 64, 64, 2, 2, 32),
    (2, 96, 96, 4, 2, 32),     # GQA, non-divisible seq/block
    (1, 128, 128, 4, 1, 64),   # MQA
    (2, 33, 65, 2, 2, 16),     # ragged
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(b, sq, skv, h, k, d, causal):
    if causal and sq != skv:
        pytest.skip("causal requires sq == skv in this sweep")
    q, kk, v = _rand((b, sq, h, d)), _rand((b, skv, k, d)), _rand((b, skv, k, d))
    got = ops.flash_attention(q, kk, v, causal=causal, q_block=32, kv_block=32)
    g = h // k
    qf = q.reshape(b, sq, k, g, d).transpose(0, 2, 3, 1, 4).reshape(b * h, sq, d)
    kf = jnp.broadcast_to(kk.transpose(0, 2, 1, 3)[:, :, None],
                          (b, k, g, skv, d)).reshape(b * h, skv, d)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (b, k, g, skv, d)).reshape(b * h, skv, d)
    want = ref.flash_attention_ref(qf, kf, vf, causal=causal)
    want = want.reshape(b, k, g, sq, d).transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q, k, v = (_rand((1, 64, 2, 32), jnp.bfloat16) for _ in range(3))
    got = ops.flash_attention(q, k, v, q_block=32, kv_block=32)
    qf = q.transpose(0, 2, 1, 3).reshape(2, 64, 32)
    kf = k.transpose(0, 2, 1, 3).reshape(2, 64, 32)
    vf = v.transpose(0, 2, 1, 3).reshape(2, 64, 32)
    want = ref.flash_attention_ref(qf, kf, vf).reshape(1, 2, 64, 32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


def test_flash_matches_model_layer_oracle():
    """kernel == models.layers.blocked_attention (the in-model jnp path)."""
    from repro.models.layers import blocked_attention

    q, k, v = _rand((2, 80, 4, 32)), _rand((2, 80, 2, 32)), _rand((2, 80, 2, 32))
    a = ops.flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    b = blocked_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(7, 64), (3, 37, 128), (1, 1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x, w = _rand(shape, dtype), _rand(shape[-1:], dtype)
    got = ops.rmsnorm(x, w, rows_block=4)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2, atol=2e-2)


# ------------------------------------------------------------------ ssd scan

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 50, 3, 16, 8, 16),    # ragged chunks
    (1, 128, 1, 32, 16, 64),
])
def test_ssd_scan(b, s, h, p, n, chunk):
    x = _rand((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm, cm = _rand((b, s, n)), _rand((b, s, n))
    got = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    af = jnp.broadcast_to(a[None], (b, h)).reshape(-1)
    bf = jnp.broadcast_to(bm[:, None], (b, h, s, n)).reshape(b * h, s, n)
    cf = jnp.broadcast_to(cm[:, None], (b, h, s, n)).reshape(b * h, s, n)
    want = ref.ssd_ref(xf, dtf, af, bf, cf).reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_ssd_matches_model_oracle():
    """kernel == models.ssm.ssd_chunked (the in-model jnp path)."""
    from repro.models.ssm import ssd_chunked

    b, s, h, p, n = 2, 40, 2, 8, 4
    x = _rand((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm, cm = _rand((b, s, n)), _rand((b, s, n))
    got = ops.ssd_scan(x, dt, a, bm, cm, chunk=16)
    want, _ = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- quant

@pytest.mark.parametrize("n,block", [(3000, 256), (1024, 1024), (100, 64)])
def test_quantize_blocks(n, block):
    x = _rand((n,))
    q, s, n_out = ops.quantize_blocks(x, block=block)
    qr, sr, _ = ref.quantize_blocks_ref(x, block=block)
    assert n_out == n
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_quant_roundtrip_error_bound():
    x = _rand((4096,))
    q, s, _ = ops.quantize_blocks(x, block=512)
    acc = jnp.zeros_like(q, jnp.float32)
    deq = ops.dequant_add(q, s, acc, block=512)
    err = np.abs(np.asarray(deq[:4096]) - np.asarray(x)).max()
    bound = float(np.abs(np.asarray(x)).max()) / 127 + 1e-6
    assert err <= bound
