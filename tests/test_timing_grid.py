"""Batched timing engine vs per-point run_optical (DESIGN.md §9).

The contract pinned here is *bit-identity*, not approximation: for every
``algorithm × N × payload × timing`` cell, ``timing.evaluate_grid`` (and the
underlying ``ScheduleProfile`` engines) must reproduce the exact floats of
``simulator.run_optical`` — same division chains, same flit arithmetic, same
accumulation order, per-step lists included.  Also covered: the
simulator-backed auto-tuner's argmin vs brute-force per-candidate
simulation, and profile/cache behaviour.
"""

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import simulator, step_models as sm, timing, wrht
from repro.core.topology import CW, PhysicalParams, Ring, TransferBatch
from repro.core.wavelength import InsertionLossError

ALGOS = ("wrht", "ring", "bt", "hring")
TIMINGS = ("lockstep", "event", "overlap")
PAYLOADS = (1e3, 1e6, 62.3e6 * 32, 987654321.0)

RESULT_FIELDS = ("algorithm", "n", "d_bits", "steps", "serialization_s",
                 "reconfig_s", "total_s", "max_wavelengths", "timing",
                 "event_total_s", "per_step_s")


def assert_bit_identical(legacy: simulator.SimResult,
                         got: simulator.SimResult) -> None:
    for f in RESULT_FIELDS:
        assert getattr(legacy, f) == getattr(got, f), f


# ---------------------------------------------------------------------------
# golden equivalence: every grid cell == the per-point path, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg", ALGOS)
@pytest.mark.parametrize("tmode", TIMINGS)
def test_grid_matches_run_optical(alg, tmode):
    p = sm.OpticalParams(wavelengths=8)
    # 13: prime N (hring flat-ring fallback); 100: non-power-of-two groups
    for n in (13, 16, 64, 100):
        times = timing.algorithm_times(alg, n, PAYLOADS, p, tmode)
        for i, d in enumerate(PAYLOADS):
            legacy = simulator.run_optical(alg, n, d, p, timing=tmode)
            assert_bit_identical(legacy, times.sim_result(i))


@pytest.mark.parametrize("tmode", TIMINGS)
def test_grid_matches_run_optical_with_physical(tmode):
    phys = sm.OpticalParams(wavelengths=16,
                            physical=PhysicalParams(insertion_loss_db_per_hop=1.0))
    for alg in ("wrht", "ring", "hring"):
        for n in (64, 256):
            times = timing.algorithm_times(alg, n, PAYLOADS, phys, tmode)
            for i, d in enumerate(PAYLOADS):
                legacy = simulator.run_optical(alg, n, d, phys, timing=tmode)
                assert_bit_identical(legacy, times.sim_result(i))


def test_evaluate_grid_front_end_and_sim_result():
    p = sm.OpticalParams(wavelengths=8)
    grid = timing.evaluate_grid(ALGOS, (16, 64), PAYLOADS, TIMINGS, p)
    assert grid.total_s.shape == (4, 2, 3, len(PAYLOADS))
    assert grid.feasible.all()
    for alg in ALGOS:
        for n in (16, 64):
            for tmode in TIMINGS:
                for d in PAYLOADS:
                    legacy = simulator.run_optical(alg, n, d, p, timing=tmode)
                    assert_bit_identical(
                        legacy, grid.sim_result(alg, n, d, tmode))


def test_grid_marks_infeasible_cells_instead_of_raising():
    tight = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=4.0))
    with pytest.raises(InsertionLossError):
        simulator.run_optical("bt", 256, 1e6, tight)
    grid = timing.evaluate_grid(("bt", "wrht"), (256,), (1e6,),
                                ("lockstep",), tight)
    assert not grid.feasible[0, 0]          # binary tree out of optical reach
    assert grid.feasible[1, 0]              # WRHT caps its fan-out and fits
    assert ("bt", 256) in grid.errors
    assert np.isnan(grid.total("bt", 256, "lockstep")).all()
    with pytest.raises(InsertionLossError):
        grid.sim_result("bt", 256, 1e6, "lockstep")


def test_hring_span_infeasibility_agrees_across_paths():
    """The shared span check gates both paths: a hop budget below the
    inter-group span makes H-Ring infeasible in run_optical (raises) and in
    the grid (feasible=False, same message), for every timing mode."""
    tight = sm.OpticalParams(
        physical=PhysicalParams(insertion_loss_db_per_hop=8.0))  # H=4 < g=8
    for tmode in TIMINGS:
        with pytest.raises(InsertionLossError, match="H-Ring lightpath"):
            simulator.run_optical("hring", 64, 1e6, tight, timing=tmode)
    grid = timing.evaluate_grid(("hring",), (64,), (1e6,), TIMINGS, tight)
    assert not grid.feasible[0, 0]
    assert "H-Ring lightpath" in grid.errors[("hring", 64)]


def test_grid_sim_result_rejects_unknown_payload():
    grid = timing.evaluate_grid(("ring",), (16,), (1e6,), ("lockstep",))
    with pytest.raises(KeyError, match="not on this grid"):
        grid.sim_result("ring", 16, 2e6, "lockstep")


def test_profile_dedupes_shared_batches():
    """H-Ring repeats its intra/inter template batches across steps: the
    profile stores (and validates) each unique segment once."""
    p = sm.OpticalParams(wavelengths=8)
    prof = timing._hring_profile(64, 8, p)
    assert prof.num_steps == 2 * (8 - 1) + 2 * (64 // 8 - 1)
    assert prof.num_segments == 2
    assert prof.num_transfers == 64 + 64 // 8


def test_profile_caches_hit_across_payloads_and_timings():
    timing.clear_caches()
    from repro.core import plan_cache

    p = sm.OpticalParams(wavelengths=8)
    timing.evaluate_grid(("wrht",), (64,), (1e6,), TIMINGS, p)
    timing.evaluate_grid(("wrht",), (64,), (1e7, 1e8), TIMINGS, p)
    stats = plan_cache.get_default().stats
    assert stats.misses == 1         # compiled once
    assert stats.memory_hits >= 5    # reused for every other (timing, call)


def test_payload_class_division_chain_exact():
    """(d / g) / n_groups can differ from d / (g·n_groups) in the last ulp —
    the chain representation must replay the builder's exact divisions."""
    d, g, ng = 738350593.8536226, 6, 14
    assert timing.PayloadClass((g, ng)).bits(np.asarray([d]))[0] == (d / g) / ng
    # and the collapsed fraction genuinely differs for this payload
    assert (d / g) / ng != d / (g * ng)


def test_keep_per_step_false_totals_unchanged():
    p = sm.OpticalParams(wavelengths=8)
    full = timing.algorithm_times("hring", 64, PAYLOADS, p, "overlap")
    slim = timing.algorithm_times("hring", 64, PAYLOADS, p, "overlap",
                                  keep_per_step=False)
    assert slim.per_step_s is None
    np.testing.assert_array_equal(full.total_s, slim.total_s)
    np.testing.assert_array_equal(full.serialization_s, slim.serialization_s)


# ---------------------------------------------------------------------------
# generic profiles: payload classes + empty steps
# ---------------------------------------------------------------------------

def test_profile_classifies_heterogeneous_payload_classes():
    ring = Ring(8, 4)
    d = 1e6
    step = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [0, 2], [1, 3], CW, [d, d / 1000], wavelength=[0, 0]))
    prof = timing.ScheduleProfile.from_steps(
        [step], ring,
        classes=(timing.PayloadClass(()), timing.PayloadClass((1000,))),
        d_ref=d)
    legacy = simulator.simulate_steps("x", [step], ring, d)
    got = prof.evaluate(ring, [d], "lockstep").sim_result(0)
    assert got.total_s == legacy.total_s
    assert got.per_step_s == legacy.per_step_s


def test_profile_rejects_unmatched_bits():
    ring = Ring(8, 4)
    step = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [0], [1], CW, [3.0], wavelength=[0]))
    with pytest.raises(ValueError, match="payload class"):
        timing.ScheduleProfile.from_steps(
            [step], ring,
            classes=(timing.PayloadClass(()), timing.PayloadClass((2,))),
            d_ref=1.0)


def test_profile_empty_steps_match_legacy_engines():
    ring = Ring(8, 4)
    real = wrht.Step("reduce", 0, TransferBatch.from_arrays(
        [0, 2], [1, 3], CW, 1.0, wavelength=[0, 0]))
    empty = wrht.Step("reduce", 0, TransferBatch.empty())
    steps = [empty, real, empty, real, empty]
    prof = timing.ScheduleProfile.from_steps(steps, ring)
    for tmode in TIMINGS:
        if tmode == "lockstep":
            legacy = simulator.simulate_steps("x", steps, ring, 1.0,
                                              bits_override=1.0)
        else:
            legacy = simulator.simulate_steps_event(
                "x", steps, ring, 1.0, overlap=tmode == "overlap",
                bits_override=1.0)
        got = prof.evaluate(ring, [1.0], tmode).sim_result(0)
        assert got.total_s == legacy.total_s
        assert got.per_step_s == legacy.per_step_s


# ---------------------------------------------------------------------------
# scheduled collective algebra: cross-engine goldens (DESIGN.md §11)
# ---------------------------------------------------------------------------

COLLECTIVES = ("allreduce", "reduce_scatter", "all_gather", "broadcast",
               "alltoall")


@pytest.mark.parametrize("coll", COLLECTIVES)
@pytest.mark.parametrize("tmode", TIMINGS)
def test_collective_grid_matches_run_collective(coll, tmode):
    """Every collective × engine × payload cell: the batched ScheduleProfile
    grid path reproduces the per-point simulator bit for bit (the all-reduce
    contract of test_grid_matches_run_optical, extended to the algebra)."""
    p = sm.OpticalParams(wavelengths=64)
    # the single-step all-to-all needs ⌈n²/8⌉ <= 64 -> n <= 22
    ns = (2, 8, 16) if coll == "alltoall" else (2, 13, 16, 64)
    for n in ns:
        times = timing.collective_times(coll, n, PAYLOADS, p, tmode)
        for i, d in enumerate(PAYLOADS):
            legacy = simulator.run_collective(coll, n, d, p, timing=tmode)
            assert_bit_identical(legacy, times.sim_result(i))


@pytest.mark.parametrize("tmode", TIMINGS)
def test_collective_grid_matches_with_physical(tmode):
    phys = sm.OpticalParams(wavelengths=64,
                            physical=PhysicalParams(insertion_loss_db_per_hop=2.0))
    for coll in COLLECTIVES:
        n = 16 if coll == "alltoall" else 64
        times = timing.collective_times(coll, n, PAYLOADS, phys, tmode)
        for i, d in enumerate(PAYLOADS):
            legacy = simulator.run_collective(coll, n, d, phys, timing=tmode)
            assert_bit_identical(legacy, times.sim_result(i))


def test_collective_times_allreduce_equals_run_optical():
    """collective_times("allreduce") and the historical wrht path are the
    same numbers — one profile serves both entry points."""
    p = sm.OpticalParams(wavelengths=8)
    for tmode in TIMINGS:
        a = timing.collective_times("allreduce", 64, PAYLOADS, p, tmode)
        for i, d in enumerate(PAYLOADS):
            legacy = simulator.run_optical("wrht", 64, d, p, timing=tmode)
            got = a.sim_result(i)
            for f in RESULT_FIELDS:
                if f == "algorithm":
                    continue  # labelled by collective name, not "wrht"
                assert getattr(legacy, f) == getattr(got, f), f


def test_allreduce_numbers_pinned_vs_pr4():
    """Regression pin: the all-reduce totals must come out of this PR
    unchanged (values recorded from the PR-4 tree on this exact config)."""
    d = 25e6 * 32
    for n, w in ((64, 8), (1024, 64)):
        p = sm.OpticalParams(wavelengths=w)
        for tmode in ("lockstep", "overlap"):
            r = simulator.run_optical("wrht", n, d, p, timing=tmode)
            assert r.total_s == 0.060075019199999996, (n, w, tmode)
            assert r.steps == 3 and r.max_wavelengths == w
            bt = timing.collective_times("allreduce", n, [d], p, tmode)
            assert float(bt.total_s[0]) == 0.060075019199999996


def test_collective_payload_accounting_in_profile():
    """The ring passes and the all-to-all time d/n per transfer — the
    profile's payload class must shrink with n while the trees stay full-d
    (spot check of the spec's payload-per-step accounting)."""
    p = sm.OpticalParams(wavelengths=64)
    d = 1e9
    rs = timing.collective_times("reduce_scatter", 16, [d], p)
    ar = timing.collective_times("allreduce", 16, [d], p)
    ring = timing._ring_of(16, p)
    # one RS step serializes d/16; its 15 steps are cheaper than one
    # full-vector tree step
    per_rs_step = ring.serialization_time(d / 16)
    assert abs(float(rs.serialization_s[0]) - 15 * per_rs_step) < 1e-12
    assert float(rs.serialization_s[0]) < float(ar.serialization_s[0])


def test_collective_times_infeasible_raises_like_builder():
    p = sm.OpticalParams(wavelengths=8)
    from repro.core.wavelength import WavelengthConflictError
    with pytest.raises(WavelengthConflictError):
        timing.collective_times("alltoall", 64, [1e6], p)
    tight = sm.OpticalParams(
        wavelengths=64,
        physical=PhysicalParams(insertion_loss_db_per_hop=8.0))
    with pytest.raises(InsertionLossError):
        timing.collective_times("alltoall", 16, [1e6], tight)


# ---------------------------------------------------------------------------
# auto-tuner: simulated argmin == brute force
# ---------------------------------------------------------------------------

def _brute_force_best(n, w, d, tmode, max_hops=None):
    ring = Ring(n, w)
    best = None
    for m in range(2, wrht.feasible_group_size(w, max_hops) + 1):
        sched_a2a = wrht.build_schedule(n, w, 1.0, m=m, allow_alltoall=True,
                                        max_hops=max_hops)
        took = any(s.kind == "alltoall" for s in sched_a2a.steps)
        for a2a in (True, False):
            if not a2a and not took:
                continue  # identical schedule either way
            sched = wrht.build_schedule(n, w, 1.0, m=m, allow_alltoall=a2a,
                                        max_hops=max_hops)
            if tmode == "lockstep":
                r = simulator.simulate_steps("x", sched.steps, ring, d,
                                             validate=False, bits_override=d)
            else:
                r = simulator.simulate_steps_event(
                    "x", sched.steps, ring, d, overlap=tmode == "overlap",
                    validate=False, bits_override=d)
            if best is None or r.total_s < best[0]:
                best = (r.total_s, m, a2a)
    return best


@pytest.mark.parametrize("tmode", ("lockstep", "overlap"))
def test_tune_wrht_matches_brute_force(tmode):
    n, w = 64, 4
    ds = (1e3, 1e7, 1e9)
    tr = timing.tune_wrht(n, w, ds, timing=tmode)
    for i, d in enumerate(ds):
        total, m, a2a = _brute_force_best(n, w, d, tmode)
        assert tr.best(i) == (m, a2a)
        assert tr.best_total_s[i] == total


def test_tune_wrht_respects_hop_budget():
    tr = timing.tune_wrht(64, 8, 1e7, max_hops=4)
    assert tr.analytic_m == wrht.feasible_group_size(8, 4) == 9
    assert all(m <= 9 for m, _ in tr.candidates)
    total, m, a2a = _brute_force_best(64, 8, 1e7, "lockstep", max_hops=4)
    assert tr.best(0) == (m, a2a)
    assert tr.best_total_s[0] == total


def test_tune_wrht_never_worse_than_analytic_choice():
    for n, w in ((64, 4), (256, 8)):
        tr = timing.tune_wrht(n, w, 1e8)
        analytic_rows = [i for i, (m, _) in enumerate(tr.candidates)
                         if m == tr.analytic_m]
        assert tr.best_total_s[0] <= tr.total_s[analytic_rows[0], 0]


def test_tune_wrht_caps_candidates_at_n():
    """Regression: every m >= n yields the identical single-group schedule —
    the sweep must not build hundreds of duplicates on small rings."""
    tr = timing.tune_wrht(8, 64, 1e6)
    assert all(m <= 8 for m, _ in tr.candidates)
    assert len(tr.candidates) <= 2 * 7        # m in 2..8, ≤2 a2a rows each
    # and the capped argmin still matches the uncapped brute force (ties
    # break toward smaller m, so m > n candidates can never win)
    total, m, a2a = _brute_force_best(8, 64, 1e6, "lockstep")
    if m > 8:   # brute force may name a duplicate row; totals still agree
        assert tr.best_total_s[0] == total
    else:
        assert tr.best(0) == (m, a2a)
        assert tr.best_total_s[0] == total


def test_tune_broadcast_matches_direct_builds():
    """The broadcast fan-out sweep (DESIGN.md §11): argmin over the batched
    candidates == brute-force per-m builds through the per-point engine."""
    n, w = 64, 8
    ds = (1e3, 1e9)
    tr = timing.tune_wrht(n, w, ds, collective="broadcast")
    assert all(not a2a for _, a2a in tr.candidates)
    ring = Ring(n, w)
    for i, d in enumerate(ds):
        best = None
        for m in range(2, wrht.feasible_group_size(w) + 1):
            sched = wrht.build_collective_schedule("broadcast", n, w, 1.0,
                                                   m=m)
            r = simulator.simulate_steps("x", sched.steps, ring, d,
                                         validate=False, bits_override=d)
            if best is None or r.total_s < best[0]:
                best = (r.total_s, m)
        assert tr.best(i) == (best[1], False)
        assert tr.best_total_s[i] == best[0]
    with pytest.raises(ValueError, match="no fan-out axis"):
        timing.tune_wrht(n, w, 1e6, collective="reduce_scatter")


def test_run_optical_m_auto_uses_tuned_schedule():
    p = sm.OpticalParams(wavelengths=4)
    auto = simulator.run_optical("wrht", 64, 1e7, p, m="auto")
    default = simulator.run_optical("wrht", 64, 1e7, p)
    assert auto.total_s <= default.total_s
    # the reported result is the tuned schedule, re-simulated point-wise
    tr = timing.tune_wrht(64, 4, 1e7)
    assert auto.total_s == tr.best_total_s[0]


# ---------------------------------------------------------------------------
# hypothesis sweep (skipped gracefully when hypothesis is missing)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=96),
        w=st.sampled_from([2, 4, 8]),
        d=st.floats(min_value=1.0, max_value=1e11, allow_nan=False),
        alg=st.sampled_from(ALGOS),
        tmode=st.sampled_from(TIMINGS),
    )
    def test_grid_matches_run_optical_hypothesis(n, w, d, alg, tmode):
        p = sm.OpticalParams(wavelengths=w)
        times = timing.algorithm_times(alg, n, [d], p, tmode)
        assert_bit_identical(simulator.run_optical(alg, n, d, p, timing=tmode),
                             times.sim_result(0))
else:  # pragma: no cover - exercised only without hypothesis installed
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_grid_matches_run_optical_hypothesis():
        pass
