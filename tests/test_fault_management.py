"""Closed-loop fault management (DESIGN.md §14): flapping-fault model,
telemetry probe, hysteresis HealthMonitor, FaultManager replan loop, and the
severed-ring certificate the analytic planner raises on.

The headline property (ISSUE 8's acceptance criterion): under an injected
flapping-λ trace the hysteresis ``ReplanPolicy`` performs provably fewer
replans than one-per-transition, and recovery replans are memo/plan-cache
hits.
"""

from __future__ import annotations

import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import plan_cache, planner, simulator, wrht
from repro.core.plan_cache import PlanCache
from repro.core.topology import (FailureMask, FaultTimeline, FlapSchedule,
                                 ResourceObservation)
from repro.runtime.fault_tolerance import (FaultManager, HealthMonitor,
                                           ReplanPolicy)

# ---------------------------------------------------------------------------
# flapping-fault model
# ---------------------------------------------------------------------------


def test_flap_schedule_permanent_and_periodic():
    perm = FlapSchedule.permanent("wavelength", (0, 3), at=10)
    assert not perm.is_down(9)
    assert perm.is_down(10) and perm.is_down(10**9)
    assert perm.transitions(0, 100) == 1

    flap = FlapSchedule.periodic("segment", (0, 5), up_steps=2, down_steps=3,
                                 phase=1)
    # phase 1: steps 1,2 up; 3,4,5 down; 6,7 up; ...
    assert [flap.is_down(s) for s in range(1, 8)] == [
        False, False, True, True, True, False, False]
    # one down edge + one up edge per 5-step period
    assert flap.transitions(0, 50) == 20


def test_flap_schedule_validation():
    with pytest.raises(ValueError, match="kind"):
        FlapSchedule.permanent("fiber", (0, 0))
    with pytest.raises(ValueError, match="empty down interval"):
        FlapSchedule("wavelength", (0, 0), down_intervals=((5, 5),))
    with pytest.raises(ValueError, match="both up_steps and"):
        FlapSchedule("wavelength", (0, 0), up_steps=3)
    with pytest.raises(ValueError, match="never down"):
        FlapSchedule("wavelength", (0, 0))


def test_fault_timeline_mask_and_transitions():
    tl = FaultTimeline((
        FlapSchedule.permanent("wavelength", (0, 3), at=5),
        FlapSchedule.periodic("segment", (0, 2), up_steps=4, down_steps=4),
    ))
    assert tl.mask_at(0) == FailureMask(dead_segments=())  # seg up at phase 0
    assert tl.mask_at(6) == FailureMask(dead_wavelengths=((0, 3),),
                                        dead_segments=((0, 2),))
    assert tl.transitions(0, 16) == 1 + 4  # one permanent edge + 4 flaps
    with pytest.raises(ValueError, match="duplicate"):
        FaultTimeline((FlapSchedule.permanent("wavelength", (0, 3)),
                       FlapSchedule.permanent("wavelength", (0, 3), at=9)))
    with pytest.raises(TypeError, match="FlapSchedule"):
        FaultTimeline((FailureMask(),))


# ---------------------------------------------------------------------------
# simulator telemetry probe
# ---------------------------------------------------------------------------


def test_observe_faults_tracks_timeline():
    tl = FaultTimeline((FlapSchedule.permanent("wavelength", (2, 1), at=4),
                        FlapSchedule.periodic("segment", (1, 0), 2, 2)))
    obs = simulator.observe_faults(tl, 1)
    assert {(o.kind, o.ident, o.ok) for o in obs} == {
        ("wavelength", (2, 1), True), ("segment", (1, 0), True)}
    obs = simulator.observe_faults(tl, 5)   # λ down; seg down ((5-0)%4=1>=2? no
    by_key = {(o.kind, o.ident): o.ok for o in obs}
    assert by_key[("wavelength", (2, 1))] is False
    assert all(o.step == 5 for o in obs)


def test_observe_faults_traffic_restriction():
    tl = FaultTimeline((FlapSchedule.permanent("segment", (0, 0)),
                        FlapSchedule.permanent("wavelength", (7, 0))))
    n = 8
    steps = wrht.build_collective_schedule("reduce_scatter", n, 8, 1e6).steps
    obs = simulator.observe_faults(tl, 0, steps=steps, n=n)
    kinds = {(o.kind, o.ident) for o in obs}
    # the ring pass crosses every CW segment and adds/drops at every node,
    # so both resources are exercised and observed
    assert ("segment", (0, 0)) in kinds
    with pytest.raises(ValueError, match="n"):
        simulator.observe_faults(tl, 0, steps=steps)


# ---------------------------------------------------------------------------
# hysteresis state machine
# ---------------------------------------------------------------------------


def _obs(step, ok, kind="wavelength", ident=(0, 3)):
    return ResourceObservation(step=step, kind=kind, ident=ident, ok=ok)


def test_monitor_confirm_before_demote():
    mon = HealthMonitor(ReplanPolicy(confirm_k=3))
    mon.observe(_obs(0, False))
    mon.observe(_obs(1, False))
    assert mon.mask.empty and mon.state("wavelength", (0, 3)) == "suspect"
    mon.observe(_obs(2, True))     # transient glitch absorbed
    assert mon.state("wavelength", (0, 3)) == "up"
    for s in range(3, 6):
        mon.observe(_obs(s, False))
    assert mon.mask == FailureMask(dead_wavelengths=((0, 3),))
    assert mon.demotions == 1


def test_monitor_cooldown_before_readmit():
    mon = HealthMonitor(ReplanPolicy(confirm_k=1, recover_k=2,
                                     cooldown_steps=10))
    mon.observe(_obs(0, False))            # demoted at step 0
    assert not mon.mask.empty
    mon.observe(_obs(1, True))
    mon.observe(_obs(2, True))             # recover_k met but cooldown not
    assert not mon.mask.empty
    mon.observe(_obs(5, False))            # flap during recovery: back down
    mon.observe(_obs(11, True))
    mon.observe(_obs(12, True))            # cooldown (since step 0) elapsed
    assert mon.mask.empty
    assert mon.readmissions == 1


def test_replan_policy_validation():
    with pytest.raises(ValueError, match="confirm_k"):
        ReplanPolicy(confirm_k=0)
    with pytest.raises(ValueError, match="cooldown"):
        ReplanPolicy(cooldown_steps=-1)
    with pytest.raises(ValueError, match="on_infeasible"):
        ReplanPolicy(on_infeasible="panic")


# ---------------------------------------------------------------------------
# FaultManager: the closed loop
# ---------------------------------------------------------------------------


def _manager_for(timeline, policy, sink=None):
    mgr = FaultManager(lambda s: simulator.observe_faults(timeline, s),
                       policy)
    mgr.attach(sink if sink is not None else (lambda mask: None))
    return mgr


def test_fast_flap_provably_fewer_replans_than_transitions():
    """The acceptance criterion: a λ flapping faster than the confirm
    window causes ZERO replans, vs one per transition for a naive policy."""
    tl = FaultTimeline((FlapSchedule.periodic("wavelength", (0, 3), 2, 2),))
    mgr = _manager_for(tl, ReplanPolicy(confirm_k=3))
    for s in range(80):
        mgr.on_step(s)
    naive = tl.transitions(0, 79)
    assert naive >= 20
    assert mgr.replan_count < naive        # provably fewer ...
    assert mgr.replan_count == 0           # ... in fact none at all


def test_slow_flap_coalesced_by_cooldown():
    """A slow flapper clears the confirm window, but cooldown holds the
    resource out across heal/fail cycles: strictly fewer replans than the
    naive one-per-transition count, never more."""
    tl = FaultTimeline((FlapSchedule.periodic("wavelength", (0, 3), 30, 30),))
    mgr = _manager_for(tl, ReplanPolicy(confirm_k=3, recover_k=3,
                                        cooldown_steps=60))
    for s in range(200):
        mgr.on_step(s)
    naive = tl.transitions(0, 199)
    assert 0 < mgr.replan_count < naive


def test_permanent_fault_full_roundtrip():
    """Degrade exactly once at confirmation, heal exactly once after
    recovery: masks arrive at the replan sink in order."""
    tl = FaultTimeline((FlapSchedule("wavelength", (0, 3),
                                     down_intervals=((5, 20),)),))
    seen = []
    mgr = _manager_for(tl, ReplanPolicy(), sink=seen.append)
    for s in range(40):
        mgr.on_step(s)
    assert mgr.replan_count == 2
    assert seen[0] == FailureMask(dead_wavelengths=((0, 3),))
    assert seen[1].empty
    assert mgr.current_mask is None        # healed == healthy
    assert [h["applied"] for h in mgr.history] == [True, True]


def test_rate_limit_defers_then_applies():
    tl = FaultTimeline((FlapSchedule.permanent("wavelength", (0, 3), at=0),
                        FlapSchedule.permanent("segment", (0, 1), at=4)))
    seen = []
    mgr = _manager_for(tl, ReplanPolicy(confirm_k=1, min_replan_interval=10),
                       sink=seen.append)
    for s in range(20):
        mgr.on_step(s)
    # λ confirmed at step 0, segment at step 4 — the second proposal is
    # deferred until the rate limit clears at step 10, then applied once
    assert mgr.replan_count == 2
    assert mgr.history[1]["step"] == 10
    assert seen[1] == FailureMask(dead_wavelengths=((0, 3),),
                                  dead_segments=((0, 1),))


def test_infeasible_keep_vs_raise():
    tl = FaultTimeline((FlapSchedule.permanent("wavelength", (0, 3)),))

    def refusing_sink(mask):
        raise wrht.DegradedInfeasibleError("storm took the last lambda")

    mgr = _manager_for(tl, ReplanPolicy(confirm_k=1), sink=refusing_sink)
    mgr.on_step(0)                         # swallowed, loop keeps running
    assert mgr.infeasible_count == 1 and mgr.replan_count == 0
    assert mgr.current_mask is None
    assert mgr.history[0]["applied"] is False

    mgr2 = _manager_for(tl, ReplanPolicy(confirm_k=1, on_infeasible="raise"),
                        sink=refusing_sink)
    with pytest.raises(wrht.DegradedInfeasibleError):
        mgr2.on_step(0)


def test_on_step_before_attach_raises():
    tl = FaultTimeline((FlapSchedule.permanent("wavelength", (0, 3)),))
    mgr = FaultManager(lambda s: simulator.observe_faults(tl, s),
                       ReplanPolicy(confirm_k=1))
    with pytest.raises(RuntimeError, match="attach"):
        mgr.on_step(0)


# ---------------------------------------------------------------------------
# mask algebra + the severed-ring certificate
# ---------------------------------------------------------------------------


def test_mask_union_and_covers():
    a = FailureMask(dead_segments=((0, 1),))
    b = FailureMask(dead_wavelengths=((2, 0),), dead_segments=((0, 1),))
    u = a.union(b)
    assert u == b.union(a)                 # canonical, order-free
    assert u.covers(a) and u.covers(b) and not a.covers(b)
    assert FailureMask().union(a) == a


def test_disconnects_certificate():
    n = 8
    # single-lane cuts: the other fiber still reaches everyone
    assert not FailureMask(dead_segments=((0, 0), (0, 4))).disconnects(n)
    # both lanes of ONE span: a line topology, still connected
    assert not FailureMask(dead_segments=((0, 4), (1, 4))).disconnects(n)
    # both lanes of TWO spans: severed
    assert FailureMask(
        dead_segments=((0, 0), (1, 0), (0, 4), (1, 4))).disconnects(n)
    # an entire dead CW fiber is fine while the CCW ring is intact
    assert not FailureMask(
        dead_segments=tuple((0, s) for s in range(n))).disconnects(n)
    # a node with both transceivers dead can never receive
    assert FailureMask(
        dead_transceivers=((3, 0), (3, 1))).disconnects(n)
    assert not FailureMask(dead_transceivers=((3, 0),)).disconnects(n)
    # λ failures alone never sever (pass-through needs no add/drop)
    assert not FailureMask(
        dead_wavelengths=tuple((0, l) for l in range(64))).disconnects(n)


def test_analytic_planner_raises_on_severed_ring():
    """The analytic backend used to cost a fabric no schedule can use; the
    certificate makes both backends agree at the cliff (DESIGN.md §14)."""
    severed = FailureMask(dead_segments=((0, 0), (1, 0), (0, 2), (1, 2)))
    for collective in ("allreduce", "reduce_scatter"):
        with pytest.raises(wrht.DegradedInfeasibleError, match="severs"):
            planner.plan_buckets(8, [1 << 20], backend="analytic",
                                 collective=collective, failures=severed)


def test_recovery_replan_hits_plan_cache():
    """Shrinking the mask back to a previously-seen state is pure cache
    traffic on the simulated backend: zero misses, zero new compiles."""
    plan_cache.set_default(PlanCache())
    try:
        sizes = [1 << 18, 1 << 22]
        mask = FailureMask(dead_segments=((0, 1),),
                           dead_wavelengths=((2, 0),))
        cache = plan_cache.get_default()
        healthy = planner.plan_buckets(8, sizes, backend="simulated",
                                       collective="reduce_scatter")
        cold = cache.stats.snapshot()
        assert cold.misses >= 1               # the healthy plan was compiled
        planner.plan_buckets(8, sizes, backend="simulated",
                             collective="reduce_scatter", failures=mask)
        before = cache.stats.snapshot()
        restored = planner.plan_buckets(8, sizes, backend="simulated",
                                        collective="reduce_scatter")
        d = cache.stats.delta(before)
        # every cacheable candidate is a memory hit; nothing is re-compiled
        # or re-written (misses may re-probe candidates that raised as
        # infeasible during the cold pass — those are never cached)
        assert d.hits >= 1 and d.misses < cold.misses, vars(d)
        assert d.disk_writes == 0 and d.evictions == 0, vars(d)
        assert [p.strategy for p in restored] == [p.strategy for p in healthy]
    finally:
        plan_cache.set_default(None)


# ---------------------------------------------------------------------------
# hypothesis sweep — fast lane + scheduled deep lane
# ---------------------------------------------------------------------------


def _check_bounded_replans(up, down, phase, confirm_k, cooldown, steps):
    flap = FlapSchedule.periodic("wavelength", (0, 3), up, down, phase=phase)
    tl = FaultTimeline((flap,))
    mgr = _manager_for(tl, ReplanPolicy(confirm_k=confirm_k,
                                        recover_k=confirm_k,
                                        cooldown_steps=cooldown))
    for s in range(steps):
        mgr.on_step(s)
    naive = tl.transitions(0, steps - 1)
    # the hysteresis NEVER replans more than one-per-transition, and the
    # final mask is consistent with the monitor state
    assert mgr.replan_count <= max(naive, 1)
    if down < confirm_k:
        assert mgr.replan_count == 0       # too fast to ever confirm
    last = mgr.current_mask
    assert last is None or last == FailureMask(dead_wavelengths=((0, 3),))


def _check_storm_masks_nested_monotone(n, stages):
    """Every stage of a random nested mask ladder covers the last, and the
    severed certificate is monotone along it (once disconnected, always
    disconnected)."""
    import random as _random
    rng = _random.Random(stages * 1000 + n)
    mask = FailureMask()
    was_disconnected = False
    for _ in range(stages):
        kind = rng.choice(["segment", "wavelength", "transceiver"])
        if kind == "segment":
            extra = FailureMask(dead_segments=(
                (rng.randrange(2), rng.randrange(n)),))
        elif kind == "wavelength":
            extra = FailureMask(dead_wavelengths=(
                (rng.randrange(n), rng.randrange(8)),))
        else:
            extra = FailureMask(dead_transceivers=(
                (rng.randrange(n), rng.randrange(2)),))
        bigger = mask.union(extra)
        assert bigger.covers(mask)
        disconnected = bigger.disconnects(n)
        assert disconnected or not was_disconnected, (
            "severed ring healed by adding failures")
        was_disconnected = disconnected
        mask = bigger


if HAVE_HYPOTHESIS:
    import os

    DEEP_EXAMPLES = int(os.environ.get("REPRO_DEEP_EXAMPLES", "300"))

    _flap_strategy = dict(
        up=st.integers(min_value=1, max_value=6),
        down=st.integers(min_value=1, max_value=6),
        phase=st.integers(min_value=0, max_value=5),
        confirm_k=st.integers(min_value=1, max_value=4),
        cooldown=st.integers(min_value=0, max_value=12),
        steps=st.integers(min_value=10, max_value=120),
    )
    _storm_strategy = dict(
        n=st.integers(min_value=4, max_value=16),
        stages=st.integers(min_value=1, max_value=12),
    )

    @settings(max_examples=20, deadline=None)
    @given(**_flap_strategy)
    def test_flap_bounded_replans_hypothesis(up, down, phase, confirm_k,
                                             cooldown, steps):
        _check_bounded_replans(up, down, phase, confirm_k, cooldown, steps)

    @pytest.mark.deep
    @settings(max_examples=DEEP_EXAMPLES, deadline=None)
    @given(**_flap_strategy)
    def test_flap_bounded_replans_hypothesis_deep(up, down, phase, confirm_k,
                                                  cooldown, steps):
        _check_bounded_replans(up, down, phase, confirm_k, cooldown, steps)

    @settings(max_examples=20, deadline=None)
    @given(**_storm_strategy)
    def test_storm_masks_nested_hypothesis(n, stages):
        _check_storm_masks_nested_monotone(n, stages)

    @pytest.mark.deep
    @settings(max_examples=DEEP_EXAMPLES, deadline=None)
    @given(**_storm_strategy)
    def test_storm_masks_nested_hypothesis_deep(n, stages):
        _check_storm_masks_nested_monotone(n, stages)
else:  # pragma: no cover - exercised only without hypothesis installed
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_flap_bounded_replans_hypothesis():
        pass
