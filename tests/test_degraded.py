"""Fault-tolerant re-planning (DESIGN.md §12): FailureMask identity, degraded
builders/validators, plan-cache isolation, degraded planning across both
backends, the online SyncController plan swap, the trainer's degradation /
straggler hooks, and the device-level no-retrace E2E.

The conformance oracles for degraded schedules live in
tests/test_collective_conformance.py (the failure-mask lane); this file
covers everything around them."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.core import planner, simulator, timing, wrht
from repro.core.plan_cache import PlanCache, PlanKey
from repro.core.topology import FailureMask
from repro.data.pipeline import CorpusLM
from repro.runtime.fault_tolerance import (FailureInjector, StepWatchdog,
                                           StragglerEvent)
from repro.train import Trainer, TrainerOptions
from repro.train import train_step as TS

# ≥1 dead arc + ≥1 dead λ: the ISSUE's acceptance mask shape
MASK = FailureMask(dead_segments=((0, 1),), dead_wavelengths=((2, 0),))
# both fibers cut at two distinct spans: the ring is severed
SEVERED = FailureMask(dead_segments=((0, 0), (1, 0), (0, 2), (1, 2)))


# ---------------------------------------------------------------------------
# the mask itself
# ---------------------------------------------------------------------------

def test_mask_canonical_hashable_fingerprint():
    a = FailureMask(dead_segments=((0, 3), (0, 1), (0, 3)),
                    dead_wavelengths=((5, 2), (1, 0)))
    b = FailureMask(dead_segments=((0, 1), (0, 3)),
                    dead_wavelengths=((1, 0), (5, 2)))
    assert a == b and hash(a) == hash(b)
    assert a.fingerprint() == b.fingerprint() != "ok"
    assert FailureMask().empty and FailureMask().fingerprint() == "ok"
    assert FailureMask.from_lists(a.to_lists()) == a
    with pytest.raises(ValueError, match="lane"):
        FailureMask(dead_segments=((2, 0),))


def test_effective_wavelengths_and_group_size_shrink():
    two_dead = FailureMask(dead_wavelengths=((0, 0), (0, 1), (3, 2)))
    assert wrht.effective_wavelengths(8) == 8
    assert wrht.effective_wavelengths(8, two_dead) == 6
    assert wrht.effective_wavelengths(1, two_dead) == 1  # floored
    assert (wrht.feasible_group_size(8, failures=two_dead)
            <= wrht.feasible_group_size(8))


# ---------------------------------------------------------------------------
# degraded building: line topology routable, severed ring is not
# ---------------------------------------------------------------------------

def test_line_topology_builds_every_collective():
    line = FailureMask(dead_segments=((0, 2), (1, 2)))
    for coll in wrht.COLLECTIVES:
        try:
            sched = wrht.build_collective_schedule(coll, 8, 8, 1e6,
                                                   failures=line)
        except wrht.DegradedInfeasibleError:
            # flip-only collectives (the one-step all-to-all) may hit the
            # hop budget going the long way; trees must route
            assert coll == "alltoall"
            continue
        assert sched.failures == line


def test_severed_ring_is_infeasible():
    for coll in wrht.COLLECTIVES:
        with pytest.raises(wrht.DegradedInfeasibleError):
            wrht.build_collective_schedule(coll, 8, 8, 1e6, failures=SEVERED)


# ---------------------------------------------------------------------------
# plan cache: healthy and degraded plans never mix
# ---------------------------------------------------------------------------

def test_plan_cache_isolation(tmp_path):
    cache = PlanCache(disk_dir=tmp_path)
    k_ok = PlanKey(8, 8)
    k_bad = PlanKey(8, 8, failures=MASK)
    assert k_ok != k_bad
    assert k_ok.filename() != k_bad.filename()
    assert "-Fok-" in k_ok.filename()
    assert f"-F{MASK.fingerprint()}-" in k_bad.filename()

    s_ok, s_bad = cache.schedule(k_ok), cache.schedule(k_bad)
    assert s_ok.failures is None
    assert s_bad.failures == MASK
    # distinct entries: a second lookup of each hits its own plan
    assert cache.schedule(k_ok) is s_ok
    assert cache.schedule(k_bad) is s_bad

    # disk tier round-trips per-fingerprint artifacts independently
    cache.profile(k_bad)
    fresh = PlanCache(disk_dir=tmp_path)
    assert fresh.peek_profile(k_ok) is None          # never served the mask's
    assert fresh.peek_profile(k_bad) is not None
    assert (tmp_path / k_bad.filename()).exists()

    # the empty mask IS the healthy key (one entry, one artifact)
    assert PlanKey(8, 8, failures=FailureMask()) == k_ok
    assert PlanKey(8, 8, failures=FailureMask()).filename() == k_ok.filename()


# ---------------------------------------------------------------------------
# timing / simulator / planner under a mask
# ---------------------------------------------------------------------------

def test_degraded_times_never_beat_healthy():
    # every degraded schedule is also a valid healthy schedule, so the tuned
    # healthy optimum is a lower bound on the degraded one
    d = np.array([1e6, 1e8])
    healthy = timing.collective_times("allreduce", 16, d)
    degraded = timing.collective_times("allreduce", 16, d, failures=MASK)
    assert (np.asarray(degraded.total_s) >= np.asarray(healthy.total_s)
            - 1e-12).all()

    t_ok = simulator.run_collective("allreduce", 16, 1e8)
    t_bad = simulator.run_collective("allreduce", 16, 1e8, failures=MASK)
    assert t_bad.total_s >= t_ok.total_s - 1e-12


def test_fixed_schedule_baselines_reject_masks():
    with pytest.raises(ValueError, match="fixed schedule"):
        simulator.run_optical("ring", 16, 1e6, failures=MASK)


def test_planner_degraded_both_backends():
    sizes = [1 << 16, 1 << 22]
    for backend in ("analytic", "simulated"):
        plans = planner.plan_buckets(8, sizes, backend=backend,
                                     collective="reduce_scatter",
                                     failures=MASK)
        assert len(plans) == 2
        assert all(p.strategy in ("flat", "alltoall") for p in plans)
    # the simulated backend is exact: a severed ring has no feasible plan
    with pytest.raises(wrht.DegradedInfeasibleError):
        planner.plan_buckets(8, sizes, backend="simulated", failures=SEVERED)


# ---------------------------------------------------------------------------
# injector + straggler policy
# ---------------------------------------------------------------------------

def test_injector_degradation_one_shot_and_reset():
    inj = FailureInjector((5,), degrade_at={3: MASK})
    assert inj.degradation(2) is None
    assert inj.degradation(3) is MASK
    assert inj.degradation(3) is None          # one-shot
    with pytest.raises(Exception):
        inj.check(5)
    inj.check(5)                               # already fired
    inj.reset()
    assert inj.degradation(3) is MASK          # re-armed
    with pytest.raises(Exception):
        inj.check(5)


def test_injector_rejects_non_mask_at_construction():
    """The degrade_at satellite: a wrong value type fails at construction
    with a pointed error, not steps later inside Trainer.replan."""
    with pytest.raises(TypeError, match=r"degrade_at\[3\].*FailureMask"):
        FailureInjector(degrade_at={3: {"dead_segments": [(0, 1)]}})
    with pytest.raises(TypeError, match="got NoneType"):
        FailureInjector(degrade_at={0: None})
    FailureInjector(degrade_at={3: MASK})   # the real thing still works


def test_watchdog_deque_window_and_warmup():
    """The O(window) list.pop(0) is gone: the history is a bounded deque,
    and the warmup (previously hard-coded at 4) is a constructor arg."""
    ticks = iter(float(i) for i in range(10**6)).__next__

    wd = StepWatchdog(threshold=3.0, window=4, warmup=1,
                      clock=lambda: ticks())
    assert wd._times.maxlen == 4
    # warmup=1: the second step can already be flagged
    wd.start(); wd.stop(0)                        # dt = 1.0 (recorded)
    wd.start()
    for _ in range(8):                            # burn 8 ticks -> dt = 9.0
        ticks()
    wd.stop(1)
    assert [e.step for e in wd.events] == [1]
    # the window really bounds the median history
    for s in range(2, 12):
        wd.start(); wd.stop(s)
    assert len(wd._times) == 4

    # default warmup matches the historical 4-sample behaviour
    assert StepWatchdog().warmup == 4
    with pytest.raises(ValueError, match="warmup"):
        StepWatchdog(warmup=0)


def test_sync_controller_cumulative_and_recovery_memo():
    """Cumulative degradation (mask union) then recovery: fresh masks
    re-plan, previously-seen masks — including the healthy one — are memo
    hits (``last_replan_cached``), so the heal leg costs ~nothing."""
    tc = TrainConfig(sync_algorithm="planned_sharded", bucket_bytes=1 << 10)
    ctrl = TS.SyncController(_abstract_grads(), tc, _StubMesh())
    healthy = ctrl.arrays()

    ctrl.replan(MASK)
    assert not ctrl.last_replan_cached          # fresh degraded plan
    bigger = MASK.union(FailureMask(dead_wavelengths=((2, 1),)))
    assert bigger.covers(MASK)
    ctrl.replan(bigger)
    assert not ctrl.last_replan_cached          # union is a new mask
    ctrl.replan(MASK)                           # storm recedes partially
    assert ctrl.last_replan_cached
    restored = ctrl.replan(FailureMask())       # full recovery
    assert ctrl.last_replan_cached and ctrl.failures is None
    for k in healthy:
        np.testing.assert_array_equal(np.asarray(restored[k]),
                                      np.asarray(healthy[k]))
    assert ctrl.replan_count == 4


def _smoke_trainer(tmp_path, **opt_kwargs):
    cfg = registry.get("qwen2-1.5b", smoke=True)
    tc = TrainConfig(lr=1e-3, total_steps=12, warmup_steps=2, remat="none")
    src = CorpusLM(cfg.vocab_size, 16, 4)
    return Trainer(cfg, tc, src, mesh=None,
                   options=TrainerOptions(ckpt_dir=tmp_path, log_every=100,
                                          **opt_kwargs))


def test_straggler_checkpoint_policy(tmp_path):
    """A flagged straggler under policy="checkpoint" forces an early save:
    step 8 takes 20 fake seconds vs a 1 s median, so a checkpoint must land
    at step 9 even though ckpt_every would first fire at step 12."""
    tr = _smoke_trainer(tmp_path, ckpt_every=100,
                        straggler_policy="checkpoint")
    ticks = []
    t = 0.0
    for s in range(12):
        dt = 20.0 if s == 8 else 1.0
        ticks += [t, t + dt]
        t += dt
    fake = iter(ticks).__next__
    tr.watchdog = StepWatchdog(tr.options.watchdog_threshold,
                               on_straggler=tr._on_straggler,
                               clock=lambda: float(fake()))
    tr.run(12)
    assert len(tr.watchdog.events) == 1 and tr.watchdog.events[0].step == 8
    assert 9 in tr.ckpt.steps(), tr.ckpt.steps()
    assert not tr._ckpt_requested


def test_straggler_policy_callable_and_validation(tmp_path):
    seen = []
    tr = _smoke_trainer(tmp_path / "cb", straggler_policy=seen.append)
    ev = StragglerEvent(step=7, duration_s=9.0, median_s=1.0)
    tr._on_straggler(ev)
    assert seen == [ev] and not tr._ckpt_requested
    with pytest.raises(ValueError, match="straggler_policy"):
        _smoke_trainer(tmp_path / "bad", straggler_policy="reboot")


def test_replan_requires_controller(tmp_path):
    tr = _smoke_trainer(tmp_path)       # auto mode: no controller
    assert tr.controller is None
    with pytest.raises(RuntimeError, match="planned_sharded"):
        tr.replan(MASK)


# ---------------------------------------------------------------------------
# SyncController: the online plan swap (unit level)
# ---------------------------------------------------------------------------

class _StubMesh:
    """Just enough mesh for the planner: named axes + sizes."""
    axis_names = ("data", "pod")
    shape = {"data": 4, "pod": 2}


def _abstract_grads():
    return {k: jax.ShapeDtypeStruct((n,), jnp.float32)
            for k, n in (("a", 37), ("b", 129), ("c", 513))}


def test_sync_controller_replan_swaps_codes():
    tc = TrainConfig(sync_algorithm="planned_sharded", bucket_bytes=1 << 10)
    ctrl = TS.SyncController(_abstract_grads(), tc, _StubMesh())
    healthy = ctrl.arrays()
    assert set(healthy) == {"rs:data", "rs:pod", "ag:data", "ag:pod"}
    assert all(v.dtype == jnp.int32 for v in healthy.values())

    degraded = ctrl.replan(MASK)
    assert ctrl.replan_count == 1 and ctrl.failures == MASK
    assert ctrl.last_replan_s is not None and ctrl.last_replan_s >= 0
    # shape/dtype invariance is the no-retrace contract
    for k in healthy:
        assert degraded[k].shape == healthy[k].shape
        assert degraded[k].dtype == healthy[k].dtype

    # an empty mask restores the healthy plan exactly
    restored = ctrl.replan(FailureMask())
    assert ctrl.failures is None
    for k in healthy:
        np.testing.assert_array_equal(np.asarray(restored[k]),
                                      np.asarray(healthy[k]))


def test_sync_controller_infeasible_keeps_previous_plan():
    tc = TrainConfig(sync_algorithm="planned_sharded", bucket_bytes=1 << 10)
    ctrl = TS.SyncController(_abstract_grads(), tc, _StubMesh(),
                             backend="simulated")
    before = ctrl.plans
    with pytest.raises(wrht.DegradedInfeasibleError):
        ctrl.replan(SEVERED)
    assert ctrl.plans is before and ctrl.failures is None
    assert ctrl.replan_count == 0


# ---------------------------------------------------------------------------
# device-level E2E: mid-run plan swap with NO retrace (8 simulated devices)
# ---------------------------------------------------------------------------
# Uses the same shard_map compat shim as the conformance twins, so this runs
# on jax builds that predate jax.shard_map too.  The jitted body counts its
# own traces; swapping healthy -> degraded codes must not add one.

NO_RETRACE = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.configs.base import TrainConfig
from repro.core.topology import FailureMask
from repro.train import train_step as TS

try:
    _sm = jax.shard_map
    def smap(body, mesh, in_specs, out_specs):
        return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   axis_names={'data', 'pod'})
except AttributeError:
    from jax.experimental.shard_map import shard_map as _sm
    def smap(body, mesh, in_specs, out_specs):
        return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ('data', 'pod'))
tc = TrainConfig(sync_algorithm="planned_sharded", bucket_bytes=1 << 10)
rng = np.random.default_rng(0)
tree = {k: rng.normal(size=(8, n)).astype(np.float32)
        for k, n in (('a', 37), ('b', 129), ('c', 513))}

ctrl = TS.SyncController(
    jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], jnp.float32),
                 tree),
    tc, mesh)

TRACES = 0
def body(stacked, codes):
    global TRACES
    TRACES += 1
    local = jax.tree.map(lambda x: x[0], stacked)
    out, _ = TS.sync_gradients(local, tc, mesh, sync_plans=ctrl.plans,
                               plan_codes=codes)
    return jax.tree.map(lambda x: x[None], out)

spec = P(('data', 'pod'))
healthy = ctrl.arrays()
in_specs = (jax.tree.map(lambda _: spec, tree),
            jax.tree.map(lambda _: P(), healthy))
step = jax.jit(smap(body, mesh, in_specs, jax.tree.map(lambda _: spec, tree)))

got0 = step(tree, healthy)
mask = FailureMask(dead_segments=((0, 1),), dead_wavelengths=((2, 0),))
degraded = ctrl.replan(mask)
got1 = step(tree, degraded)          # swapped plan, same compiled step
assert TRACES == 1, TRACES           # <- the no-retrace acceptance criterion
assert ctrl.last_replan_s is not None

# cumulative degradation: the storm worsens (mask union), then recedes back
# to healthy — the heal leg is a plan-memo hit and STILL no retrace
worse = mask.union(FailureMask(dead_wavelengths=((2, 1),)))
assert worse.covers(mask)
got2 = step(tree, ctrl.replan(worse))
assert not ctrl.last_replan_cached   # fresh degraded plan
healed = ctrl.replan(None)
assert ctrl.last_replan_cached       # recovery = zero planner work
got3 = step(tree, healed)
assert TRACES == 1, TRACES           # one compile across the whole storm
for k in healthy:
    np.testing.assert_array_equal(np.asarray(healed[k]),
                                  np.asarray(healthy[k]))
for k, v in tree.items():
    want = np.asarray(v).mean(axis=0)
    for got in (got0, got1, got2, got3):
        assert np.abs(np.asarray(got[k]) - want[None]).max() < 1e-5, k
print('NO_RETRACE_OK', ctrl.replan_count, '%.3fms' % (1e3 * ctrl.last_replan_s))
"""


def test_midrun_plan_swap_no_retrace(subproc):
    assert "NO_RETRACE_OK" in subproc(NO_RETRACE)


# trainer-level E2E on a typed mesh: the injector reports a mask mid-run and
# the trainer re-plans through the controller with no retrace of the jitted
# step.  Needs jax.shard_map + AxisType (conftest skips on older jax).
TRAINER_REPLAN = """
import jax, numpy as np
from jax.sharding import AxisType
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.core.topology import FailureMask
from repro.data.pipeline import SyntheticLM
from repro.runtime.fault_tolerance import FailureInjector
from repro.train import Trainer, TrainerOptions
from repro.parallel import context as pctx

cfg = registry.get("qwen2-1.5b", smoke=True)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,)*3)
mask = FailureMask(dead_segments=((0, 1),), dead_wavelengths=((1, 0),))
with jax.set_mesh(mesh):
    pctx.set_mesh(mesh)
    tc = TrainConfig(lr=1e-3, total_steps=6, warmup_steps=2, remat="none",
                     sync_algorithm="planned_sharded", bucket_bytes=1 << 20)
    src = SyntheticLM(cfg.vocab_size, 16, 8)
    tr = Trainer(cfg, tc, src, mesh=mesh,
                 options=TrainerOptions(ckpt_dir="ckpt_replan", ckpt_every=100,
                                        log_every=100),
                 injector=FailureInjector(degrade_at={3: mask}))
    assert tr.controller is not None
    state = tr.run(6)
assert tr.controller.replan_count == 1
assert tr.controller.failures == mask
sizes = getattr(tr._step_fn, "_cache_size", None)
if sizes is not None:
    assert tr._step_fn._cache_size() == 1, tr._step_fn._cache_size()
loss = float(tr.history[-1]["loss"]) if tr.history else 0.0
assert np.isfinite(np.asarray(jax.tree.leaves(state["params"])[0])).all()
print("TRAINER_REPLAN_OK", tr.controller.replan_count)
"""


def test_trainer_replans_midrun_multidevice(subproc):
    assert "TRAINER_REPLAN_OK" in subproc(TRAINER_REPLAN, timeout=900)


# trainer-level E2E of the CLOSED loop (DESIGN.md §14): no injected mask —
# the FaultManager observes a transient fault through the simulator probe,
# confirms it, replans, then heals back to the healthy plan via a memo hit.
TRAINER_FAULT_LOOP = """
import jax, numpy as np
from jax.sharding import AxisType
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.core.simulator import observe_faults
from repro.core.topology import FaultTimeline, FlapSchedule
from repro.data.pipeline import SyntheticLM
from repro.runtime.fault_tolerance import FaultManager, ReplanPolicy
from repro.train import Trainer, TrainerOptions
from repro.parallel import context as pctx

cfg = registry.get("qwen2-1.5b", smoke=True)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,)*3)
# λ 0 at node 2 dies during steps [2, 5), then heals
timeline = FaultTimeline((FlapSchedule("wavelength", (2, 0),
                                       down_intervals=((2, 5),)),))
mgr = FaultManager(lambda s: observe_faults(timeline, s),
                   ReplanPolicy(confirm_k=2, recover_k=2, cooldown_steps=2))
with jax.set_mesh(mesh):
    pctx.set_mesh(mesh)
    tc = TrainConfig(lr=1e-3, total_steps=10, warmup_steps=2, remat="none",
                     sync_algorithm="planned_sharded", bucket_bytes=1 << 20)
    src = SyntheticLM(cfg.vocab_size, 16, 8)
    tr = Trainer(cfg, tc, src, mesh=mesh,
                 options=TrainerOptions(ckpt_dir="ckpt_loop", ckpt_every=100,
                                        log_every=100),
                 fault_manager=mgr)
    assert tr.controller is not None
    state = tr.run(10)
# degrade once (confirmed at step 3), heal once (readmitted after cooldown)
assert mgr.replan_count == 2, mgr.history
assert mgr.current_mask is None           # fully healed
assert tr.controller.failures is None
assert tr.controller.last_replan_cached   # the heal leg was a memo hit
assert [h["applied"] for h in mgr.history] == [True, True]
assert np.isfinite(np.asarray(jax.tree.leaves(state["params"])[0])).all()
print("FAULT_LOOP_OK", mgr.replan_count)
"""


def test_trainer_closed_fault_loop_multidevice(subproc):
    assert "FAULT_LOOP_OK" in subproc(TRAINER_FAULT_LOOP, timeout=900)
