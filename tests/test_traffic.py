"""Multi-tenant traffic simulator (DESIGN.md §16): determinism, offered-load
monotonicity, policy conformance, join/leave plan-memo recovery, and the
serving-engine traffic source."""

import numpy as np
import pytest

from repro.core import compose, simulator, step_models as sm, traffic, wrht

MB = 2**20 * 8.0
N = 16
W = 16


def _p(**kw) -> sm.OpticalParams:
    return sm.OpticalParams(wavelengths=W, **kw)


def _tenants():
    return [
        traffic.TenantSpec("train-a", rate_hz=120.0, d_bits=4 * MB),
        traffic.TenantSpec("train-b", rate_hz=120.0, d_bits=1 * MB),
        traffic.TenantSpec("serve", rate_hz=240.0, d_bits=0.25 * MB,
                           collective="all_gather"),
    ]


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------

def test_poisson_source_deterministic():
    a = traffic.PoissonSource(_tenants(), seed=7).jobs(0.25)
    b = traffic.PoissonSource(_tenants(), seed=7).jobs(0.25)
    assert a == b
    c = traffic.PoissonSource(_tenants(), seed=8).jobs(0.25)
    assert a != c


def test_poisson_source_respects_registration_window():
    spec = traffic.TenantSpec("t", rate_hz=500.0, join_s=0.1, leave_s=0.2)
    jobs = traffic.PoissonSource([spec], seed=0).jobs(1.0)
    assert jobs
    assert all(0.1 <= j.arrival_s < 0.2 for j in jobs)


def test_trace_source_sorts_and_clips():
    jobs = [traffic.CollectiveJob("t", 0.5), traffic.CollectiveJob("t", 0.1)]
    out = traffic.TraceSource(jobs).jobs(0.3)
    assert [j.arrival_s for j in out] == [0.1]


def test_scale_jobs_compresses_arrivals():
    jobs = [traffic.CollectiveJob("t", 1.0), traffic.CollectiveJob("t", 2.0)]
    scaled = traffic.scale_jobs(jobs, 4.0)
    assert [j.arrival_s for j in scaled] == [0.25, 0.5]
    with pytest.raises(ValueError):
        traffic.scale_jobs(jobs, 0.0)


def test_job_validation():
    with pytest.raises(ValueError):
        traffic.CollectiveJob("t", -1.0)
    with pytest.raises(ValueError):
        traffic.CollectiveJob("t", 0.0, d_bits=0.0)
    with pytest.raises(ValueError):
        traffic.PoissonSource([traffic.TenantSpec("x"),
                               traffic.TenantSpec("x")])


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

def test_run_deterministic_under_fixed_seed():
    tenants = _tenants()
    runs = []
    for _ in range(2):
        src = traffic.PoissonSource(tenants, seed=3)
        sim = traffic.RingTrafficSim(N, _p(), policy="shared")
        res = sim.run(src, horizon_s=0.2)
        runs.append([(r.job, r.start_s, r.finish_s) for r in res.jobs])
    assert runs[0] == runs[1]


@pytest.mark.parametrize("policy", traffic.POLICIES)
def test_p99_monotone_in_offered_load(policy):
    tenants = _tenants()
    base = traffic.PoissonSource(tenants, seed=11).jobs(0.5)
    p99s = []
    for load in (0.25, 1.0, 4.0):
        sim = traffic.RingTrafficSim(N, _p(), policy=policy)
        res = sim.run(traffic.scale_jobs(base, load), tenants=tenants)
        p99s.append(res.percentile(99))
    assert p99s == sorted(p99s), p99s


@pytest.mark.parametrize("policy", traffic.POLICIES)
def test_policy_conformance(policy):
    """Every admitted group's composed schedule validates, and every
    constituent — after cross-tenant fusion — still passes its own
    per-collective semantic oracle."""
    tenants = _tenants()
    src = traffic.PoissonSource(tenants, seed=5)
    sim = traffic.RingTrafficSim(N, _p(), policy=policy,
                                 keep_schedules=True)
    res = sim.run(src, horizon_s=0.1, tenants=tenants)
    fused = [g for g in res.groups if len(g.jobs) > 1]
    assert fused, "expected at least one fused cross-tenant group"
    for g in res.groups:
        compose.validate_composed(g.composed)
        for j in range(g.composed.depth):
            wrht.validate_schedule(g.composed.constituent_view(j))


def test_partitioned_fused_slots_use_disjoint_wavelength_slices():
    tenants = _tenants()
    src = traffic.PoissonSource(tenants, seed=5)
    sim = traffic.RingTrafficSim(N, _p(), policy="partitioned",
                                 keep_schedules=True)
    res = sim.run(src, horizon_s=0.1, tenants=tenants)
    checked = 0
    for g in res.groups:
        if len(g.jobs) < 2:
            continue
        for cs in g.composed.steps:
            if not cs.fused:
                continue
            ranges = []
            for part in cs.parts:
                lam = cs.transfers.wavelength[part.lo:part.hi]
                ranges.append((int(lam.min()), int(lam.max())))
                checked += 1
            ranges.sort()
            for (_, hi), (lo, _) in zip(ranges, ranges[1:]):
                assert hi < lo, ranges
    assert checked > 0


def test_partitioned_too_many_tenants_raises():
    jobs = [traffic.CollectiveJob(f"t{i}", 0.0, d_bits=MB)
            for i in range(W + 1)]
    sim = traffic.RingTrafficSim(N, _p(), policy="partitioned",
                                 max_concurrent=1)
    with pytest.raises(ValueError, match="cannot split"):
        sim.run(jobs)


def test_same_tenant_jobs_serialize():
    """At most one in-flight job per tenant: a tenant's collectives are
    ordered, so three same-time submissions become three groups."""
    jobs = [traffic.CollectiveJob("t", 0.0, d_bits=MB) for _ in range(3)]
    sim = traffic.RingTrafficSim(N, _p(), policy="shared")
    res = sim.run(jobs)
    assert len(res.groups) == 3
    finishes = sorted(r.finish_s for r in res.jobs)
    assert finishes[0] < finishes[1] < finishes[2]


def test_admission_control_rejects_beyond_queue_cap():
    jobs = [traffic.CollectiveJob("t", 0.0, d_bits=16 * MB),
            *[traffic.CollectiveJob(f"u{i}", 0.0, d_bits=16 * MB)
              for i in range(6)]]
    sim = traffic.RingTrafficSim(N, _p(), policy="shared",
                                 max_concurrent=1, max_queue=2)
    res = sim.run(jobs)
    # 2 fit the backlog cap at t=0; the other 5 simultaneous arrivals bounce
    assert len(res.rejected) == 5
    assert len(res.jobs) == 2


def test_zero_contention_matches_simulate_composed_bit_for_bit():
    """The acceptance anchor: a single tenant's lone job times exactly as
    simulate_composed on the same (depth-1-composed) schedule."""
    d = 4 * MB
    p = _p()
    sched = wrht.build_collective_schedule("allreduce", N, W, d,
                                           validate=False)
    direct = simulator.simulate_composed(
        compose.compose_schedules([sched]), d, p).total_s
    for policy in traffic.POLICIES:
        sim = traffic.RingTrafficSim(N, p, policy=policy)
        res = sim.run([traffic.CollectiveJob("solo", 0.0, "allreduce", d)])
        assert res.jobs[0].latency_s == direct


def test_tenant_leave_replans_through_plan_memo():
    """B leaving re-partitions the pool (A re-plans at full width); B's
    late job restores the original partition — a pure memo hit, the
    SyncController recovery contract (DESIGN.md §14)."""
    tenants = [traffic.TenantSpec("a", rate_hz=0.0, d_bits=MB),
               traffic.TenantSpec("b", rate_hz=0.0, d_bits=MB,
                                  leave_s=0.5)]
    jobs = [
        traffic.CollectiveJob("a", 0.00, d_bits=MB),   # R={a,b}: plan a@half
        traffic.CollectiveJob("b", 0.00, d_bits=MB),   #          plan b@half
        traffic.CollectiveJob("a", 0.30, d_bits=MB),   # memo hit
        traffic.CollectiveJob("a", 0.60, d_bits=MB),   # R={a}: plan a@full
        traffic.CollectiveJob("a", 0.70, d_bits=MB),   # memo hit
    ]
    sim = traffic.RingTrafficSim(N, _p(), policy="partitioned")
    res = sim.run(jobs, tenants=tenants)
    assert res.repartitions >= 1
    assert sim.replans == 3          # a@half, b@half, a@full — nothing else
    hits_before = sim.replan_memo_hits
    assert hits_before >= 2
    # b's straggler job restores the {a, b} partition: zero new plans
    late = sim.run([traffic.CollectiveJob("b", 1.0, d_bits=MB)],
                   tenants=tenants)
    assert late.replans == 0
    assert late.replan_memo_hits >= 1
    assert sim.last_replan_cached


def test_counters_are_per_run_deltas():
    sim = traffic.RingTrafficSim(N, _p(), policy="shared")
    jobs = [traffic.CollectiveJob("t", 0.0, d_bits=MB)]
    first = sim.run(jobs)
    assert (first.replans, first.replan_memo_hits) == (1, 0)
    second = sim.run(jobs)
    assert second.replans == 0
    assert second.replan_memo_hits >= 1


def test_shared_fusion_saves_slots_vs_serial():
    """Cross-tenant fusion must actually remove reconfiguration slots at
    contention (the composer's reason to exist)."""
    tenants = _tenants()
    src = traffic.PoissonSource(tenants, seed=5)
    sim = traffic.RingTrafficSim(N, _p(), policy="shared")
    res = sim.run(src, horizon_s=0.1, tenants=tenants)
    assert res.summary()["slots_saved"] > 0


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown policy"):
        traffic.RingTrafficSim(N, _p(), policy="best-effort")


# ---------------------------------------------------------------------------
# serving traffic source (shape-only; the live-engine path rides
# tests/test_serve.py where a real model is already spun up)
# ---------------------------------------------------------------------------

class _Cfg:
    n_layers = 4
    n_kv_heads = 2
    d_model = 64
    resolved_head_dim = 8


def _round(admitted, prefill_len, decode_steps):
    from repro.serve.engine import RoundStats
    return RoundStats(admitted=admitted, batch=admitted,
                      prefill_len=prefill_len, decode_steps=decode_steps)


def test_serving_source_sizes_jobs_from_kv_and_activation_shapes():
    cfg = _Cfg()
    log = [_round(2, 8, 4), _round(1, 3, 0)]
    src = traffic.ServingTrafficSource(cfg, log, round_period_s=0.01,
                                       compute_bits=16)
    jobs = src.jobs(1.0)
    # round 0: prefill KV + decode activations; round 1: prefill only
    assert len(jobs) == 3
    kv = traffic.kv_bits_per_token(cfg, 16)      # 2*4*2*8*16 = 2048
    act = traffic.activation_bits_per_token(cfg, 16)   # 64*16 = 1024
    assert jobs[0].d_bits == 2 * 8 * kv
    assert jobs[1].d_bits == 2 * 4 * act
    assert jobs[2].d_bits == 1 * 3 * kv
    assert jobs[2].arrival_s == pytest.approx(0.01)
    assert all(j.collective == "all_gather" for j in jobs)


def test_serving_source_competes_with_training():
    cfg = _Cfg()
    serve_src = traffic.ServingTrafficSource(
        cfg, [_round(4, 32, 16)] * 20, round_period_s=5e-4,
        compute_bits=16)
    train = [traffic.CollectiveJob("train", 1e-4 * k, "allreduce", 2 * MB)
             for k in range(10)]
    jobs = sorted(serve_src.jobs(1.0) + train,
                  key=lambda j: (j.arrival_s, j.tenant))
    sim = traffic.RingTrafficSim(N, _p(), policy="shared",
                                 keep_schedules=True)
    res = sim.run(jobs)
    assert set(res.tenants) == {"serve", "train"}
    mixed = [g for g in res.groups
             if len({j.tenant for j in g.jobs}) > 1]
    assert mixed, "expected inference and training fused in one group"
    for g in mixed:
        compose.validate_composed(g.composed)
