"""Hypothesis property tests on system invariants."""

import math

import numpy as np
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, blocked_attention, masked_xent


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(2, 40), h=st.integers(1, 3),
       kv=st.integers(1, 2), d=st.sampled_from([8, 16]),
       qb=st.sampled_from([4, 8, 16]), kvb=st.sampled_from([4, 8, 16]))
def test_blocked_attention_matches_naive(b, s, h, kv, d, qb, kvb):
    """Online-softmax blocking is exact w.r.t. naive masked attention, for
    every (block size × GQA ratio × ragged seq) combination."""
    if h % kv:
        h = kv * h
    rng = np.random.default_rng(b * 1000 + s)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    got = blocked_attention(q, k, v, causal=True, q_block=qb, kv_block=kvb)

    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qh = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, -1)
    want = jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(b, s, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 30), d=st.sampled_from([8, 16, 32]))
def test_rope_preserves_norm(s, d):
    rng = np.random.default_rng(s)
    x = jnp.asarray(rng.normal(size=(1, s, 2, d)), jnp.float32)
    pos = jnp.arange(s)[None]
    y = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_position_invariance():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)

    def dot(i, j):
        qi = apply_rope(q, jnp.asarray([[i]]), 1e4)
        kj = apply_rope(k, jnp.asarray([[j]]), 1e4)
        return float(jnp.sum(qi * kj))

    assert dot(3, 1) == np.float32(dot(10, 8)) or abs(dot(3, 1) - dot(10, 8)) < 1e-4


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 10), v=st.sampled_from([7, 16]))
def test_masked_xent_matches_naive(b, s, v):
    rng = np.random.default_rng(b * 100 + s)
    logits = jnp.asarray(rng.normal(size=(b, s, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    labels = labels.at[0, 0].set(-100)  # one masked position
    got = float(masked_xent(logits, labels))
    logp = jax.nn.log_softmax(logits, -1)
    mask = np.asarray(labels) >= 0
    nll = -np.take_along_axis(np.asarray(logp),
                              np.maximum(np.asarray(labels), 0)[..., None],
                              axis=-1)[..., 0]
    want = (nll * mask).sum() / max(mask.sum(), 1)
    assert abs(got - want) < 1e-4


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 64), w=st.integers(1, 8))
def test_planner_cost_positive_and_bounded(n, w):
    from repro.core.planner import CostParams, plan_bucket

    plan = plan_bucket(n, 2.0 ** (10 + w), CostParams.tpu_v5e())
    assert plan.cost_s > 0
    # never worse than flat ring (flat is always a candidate)
    from repro.core.planner import t_flat_ring
    assert plan.cost_s <= t_flat_ring(n, 2.0 ** (10 + w), CostParams.tpu_v5e()) + 1e-12
