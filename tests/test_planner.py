"""α–β planner (Lemma 1 on TPU): crossover and regime behavior."""

import pytest

from repro.core.planner import CostParams, crossover_table, plan_bucket


def test_small_buckets_latency_bound_tree_wins():
    plan = plan_bucket(256, 4096.0)
    assert plan.strategy in ("wrht_tree", "rd")


def test_large_buckets_bandwidth_bound():
    plan = plan_bucket(256, 1 << 30)
    assert plan.strategy in ("flat", "hier_scatter")


def test_crossover_is_monotone():
    """Once the bandwidth-optimal family wins it keeps winning as buckets grow."""
    rows = crossover_table(256)
    kinds = [r["strategy"] in ("flat", "hier_scatter") for r in rows]
    first = kinds.index(True) if True in kinds else len(kinds)
    assert all(kinds[first:])


def test_optical_regime_prefers_few_steps():
    """With the paper's 25 µs per-step cost, a small payload must map to a
    minimum-step schedule (the WRHT regime)."""
    p = CostParams.optical(64)
    plan = plan_bucket(1024, 1e4, p, m_candidates=(2, 8, 129))
    assert plan.strategy in ("wrht_tree", "rd")
    if plan.strategy == "wrht_tree":
        assert plan.m >= 8


def test_hier_scatter_beats_flat_alpha():
    """Multi-level reduce-scatter moves the same bytes in fewer steps."""
    from repro.core.planner import t_flat_ring, t_hier_scatter

    p = CostParams.tpu_v5e()
    b = 64 * 2**20
    assert t_hier_scatter((4, 8, 8), b, p) < t_flat_ring(256, b, p)


# ---------------------------------------------------------------------------
# GB/s -> bytes/s conversion regression (the `/ 8 * 8` no-op is gone)
# ---------------------------------------------------------------------------

def test_default_link_bandwidth_conversion():
    """50 GB/s per ICI link is exactly 50e9 bytes/s, and the resulting costs
    are pinned so any future unit slip shows up as a numeric change."""
    from repro.core.planner import t_flat_ring, t_rd

    p = CostParams()
    assert p.link_bw_Bps == 50e9
    assert CostParams.tpu_v5e().link_bw_Bps == p.link_bw_Bps
    assert CostParams.optical(64).link_bw_Bps == 5e9   # 40 Gb/s over 8
    # cost pins: 2*255*1e-6 + 2*(2**20)*(255/256)/50e9 and log2(256)*(α+β·b)
    assert t_flat_ring(256, float(2**20), p) == pytest.approx(
        5.517791999999999e-4, rel=1e-12)
    assert t_rd(256, float(2**20), p) == pytest.approx(
        1.7577216e-4, rel=1e-12)


# ---------------------------------------------------------------------------
# simulated backend: the flit-level simulator as an interchangeable costing
# ---------------------------------------------------------------------------

def test_simulated_backend_flat_cost_equals_simulator():
    from repro.core import simulator, step_models as sm

    p = CostParams.optical(8)
    plan = plan_bucket(64, 1e6, p, backend="simulated", allow=("flat",))
    assert plan.strategy == "flat"
    assert plan.detail["backend"] == "simulated"
    opt = sm.OpticalParams.from_cost(p.alpha_s, p.link_bw_Bps, p.links)
    assert opt.bandwidth_bps == 40e9 and opt.wavelengths == 8
    assert plan.cost_s == simulator.run_optical("ring", 64, 8e6, opt).total_s


def test_simulated_backend_picks_regimes_like_analytic():
    p = CostParams.optical(8)
    small = plan_bucket(64, 4096.0, p, backend="simulated")
    big = plan_bucket(64, 1 << 28, p, backend="simulated")
    assert small.strategy == "wrht_tree"
    assert big.strategy in ("flat", "hier_scatter")
    assert small.cost_s < big.cost_s


def test_simulated_backend_wrht_uses_tuner():
    from repro.core import timing

    p = CostParams.optical(8)
    plan = plan_bucket(64, 1e6, p, backend="simulated",
                       allow=("wrht_tree",), m_candidates=(2, 4, 8, 17))
    tuned = timing.tune_wrht(64, 8, 8e6, m_candidates=(2, 4, 8, 17))
    assert (plan.m, plan.alltoall) == tuned.best(0)
    assert plan.cost_s == tuned.best_total_s[0]


def test_simulated_backend_physical_model_filters_m_consistently():
    """Regression: the m-candidate pre-filter must use the optical model's
    hop budget — a tight PhysicalParams used to crash tune_wrht with
    'no feasible candidates' instead of falling back to flat."""
    from repro.core import step_models as sm
    from repro.core.topology import PhysicalParams

    opt = sm.OpticalParams(
        wavelengths=8,
        physical=PhysicalParams(insertion_loss_db_per_hop=16.0))  # H=2, cap 5
    plan = plan_bucket(64, 1e6, CostParams.optical(8), backend="simulated",
                       optical=opt, m_candidates=(8, 16),
                       allow=("flat", "wrht_tree"))
    assert plan.strategy == "flat"            # wrht candidates out of reach
    plan2 = plan_bucket(64, 1e6, CostParams.optical(8), backend="simulated",
                        optical=opt, m_candidates=(2, 4, 8, 16))
    assert plan2.m <= opt.physical.fan_out_cap


def test_simulated_backend_rejects_unknown_and_empty():
    p = CostParams.optical(8)
    with pytest.raises(ValueError, match="backend"):
        plan_bucket(64, 1e6, p, backend="magic")
    with pytest.raises(ValueError, match="simulated"):
        plan_bucket(64, 1e6, p, backend="simulated", allow=("rd",))


def test_collective_planning_strategies_and_backends_agree():
    """The scheduled collective algebra in the planner (DESIGN.md §11):
    per-collective candidate sets, ring-pass vs single-step all-to-all
    crossover, and analytic/simulated strategy agreement."""
    p = CostParams.optical(64)
    # small axis: the 1-reconfiguration all-to-all wins both RS phases
    for coll in ("reduce_scatter", "all_gather"):
        for backend in ("analytic", "simulated"):
            plan = plan_bucket(16, 1e6, p, backend=backend, collective=coll)
            assert plan.strategy == "alltoall", (coll, backend)
    # large axis: ⌈N²/8⌉ wavelengths are out of reach -> the ring pass
    for backend in ("analytic", "simulated"):
        plan = plan_bucket(1024, 1e6, p, backend=backend,
                          collective="reduce_scatter")
        assert plan.strategy == "flat", backend
    # broadcast sweeps its tree fan-out
    plan = plan_bucket(64, 1e6, p, collective="broadcast")
    assert plan.strategy == "wrht_tree" and plan.m >= 2
    # degenerate axis plans for free
    assert plan_bucket(1, 1e9, p, collective="all_gather").cost_s == 0.0


def test_collective_broadcast_simulated_infeasible_uniform_error():
    """Regression: broadcast fan-out candidates beyond the Lemma-1 cap must
    yield the planner's uniform 'no feasible strategy' error under the
    simulated backend (not tune_wrht's internal one), matching the
    all-reduce simulated path's pre-filter."""
    tight = CostParams(alpha_s=25e-6, link_bw_Bps=5e9, links=2)  # w=1, cap 3
    with pytest.raises(ValueError, match="no feasible strategy"):
        plan_bucket(64, 1e6, tight, backend="simulated",
                    collective="broadcast", m_candidates=(8, 16))
    # a feasible candidate in the mix plans normally on both backends
    for backend in ("analytic", "simulated"):
        plan = plan_bucket(64, 1e6, tight, backend=backend,
                           collective="broadcast", m_candidates=(2, 8, 16))
        assert plan.strategy == "wrht_tree" and plan.m <= 8
