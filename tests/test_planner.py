"""α–β planner (Lemma 1 on TPU): crossover and regime behavior."""

from repro.core.planner import CostParams, crossover_table, plan_bucket


def test_small_buckets_latency_bound_tree_wins():
    plan = plan_bucket(256, 4096.0)
    assert plan.strategy in ("wrht_tree", "rd")


def test_large_buckets_bandwidth_bound():
    plan = plan_bucket(256, 1 << 30)
    assert plan.strategy in ("flat", "hier_scatter")


def test_crossover_is_monotone():
    """Once the bandwidth-optimal family wins it keeps winning as buckets grow."""
    rows = crossover_table(256)
    kinds = [r["strategy"] in ("flat", "hier_scatter") for r in rows]
    first = kinds.index(True) if True in kinds else len(kinds)
    assert all(kinds[first:])


def test_optical_regime_prefers_few_steps():
    """With the paper's 25 µs per-step cost, a small payload must map to a
    minimum-step schedule (the WRHT regime)."""
    p = CostParams.optical(64)
    plan = plan_bucket(1024, 1e4, p, m_candidates=(2, 8, 129))
    assert plan.strategy in ("wrht_tree", "rd")
    if plan.strategy == "wrht_tree":
        assert plan.m >= 8


def test_hier_scatter_beats_flat_alpha():
    """Multi-level reduce-scatter moves the same bytes in fewer steps."""
    from repro.core.planner import t_flat_ring, t_hier_scatter

    p = CostParams.tpu_v5e()
    b = 64 * 2**20
    assert t_hier_scatter((4, 8, 8), b, p) < t_flat_ring(256, b, p)
