"""Degrade gracefully when ``hypothesis`` is not installed.

``from _hypothesis_compat import given, settings, st`` behaves exactly like
importing from ``hypothesis`` when it is available (declared in
``requirements-dev.txt`` / ``pyproject.toml [dev]``).  When it is missing,
the decorators mark the property tests as skipped instead of erroring the
whole module at collection time, so the deterministic tests in the same file
still run.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    given = settings = _skip_decorator

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``: builders are only ever
        evaluated inside decorator argument lists, so they can return None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
