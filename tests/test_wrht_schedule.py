"""WRHT schedule builder: structure, wavelengths, semantics (paper Sec. III)."""

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import wrht
from repro.core.topology import Ring
from repro.core.wavelength import WavelengthConflictError, validate_no_conflicts


def test_motivational_example_fig2():
    """15 nodes, w=2: the paper's Fig. 2(b) finishes in 3 steps (vs BT's 8)."""
    s = wrht.build_schedule(15, 2, 1e6)
    assert s.m == 5
    assert s.num_steps == 3
    kinds = [st_.kind for st_ in s.steps]
    assert kinds == ["reduce", "alltoall", "broadcast"]


def test_table1_step_count():
    s = wrht.build_schedule(1000, 64, 1e6)
    lo, hi = wrht.theoretical_steps(1000, s.m)
    assert lo <= s.num_steps <= hi
    assert s.num_steps in (3, 4)  # 2⌈log_129 1000⌉ = 4, −1 with all-to-all


def test_every_node_receives_full_reduction():
    s = wrht.build_schedule(100, 8, 1.0)
    sets = wrht.simulate_contributions(s)
    assert all(x == frozenset(range(100)) for x in sets)


def test_wavelength_budget_never_exceeded():
    for n, w in [(64, 2), (100, 8), (256, 64), (31, 3)]:
        s = wrht.build_schedule(n, w, 1.0)
        for step in s.steps:
            assert step.wavelengths <= w


def test_conflict_validation_rejects_bad_assignment():
    from repro.core.topology import CW, Transfer

    # two overlapping CW paths on the same wavelength
    t1 = Transfer(0, 3, CW, 1.0, wavelength=0)
    t2 = Transfer(1, 4, CW, 1.0, wavelength=0)
    with pytest.raises(WavelengthConflictError):
        validate_no_conflicts([t1, t2], n=8, w=4)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 300), w=st.integers(1, 32))
def test_schedule_properties_random(n, w):
    """For any (N, w): valid wavelengths, correct semantics, step count within
    the paper's closed-form band."""
    s = wrht.build_schedule(n, w, 1.0)
    ring = Ring(max(n, 2), w)
    for step in s.steps:
        validate_no_conflicts(step.transfers, ring.n, ring.w)
        assert step.wavelengths <= w
    lo, hi = wrht.theoretical_steps(n, s.m)
    assert s.num_steps <= hi
    masks = wrht.simulate_contribution_masks(s)
    full = (1 << n) - 1
    assert all(m == full for m in masks)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 120), w=st.integers(1, 8), m=st.integers(2, 12))
def test_custom_group_size(n, w, m):
    s = wrht.build_schedule(n, w, 1.0, m=m)
    masks = wrht.simulate_contribution_masks(s)
    assert all(x == (1 << n) - 1 for x in masks)


def test_lemma1_optimal_group_size():
    assert wrht.optimal_group_size(64) == 129
    assert wrht.optimal_group_size(2) == 5
