"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and the absence of NaNs (assignment contract).
Full configs are exercised only via the dry-run."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api as mapi

RNG = np.random.default_rng(0)
B, S = 2, 16


def _batch(cfg):
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    if cfg.frontend == "patch_embed":
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.frontend_seq, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = registry.get(arch, smoke=True)
    api = mapi.get_api(cfg, remat="none")
    params = api.init(jax.random.key(0))
    batch = _batch(cfg)

    loss, metrics = jax.jit(api.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    grads = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-2.7b", "xlstm-350m",
                                  "whisper-medium", "deepseek-v2-236b"])
def test_arch_smoke_prefill_decode(arch):
    """One family member per code path: prefill fills the cache, a decode
    step extends it; logits finite and correctly shaped."""
    cfg = registry.get(arch, smoke=True)
    api = mapi.get_api(cfg, remat="none")
    params = api.init(jax.random.key(0))
    batch = _batch(cfg)
    batch.pop("labels")
    cache = api.init_cache(B, 64)

    logits, cache = jax.jit(api.prefill)(params, batch, cache)
    assert logits.shape[0] == B
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    pos = S + (cfg.frontend_seq if cfg.frontend == "patch_embed" else 0)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(api.decode)(params, tok, jnp.asarray(pos, jnp.int32), cache)
    assert logits2.shape[0] == B
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_decode_matches_forward_qwen2():
    """Teacher-forced decode reproduces the parallel forward's logits."""
    cfg = registry.get("qwen2-1.5b", smoke=True)
    api = mapi.get_api(cfg, compute_dtype=jnp.float32, remat="none")
    params = api.init(jax.random.key(1))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)

    from repro.models import transformer as T
    hidden, _, _ = T.forward(params, toks, cfg, compute_dtype=jnp.float32,
                             remat="none")
    full_logits = T.logits_fn(params, hidden, cfg)

    cache = api.init_cache(1, 16, dtype=jnp.float32)
    logits_p, cache = api.prefill(params, {"tokens": toks[:, :4]}, cache)
    np.testing.assert_allclose(np.asarray(logits_p[0]),
                               np.asarray(full_logits[0, 3]), rtol=2e-4, atol=2e-4)
    for t in range(4, 8):
        logits_d, cache = api.decode(params, toks[:, t],
                                     jnp.asarray(t, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(logits_d[0]),
                               np.asarray(full_logits[0, 7]), rtol=2e-4, atol=2e-4)


def test_causality_property_qwen2():
    """Perturbing a future token must not change past logits."""
    cfg = registry.get("qwen2-1.5b", smoke=True)
    from repro.models import transformer as T
    api = mapi.get_api(cfg, compute_dtype=jnp.float32, remat="none")
    params = api.init(jax.random.key(2))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 10)), jnp.int32)
    h1, _, _ = T.forward(params, toks, cfg, compute_dtype=jnp.float32, remat="none")
    toks2 = toks.at[0, 7].set((toks[0, 7] + 1) % cfg.vocab_size)
    h2, _, _ = T.forward(params, toks2, cfg, compute_dtype=jnp.float32, remat="none")
    np.testing.assert_allclose(np.asarray(h1[0, :7]), np.asarray(h2[0, :7]),
                               rtol=1e-5, atol=1e-5)


def test_param_counts_match_published():
    expected = {
        "deepseek-67b": (67e9, 0.08), "qwen2-1.5b": (1.5e9, 0.1),
        "qwen1.5-4b": (4e9, 0.1), "gemma-7b": (8.5e9, 0.05),
        "whisper-medium": (0.77e9, 0.1), "zamba2-2.7b": (2.7e9, 0.15),
        "granite-moe-1b-a400m": (1.3e9, 0.1), "deepseek-v2-236b": (236e9, 0.03),
    }
    for arch, (target, tol) in expected.items():
        n = mapi.param_count(registry.get(arch))
        assert abs(n - target) / target < tol, f"{arch}: {n/1e9:.2f}B vs {target/1e9}B"


def test_all_cells_enumerate():
    cells = registry.cells()
    assert len(cells) == 32  # 10 archs x 3 shapes + 2 sub-quadratic long_500k
    skipped = [c for c in registry.cells(include_skipped=True) if c[2]]
    assert len(skipped) == 8
