import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"

# Version gate for the multi-device subprocess tests: probe the installed
# jax ONCE for the features they need instead of pattern-matching subprocess
# stderr.  On a jax that actually lacks jax.sharding.AxisType the tests
# skip with a precise reason; on any newer jax they execute — and an
# AxisType import error there is a real failure, never a silent skip.
try:
    from jax.sharding import AxisType as _AxisType  # noqa: F401

    HAVE_AXISTYPE = True
except ImportError:
    HAVE_AXISTYPE = False


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python code in a fresh process with N fake XLA host devices.

    Multi-device tests must not pollute this process's jax (which smoke
    tests expect to see exactly ONE device), hence the subprocess.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH','')}"
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        missing_axistype = "cannot import name 'AxisType'" in proc.stderr
        if missing_axistype and not HAVE_AXISTYPE:
            # Genuine environment limitation (verified against the installed
            # jax above), not a repo regression: skip instead of carrying
            # known-red tests.  CI images with a current jax never take this
            # branch — there the tests run and must pass.
            pytest.skip(
                "jax.sharding.AxisType absent from the installed jax "
                "(feature-probed at collection); multi-device subprocess "
                "tests cannot run in this environment"
            )
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout\n"
            f"{proc.stdout}\n--- stderr\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
