import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python code in a fresh process with N fake XLA host devices.

    Multi-device tests must not pollute this process's jax (which smoke
    tests expect to see exactly ONE device), hence the subprocess.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH','')}"
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        if "cannot import name 'AxisType'" in proc.stderr:
            # This container ships a jax without jax.sharding.AxisType, which
            # every multi-device mesh construction here needs (directly or via
            # repro.launch.mesh).  That is an environment limitation, not a
            # repo regression — skip instead of carrying known-red tests; on a
            # current jax these tests run and must pass.
            pytest.skip(
                "jax.sharding.AxisType unavailable in the installed jax; "
                "multi-device subprocess tests cannot run in this environment"
            )
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout\n"
            f"{proc.stdout}\n--- stderr\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
