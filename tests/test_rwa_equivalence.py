"""Golden equivalence: vectorized bitmask RWA == original per-object greedy.

The array engine (DESIGN.md §2) must be *bit-identical* to
``first_fit_assign_reference`` — same wavelengths, same failures — on any
input, including the randomized sets here and whole WRHT schedules.  Also
covers the scales the old engine made infeasible (N=4096 full validation).
"""

import numpy as np
import pytest

from repro.core import wrht
from repro.core.topology import CCW, CW, Transfer, TransferBatch
from repro.core.wavelength import (
    WavelengthConflictError,
    first_fit_assign,
    first_fit_assign_reference,
    validate_no_conflicts,
    validate_no_conflicts_reference,
)


def _random_batch(rng, n, t_count):
    src = rng.integers(0, n, t_count)
    dst = (src + rng.integers(1, n, t_count)) % n
    direction = rng.choice([CW, CCW], t_count)
    return TransferBatch.from_arrays(src, dst, direction, 1.0)


@pytest.mark.parametrize("seed", range(8))
def test_golden_equivalence_random_sets(seed):
    rng = np.random.default_rng(seed)
    for _ in range(40):
        n = int(rng.integers(4, 200))
        t_count = int(rng.integers(1, 120))
        w = int(rng.integers(1, 66))  # crosses the single-uint64-word boundary
        batch = _random_batch(rng, n, t_count)
        ref_lams = ref_err = None
        try:
            ref_lams = [t.wavelength
                        for t in first_fit_assign_reference(batch.to_transfers(), n, w)]
        except WavelengthConflictError as e:
            ref_err = e
        if ref_err is not None:
            with pytest.raises(WavelengthConflictError):
                first_fit_assign(batch, n, w)
        else:
            fast = first_fit_assign(batch, n, w)
            assert fast.wavelength.tolist() == ref_lams


def test_golden_equivalence_whole_wrht_schedules():
    for n, w in [(15, 2), (31, 3), (100, 8), (257, 8), (1000, 64)]:
        fast = wrht.build_schedule(n, w, 1.0, rwa="fast")
        ref = wrht.build_schedule(n, w, 1.0, rwa="reference")
        assert [s.kind for s in fast.steps] == [s.kind for s in ref.steps]
        for a, b in zip(fast.steps, ref.steps):
            assert a.transfers.wavelength.tolist() == b.transfers.wavelength.tolist()


def test_overbudget_raises_like_reference():
    # 10 identical full-overlap paths but only 4 wavelengths
    batch = TransferBatch.from_arrays([0] * 10, [5] * 10, CW, 1.0)
    with pytest.raises(WavelengthConflictError):
        first_fit_assign_reference(batch.to_transfers(), 16, 4)
    with pytest.raises(WavelengthConflictError):
        first_fit_assign(batch, 16, 4)


def test_validator_matches_reference_on_random_assignments():
    rng = np.random.default_rng(7)
    for _ in range(60):
        n = int(rng.integers(4, 64))
        w = int(rng.integers(1, 9))
        batch = _random_batch(rng, n, int(rng.integers(1, 40)))
        batch = batch.with_wavelengths(rng.integers(0, w, len(batch)))
        ref_ok = fast_ok = True
        try:
            validate_no_conflicts_reference(batch.to_transfers(), n, w)
        except WavelengthConflictError:
            ref_ok = False
        try:
            validate_no_conflicts(batch, n, w)
        except WavelengthConflictError:
            fast_ok = False
        assert ref_ok == fast_ok


def test_validator_rejects_out_of_range_and_unassigned():
    t = TransferBatch.from_transfers([Transfer(0, 3, CW, 1.0, wavelength=5)])
    with pytest.raises(WavelengthConflictError):
        validate_no_conflicts(t, n=8, w=4)
    u = TransferBatch.from_transfers([Transfer(0, 3, CW, 1.0)])
    with pytest.raises(WavelengthConflictError):
        validate_no_conflicts(u, n=8, w=4)


def test_batch_roundtrip_preserves_transfers():
    ts = [Transfer(0, 3, CW, 2.0, 1), Transfer(7, 2, CCW, 4.0, 0)]
    batch = TransferBatch.from_transfers(ts)
    assert batch.to_transfers() == ts
    assert len(batch) == 2 and batch.max_wavelength == 1


@pytest.mark.slow
def test_full_build_and_validate_at_4096():
    """End-to-end validated build at a scale the old engine capped out on."""
    sched = wrht.build_schedule(4096, 64, 1.0, validate=True)
    lo, hi = wrht.theoretical_steps(4096, sched.m)
    assert lo <= sched.num_steps <= hi
    for step in sched.steps:
        assert step.wavelengths <= 64
