"""Closed-form step counts and time models (paper Table I / Eq. 1)."""

import math

import pytest

from repro.core import step_models as sm


def test_table1_numbers_n1000_w64():
    assert sm.ring_steps(1000) == 1998
    assert sm.bt_steps(1000) == 20
    # the paper's table prints 411 = formula without the −4 term
    assert sm.hring_steps(1000, 5, 64, table_variant=True) == 411
    assert sm.hring_steps(1000, 5, 64) == 407
    assert sm.wrht_steps(1000, 129, with_alltoall=False) == 4
    assert sm.wrht_steps(1000, 129, with_alltoall=True) == 3


def test_rd_steps():
    assert sm.rd_steps(1024) == 10
    assert sm.rd_steps(128) == 7


def test_eq1_time_decomposition():
    """T = θ·d/B + θ·a exactly for full-vector algorithms."""
    p = sm.OpticalParams()
    d = 1e9
    t = sm.t_wrht(1024, d, p)
    theta = sm.wrht_steps(1024, 2 * p.wavelengths + 1, False)
    assert t == pytest.approx(theta * d / p.bandwidth_bps
                              + theta * p.reconfig_delay_s)


def test_wrht_time_nearly_constant_in_n():
    """The paper's headline: WRHT comm time ~constant from 1k to 4k nodes."""
    p = sm.OpticalParams()
    d = 62.3e6 * 32
    t1 = sm.t_wrht(1024, d, p)
    t4 = sm.t_wrht(4096, d, p)
    assert t4 <= 2.0 * t1  # one extra ⌈log⌉ level at most


def test_ring_time_linear_in_n():
    p = sm.OpticalParams()
    d = 62.3e6 * 32
    t1 = sm.t_ring_optical(1024, d, p)
    t4 = sm.t_ring_optical(4096, d, p)
    assert t4 > 1.8 * t1


def test_electrical_slower_than_optical():
    """Fig. 5 directionality: optical ring beats the electrical fat-tree."""
    e, o = sm.ElectricalParams(), sm.OpticalParams()
    for d in sm.PAPER_MODELS_BITS.values():
        assert sm.t_ring_electrical(512, d, e) > sm.t_ring_optical(512, d, o)
        assert sm.t_rd_electrical(512, d, e) > sm.t_wrht(512, d, o)
