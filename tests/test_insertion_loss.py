"""Insertion-loss physical layer: hop budget, arc splitting, schedule caps.

The paper's Sec. III constraint — a wavelength can only traverse as many
nodes as the optical power budget allows — enters the code as
``topology.PhysicalParams`` (power budget → hop budget), is enforced in
``wavelength`` (validation + relay splitting), caps the tree fan-out in
``wrht.build_schedule``, and filters candidate fan-outs in
``planner.plan_bucket``.
"""

import numpy as np
import pytest

from repro.core import planner, simulator, step_models as sm, wrht
from repro.core.topology import CCW, CW, PhysicalParams, Ring, TransferBatch
from repro.core.wavelength import (
    InsertionLossError,
    first_fit_assign,
    split_overlong_arcs,
    validate_hop_budget,
    validate_no_conflicts,
)


# ---------------------------------------------------------------------------
# PhysicalParams: power budget -> hop budget
# ---------------------------------------------------------------------------

def test_max_hops_from_power_budget():
    p = PhysicalParams(laser_power_dbm=10, receiver_sensitivity_dbm=-26,
                       coupling_loss_db=4, insertion_loss_db_per_hop=0.5)
    assert p.power_budget_db == pytest.approx(32.0)
    assert p.max_hops == 64
    assert p.fan_out_cap == 129
    assert PhysicalParams(insertion_loss_db_per_hop=2.0).max_hops == 16


def test_exact_division_boundary():
    # 32 dB budget, 8 dB/hop: exactly 4 hops, not 3 or 5
    p = PhysicalParams(insertion_loss_db_per_hop=8.0)
    assert p.max_hops == 4


def test_lossless_is_unbounded():
    assert PhysicalParams(insertion_loss_db_per_hop=0.0).max_hops > 10**9


def test_budget_below_one_hop_rejected():
    with pytest.raises(ValueError, match="single hop"):
        PhysicalParams(laser_power_dbm=-30, insertion_loss_db_per_hop=8.0)


def test_feasible_vectorized():
    p = PhysicalParams(insertion_loss_db_per_hop=8.0)  # H=4
    np.testing.assert_array_equal(
        p.feasible(np.array([1, 4, 5, 100])), [True, True, False, False]
    )


# ---------------------------------------------------------------------------
# wavelength: hop-budget validation and relay splitting
# ---------------------------------------------------------------------------

def _one(src, dst, direction, n=16):
    return TransferBatch.from_arrays([src], [dst], direction, 1.0, wavelength=0)


def test_hop_budget_exactly_met_passes():
    validate_hop_budget(_one(0, 4, CW), n=16, max_hops=4)
    validate_hop_budget(_one(4, 0, CCW), n=16, max_hops=4)


def test_hop_budget_exceeded_rejected():
    with pytest.raises(InsertionLossError, match="5 segments"):
        validate_hop_budget(_one(0, 5, CW), n=16, max_hops=4)


def test_validate_no_conflicts_checks_budget():
    with pytest.raises(InsertionLossError):
        validate_no_conflicts(_one(0, 5, CW), n=16, w=4, max_hops=4)
    validate_no_conflicts(_one(0, 4, CW), n=16, w=4, max_hops=4)


def test_first_fit_rejects_overlong_arc():
    batch = TransferBatch.from_arrays([0], [5], CW, 1.0)
    with pytest.raises(InsertionLossError):
        first_fit_assign(batch, n=16, w=4, max_hops=4)
    assigned = first_fit_assign(batch, n=16, w=4, max_hops=5)
    assert assigned.wavelength[0] == 0


def test_split_overlong_arcs_chains_connect():
    # 10-hop CW path with H=3 -> 4 relay segments of 3+3+3+1
    batch = TransferBatch.from_arrays([2], [12], CW, 7.0)
    subs = split_overlong_arcs(batch, n=16, max_hops=3)
    assert len(subs) == 4
    hops = [int(s.arcs(16)[2][0]) for s in subs]
    assert hops == [3, 3, 3, 1]
    # the chain is contiguous: each sub-path starts where the previous ended
    assert int(subs[0].src[0]) == 2
    for prev, nxt in zip(subs, subs[1:]):
        assert int(prev.dst[0]) == int(nxt.src[0])
    assert int(subs[-1].dst[0]) == 12
    assert all(int(s.direction[0]) == CW for s in subs)
    assert all(float(s.bits[0]) == 7.0 for s in subs)
    # wavelengths are reset for per-sub-step RWA
    assert all(int(s.wavelength[0]) == -1 for s in subs)


def test_split_overlong_arcs_ccw_and_short_mix():
    batch = TransferBatch.from_arrays([12, 5], [2, 4], [CCW, CCW], 1.0)
    subs = split_overlong_arcs(batch, n=16, max_hops=4)
    assert len(subs) == 3  # 10 CCW hops -> 4+4+2; the 1-hop stays in sub 0
    assert len(subs[0]) == 2 and len(subs[1]) == 1 and len(subs[2]) == 1
    # reassemble the long chain: 12 -> 8 -> 4 -> 2 going CCW
    assert int(subs[0].dst[0]) == 8
    assert int(subs[1].src[0]) == 8 and int(subs[1].dst[0]) == 4
    assert int(subs[2].src[0]) == 4 and int(subs[2].dst[0]) == 2


def test_split_within_budget_is_identity_shape():
    batch = TransferBatch.from_arrays([0, 3], [2, 5], CW, 1.0)
    subs = split_overlong_arcs(batch, n=16, max_hops=4)
    assert len(subs) == 1 and len(subs[0]) == 2


# ---------------------------------------------------------------------------
# wrht: the builder never emits an overlong lightpath
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w,H", [
    (64, 8, 3), (64, 8, 32), (100, 8, 1), (127, 4, 2),  # 127 is prime
    (256, 64, 64), (31, 3, 5), (17, 2, 1),
])
def test_schedule_respects_hop_budget_and_semantics(n, w, H):
    s = wrht.build_schedule(n, w, 1.0, max_hops=H)
    assert s.max_hops == H
    for step in s.steps:
        validate_hop_budget(step.transfers, n, H)
        assert step.wavelengths <= w
    masks = wrht.simulate_contribution_masks(s)
    assert all(m == (1 << n) - 1 for m in masks)


def test_fan_out_capped_at_level_zero():
    # w=64 would allow m=129, but H=4 caps the group at 2*4+1=9
    s = wrht.build_schedule(64, 64, 1.0, max_hops=4)
    assert s.m == 9
    assert s.level_group_sizes[0] == 9


def test_hop_budget_exactly_met_in_schedule():
    # m=2H+1 puts the farthest member exactly H hops from the representative
    H = 4
    s = wrht.build_schedule(27, 64, 1.0, max_hops=H)
    hops0 = s.steps[0].transfers.arcs(27)[2]
    assert int(hops0.max()) == H


def test_physical_params_equivalent_to_max_hops():
    phys = PhysicalParams(insertion_loss_db_per_hop=2.0)  # H=16
    a = wrht.build_schedule(100, 8, 1.0, physical=phys)
    b = wrht.build_schedule(100, 8, 1.0, max_hops=16)
    assert a.max_hops == b.max_hops == 16
    assert a.num_steps == b.num_steps
    assert a.level_group_sizes == b.level_group_sizes


def test_validate_schedule_rejects_overlong_transfer():
    s = wrht.build_schedule(64, 8, 1.0)  # unconstrained build: 8-hop paths
    s.max_hops = 2
    with pytest.raises(InsertionLossError):
        wrht.validate_schedule(s)


def test_feasible_group_size():
    assert wrht.feasible_group_size(64) == 129
    assert wrht.feasible_group_size(64, max_hops=4) == 9
    assert wrht.feasible_group_size(64, max_hops=4, spacing=9) == 2
    assert wrht.feasible_group_size(2, max_hops=100) == 5


def test_alltoall_skipped_when_out_of_reach():
    # 15 nodes, w=2: Fig. 2(b) uses an all-to-all among reps 5 apart (up to
    # 10 ring hops between them); H=4 forbids it and the tree must climb
    free = wrht.build_schedule(15, 2, 1.0)
    assert any(st.kind == "alltoall" for st in free.steps)
    capped = wrht.build_schedule(15, 2, 1.0, max_hops=4)
    assert not any(st.kind == "alltoall" for st in capped.steps)
    for step in capped.steps:
        validate_hop_budget(step.transfers, 15, 4)


# ---------------------------------------------------------------------------
# simulator + planner integration
# ---------------------------------------------------------------------------

def test_run_optical_wrht_under_budget():
    p = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=4.0))
    r = simulator.run_optical("wrht", 256, 1e6, p)
    assert r.total_s > 0
    sched = simulator._cached_wrht_schedule(256, p.wavelengths, None, 8)
    for step in sched.steps:
        validate_hop_budget(step.transfers, 256, 8)


def test_hring_prime_n_fallback_feasible_under_budget():
    # prime N degrades H-Ring to the flat ring, whose neighbour hops always
    # fit any budget >= 1 — the physical layer must not break the fallback
    p = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=8.0))
    assert p.physical.max_hops == 4
    for n in (13, 127):
        r = simulator.run_optical("hring", n, 1e6, p)
        assert r.algorithm == "hring"
        assert r.steps == sm.ring_steps(n)
        assert r.total_s > 0


def test_hring_single_group_wrap_link_checked():
    # n=7 admits g=7 (one group): the intra wrap link spans 6 segments,
    # genuinely infeasible at H=4 — reported, not silently mistimed
    p = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=8.0))
    with pytest.raises(InsertionLossError, match="6 segments"):
        simulator.run_optical("hring", 7, 1e6, p)


def test_bt_infeasible_at_tight_budget():
    p = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=8.0))
    with pytest.raises(InsertionLossError):
        simulator.run_optical("bt", 256, 1e6, p)


def test_max_feasible_m():
    assert sm.max_feasible_m(sm.OpticalParams()) == 129
    p = sm.OpticalParams(physical=PhysicalParams(insertion_loss_db_per_hop=4.0))
    assert sm.max_feasible_m(p) == 17  # H=8 -> 2*8+1


def test_planner_never_plans_infeasible_m():
    cp = planner.CostParams.optical(64)
    # force the tree strategy so the m filter is what decides
    plan = planner.plan_bucket(256, 1e3, cp, allow=("wrht_tree",),
                               m_candidates=(2, 3, 4, 8, 16), max_hops=3)
    assert plan.strategy == "wrht_tree"
    assert plan.m <= 2 * 3 + 1
    # unconstrained, the same call picks a larger fan-out (fewer steps win)
    free = planner.plan_bucket(256, 1e3, cp, allow=("wrht_tree",),
                               m_candidates=(2, 3, 4, 8, 16))
    assert free.m == 16


def test_planner_all_m_infeasible_falls_back():
    cp = planner.CostParams.optical(64)
    plan = planner.plan_bucket(256, 1e3, cp, m_candidates=(8, 16), max_hops=2)
    assert plan.strategy != "wrht_tree"
