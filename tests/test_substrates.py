"""Optimizer, data pipeline, bucketing, compression primitives."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import bucketing
from repro.core.compression import dequantize, ef_compress, quantize
from repro.data.pipeline import CorpusLM, SyntheticLM
from repro.optim import adamw_init, adamw_update, global_norm, make_lr_schedule


def test_adamw_converges_on_quadratic():
    tc = TrainConfig(lr=0.1, weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, opt, _ = adamw_update(grads, opt, params, jnp.asarray(0.05), tc)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_applies():
    tc = TrainConfig(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    grads = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(grads, opt, params, jnp.asarray(0.0), tc)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_warmup_and_decay():
    tc = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lr = make_lr_schedule(tc)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) < 1e-4


def test_synthetic_data_deterministic_per_step():
    src = SyntheticLM(1000, 16, 4, seed=7)
    a, b = src.batch(3), src.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_corpus_labels_shift():
    src = CorpusLM(300, 16, 4)
    b = src.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=20, deadline=None)
@given(sizes=st.lists(st.integers(1, 200), min_size=1, max_size=10),
       cap=st.integers(64, 4096))
def test_bucketing_roundtrip_identity(sizes, cap):
    rng = np.random.default_rng(0)
    tree = {f"p{i}": jnp.asarray(rng.normal(size=(s,)), jnp.float32)
            for i, s in enumerate(sizes)}
    out = bucketing.bucketed_allreduce(tree, lambda b, n: b, max_bucket_bytes=cap)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))


@pytest.mark.parametrize("depth", [2, 3, 5])
def test_bucketing_pipelined_bit_identical_at_depth(depth):
    """Regression for the sliding-window drain: pipelining must only
    reorder *issue*, never change per-bucket numerics — at any depth the
    result is bit-identical to the serial ag(rs(...)) composition, and
    every bucket's phases ran exactly once in FIFO window order."""
    rng = np.random.default_rng(3)
    tree = {f"p{i}": jnp.asarray(rng.normal(size=(s,)), jnp.float32)
            for i, s in enumerate([300, 7, 1200, 64, 512, 2, 900])}
    spec = bucketing.plan_buckets(tree, max_bucket_bytes=2048)
    assert len(spec.bucket_sizes) > depth  # window actually wraps

    calls = []

    def rs(b, n, i):
        calls.append(("rs", i))
        return b * 0.5, {"scale": 2.0, "i": i}

    def ag(shard, ctx, n, j):
        calls.append(("ag", j))
        assert ctx["i"] == j  # the ctx carried belongs to this bucket
        return shard * ctx["scale"]

    out = bucketing.bucketed_apply_pipelined(tree, rs, ag, spec, depth=depth)
    serial = bucketing.bucketed_apply_indexed(
        tree, lambda b, n, i: (b * 0.5) * 2.0, spec)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(serial[k]))
    nb = len(spec.bucket_sizes)
    rs_order = [i for kind, i in calls[: 2 * nb] if kind == "rs"][:nb]
    ag_order = [i for kind, i in calls if kind == "ag"][:nb]
    assert sorted(rs_order) == list(range(nb))
    assert ag_order == sorted(ag_order)  # FIFO drain: all-gathers in order


def test_bucketing_pipelined_depth_validation():
    tree = {"p": jnp.zeros(8, jnp.float32)}
    spec = bucketing.plan_buckets(tree)
    with pytest.raises(ValueError, match="depth"):
        bucketing.bucketed_apply_pipelined(
            tree, lambda b, n, i: (b, None),
            lambda s, c, n, j: s, spec, depth=0)


def test_bucket_cap_respected():
    tree = {f"p{i}": jnp.zeros(100, jnp.float32) for i in range(10)}  # 400 B each
    spec = bucketing.plan_buckets(tree, max_bucket_bytes=1000)
    assert len(spec.bucket_sizes) >= 4
    assert max(spec.bucket_sizes) * 4 <= 1000


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2, max_size=64))
def test_quantize_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    c = quantize(x)
    err = float(jnp.abs(dequantize(c) - x).max())
    assert err <= float(jnp.abs(x).max()) / 127 + 1e-5


def test_error_feedback_accumulates_residual():
    g = jnp.asarray([1.0, 0.004, -0.004, 0.5])
    e = jnp.zeros(4)
    c, e1 = ef_compress(g, e)
    # residual equals what quantization lost
    np.testing.assert_allclose(np.asarray(dequantize(c) + e1), np.asarray(g),
                               rtol=1e-6, atol=1e-7)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
