"""HLO collective parser + roofline arithmetic (pure text-level units)."""

import pytest

from repro.launch.hlo_analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                       _shape_bytes, parse_collectives)

SAMPLE_HLO = """
HloModule jit_step

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(%p0), to_apply=%sum
  %cp = f32[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %rs = f32[32,256]{1,0} reduce-scatter(%p0), dimensions={0}
  %a2a = f32[128,256]{1,0} all-to-all(%p0), dimensions={0}
  ROOT %out = f32[128,256]{1,0} add(%ar, %cp)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4], s8[4])") == 20
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_counts_and_bytes():
    stats = parse_collectives(SAMPLE_HLO)
    operand = 128 * 256 * 4
    assert stats.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                   "collective-permute": 1,
                                   "reduce-scatter": 1, "all-to-all": 1}
    # every op's single operand is p0
    for kind in stats.bytes_by_kind:
        assert stats.bytes_by_kind[kind] == operand
    assert stats.total_bytes == 5 * operand


def test_parse_variadic_allreduce():
    hlo = """
  %a = bf16[1024]{0} parameter(0)
  %b = bf16[2048]{0} parameter(1)
  %arv = (bf16[1024], bf16[2048]) all-reduce(%a, %b), to_apply=%sum
"""
    stats = parse_collectives(hlo)
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 2 + 2048 * 2


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_per_device=PEAK_FLOPS,        # 1 s of compute
                 bytes_per_device=HBM_BW / 2,        # 0.5 s of memory
                 collective_bytes_per_device=ICI_BW / 4,  # 0.25 s
                 model_flops_per_device=PEAK_FLOPS / 2)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)


def test_analytic_model_flops_scaling():
    """MODEL_FLOPS must scale ~linearly with tokens and with N_active."""
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.launch import analytic

    cfg = registry.get("qwen2-1.5b")
    s1 = ShapeConfig("a", 4096, 64, "train")
    s2 = ShapeConfig("b", 4096, 128, "train")
    f1, f2 = analytic.model_flops(cfg, s1), analytic.model_flops(cfg, s2)
    assert f2 / f1 == pytest.approx(2.0, rel=1e-6)

    moe = registry.get("granite-moe-1b-a400m")
    act = analytic.n_active(moe)
    # active params far below total for top-8/32 experts
    from repro.models.api import param_count
    assert act < param_count(moe)


def test_depth_variants_consistent():
    from repro.configs import registry
    from repro.launch import analytic

    for arch in ("deepseek-67b", "zamba2-2.7b", "xlstm-350m",
                 "whisper-medium", "deepseek-v2-236b"):
        cfg = registry.get(arch)
        full = analytic.scan_depth(cfg)
        assert full >= 2
        c1 = analytic.with_depth(cfg, 1)
        assert analytic.scan_depth(c1) == 1
        c0 = analytic.with_depth(cfg, 0)
        assert analytic.scan_depth(c0) == 0
